"""smollm-360m — llama-architecture small dense model.

[hf:HuggingFaceTB/SmolLM-360M; hf]  32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    attn_backend="flash",  # Pallas kernel on TPU; blockwise fallback off-TPU
    decode_backend="kernel",  # split-KV flash-decode on TPU (serving)
)

SPEC = ArchSpec(
    model=MODEL,
    source="hf:HuggingFaceTB/SmolLM-360M",
    notes="long_500k skipped: pure full attention (quadratic)",
)
