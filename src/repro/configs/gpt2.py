"""The paper's own models: GPT-2 117M / 1.5B and GPT-3 125M replicas.

These mirror the configurations in Section 3 / 5 of the paper (Radford et al.
GPT-2; Brown et al. GPT-3 small), with learned positional embeddings,
LayerNorm and GELU MLPs — the Megatron-LM-era architecture the paper trains.
"""
from repro.configs.base import ArchSpec, ModelConfig, ShapeConfig

GPT2_117M = ModelConfig(
    name="gpt2-117m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=50257,
    pos_emb="learned",
    norm="layernorm",
    mlp="gelu",
    tie_embeddings=True,
    max_seq_len=2048,
    # the training hot path: Pallas flash attention (fwd + bwd) on TPU;
    # blockwise fallback keeps CPU smoke tests and the dry-run unchanged
    attn_backend="flash",
    # serving hot path: Pallas split-KV flash-decode on TPU (reference
    # fallback elsewhere)
    decode_backend="kernel",
)

GPT2_1P5B = GPT2_117M.replace(
    name="gpt2-1.5b", n_layers=48, d_model=1600, n_heads=25, n_kv_heads=25,
    d_ff=6400, head_dim=64,
)

GPT3_125M = GPT2_117M.replace(name="gpt3-125m", max_seq_len=2048)

# Paper training shapes: GPT-2 uses seqlen 1K (2K for the GPT-3-style runs).
PAPER_SHAPES = (
    ShapeConfig("train_1k_b512", "train", 1024, 512),
    ShapeConfig("train_1k_b4k", "train", 1024, 4096),
    ShapeConfig("train_2k_b512", "train", 2048, 512),
)

SPEC_GPT2_117M = ArchSpec(model=GPT2_117M, shapes=PAPER_SHAPES,
                          source="paper §3 (Radford et al. 2019)")
SPEC_GPT2_1P5B = ArchSpec(model=GPT2_1P5B, shapes=PAPER_SHAPES,
                          source="paper §3 (Radford et al. 2019)")
SPEC_GPT3_125M = ArchSpec(model=GPT3_125M, shapes=PAPER_SHAPES,
                          source="paper §5.2 (Brown et al. 2020)")
