"""qwen3-32b — dense GQA with qk-norm.

[hf:Qwen/Qwen3-8B family; hf]  64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    attn_backend="flash",  # Pallas kernel on TPU; blockwise fallback off-TPU
    decode_backend="kernel",  # split-KV flash-decode on TPU (serving)
)

SPEC = ArchSpec(
    model=MODEL,
    source="hf:Qwen/Qwen3 family",
    notes="largest dense cell; long_500k skipped: pure full attention",
)
