"""qwen2-1.5b — dense GQA with QKV bias.

[arXiv:2407.10671; hf]  28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    attn_backend="flash",  # Pallas kernel on TPU; blockwise fallback off-TPU
    decode_backend="kernel",  # split-KV flash-decode on TPU (serving)
)

SPEC = ArchSpec(
    model=MODEL,
    source="arXiv:2407.10671",
    notes="long_500k skipped: pure full attention (quadratic)",
)
