"""musicgen-large — decoder-only over EnCodec tokens (backbone only).

[arXiv:2306.05284; hf]  48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.
The EnCodec frontend is a STUB per the assignment: input_specs() provide
precomputed frame embeddings (B, S, d_model).
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="musicgen-large",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio_frames",
    pos_emb="learned",
    norm="layernorm",
    mlp="gelu",
    max_seq_len=32768,
)

SPEC = ArchSpec(
    model=MODEL,
    source="arXiv:2306.05284",
    notes="audio backbone; frontend stubbed (precomputed frame embeddings); "
    "long_500k skipped: full attention",
)
