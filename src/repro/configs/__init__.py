"""Architecture registry: ``get_arch(name)`` / ``--arch <id>``.

Ten assigned architectures (public literature, see per-file docstrings) plus
the paper's own GPT-2/GPT-3 replicas.  ``reduced(model)`` produces a small
same-family config for CPU smoke tests; the full configs are exercised only
through the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    ArchSpec,
    BatchWarmupConfig,
    LM_SHAPES,
    ModelConfig,
    OptimizerConfig,
    RegulatorSpec,
    ShapeConfig,
    SLWConfig,
    TrainConfig,
)

from repro.configs import (  # noqa: E402
    deepseek_moe_16b,
    gpt2,
    llava_next_mistral_7b,
    moonshot_v1_16b_a3b,
    musicgen_large,
    phi3_mini_3p8b,
    qwen2_1p5b,
    qwen3_32b,
    rwkv6_7b,
    smollm_360m,
    zamba2_2p7b,
)

# The 10 assigned architectures (dry-run + roofline targets).
ASSIGNED: Dict[str, ArchSpec] = {
    "zamba2-2.7b": zamba2_2p7b.SPEC,
    "smollm-360m": smollm_360m.SPEC,
    "phi3-mini-3.8b": phi3_mini_3p8b.SPEC,
    "qwen3-32b": qwen3_32b.SPEC,
    "qwen2-1.5b": qwen2_1p5b.SPEC,
    "rwkv6-7b": rwkv6_7b.SPEC,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.SPEC,
    "deepseek-moe-16b": deepseek_moe_16b.SPEC,
    "musicgen-large": musicgen_large.SPEC,
    "llava-next-mistral-7b": llava_next_mistral_7b.SPEC,
}

# The paper's own models (benchmarks / examples).
PAPER: Dict[str, ArchSpec] = {
    "gpt2-117m": gpt2.SPEC_GPT2_117M,
    "gpt2-1.5b": gpt2.SPEC_GPT2_1P5B,
    "gpt3-125m": gpt2.SPEC_GPT3_125M,
}

ARCHS: Dict[str, ArchSpec] = {**ASSIGNED, **PAPER}


def get_arch(name: str) -> ArchSpec:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(model: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests (one fwd/train step)."""
    kw = dict(
        name=model.name + "-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 * model.n_kv_heads // max(model.n_heads, 1)),
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        max_seq_len=256,
        prefix_tokens=8 if model.frontend == "vision_patches" else 0,
    )
    if model.family == "moe":
        kw.update(n_experts=4, n_shared_experts=min(model.n_shared_experts, 1),
                  top_k=2)
    if model.family == "hybrid":
        kw.update(n_layers=4, attn_every=2, ssm_state=16, ssm_head_dim=16,
                  ssm_chunk=32)
    if model.family == "rwkv":
        kw.update(n_heads=4, n_kv_heads=4, rwkv_head_dim=16, rwkv_lora_rank=8,
                  rwkv_chunk=16)
    return model.replace(**kw)


__all__ = [
    "ARCHS", "ASSIGNED", "PAPER", "ArchSpec", "BatchWarmupConfig", "LM_SHAPES",
    "ModelConfig", "OptimizerConfig", "RegulatorSpec", "ShapeConfig",
    "SLWConfig", "TrainConfig", "get_arch", "reduced",
]
