"""moonshot-v1-16b-a3b (kimi/moonlight) — fine-grained MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert) vocab=163840, MoE 64e top-6 (+2 shared experts).
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
)

SPEC = ArchSpec(
    model=MODEL,
    source="hf:moonshotai/Moonlight-16B-A3B",
    notes="EP: experts sharded over the model axis; long_500k skipped: full attention",
)
