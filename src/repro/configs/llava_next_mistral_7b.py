"""llava-next-mistral-7b — VLM: mistral-7b backbone, anyres patch prefix.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The vision tower is a STUB per the
assignment: input_specs() provide precomputed patch embeddings (B, P, d_model)
that are prepended to the text token embeddings.  SLW warms up only the text
segment (the patch prefix is never truncated).
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="llava-next-mistral-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    frontend="vision_patches",
    prefix_tokens=576,  # one 24x24 anyres base tile
)

SPEC = ArchSpec(
    model=MODEL,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (unverified tier)",
    notes="vision frontend stubbed (precomputed patch embeddings); "
    "long_500k skipped: full attention",
)
