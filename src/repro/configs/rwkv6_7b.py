"""rwkv6-7b (Finch) — attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    norm="layernorm",
    rwkv_backend="kernel",  # Pallas WKV fwd+bwd on TPU (reference off-TPU)
)

SPEC = ArchSpec(
    model=MODEL,
    source="arXiv:2404.05892",
    notes="attention-free: decode state is O(1) per layer; long_500k runs "
    "(the 500K 'cache' is a constant-size WKV state + token-shift buffers)",
)
