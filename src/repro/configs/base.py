"""Config dataclasses for the SLW framework.

Everything is a frozen dataclass so configs are hashable and safe to use as
compile-cache keys (the SLW curriculum compiles one step function per sequence
length bucket).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture definition. One instance per assigned architecture."""

    name: str
    family: str  # dense | moe | rwkv | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    # train/prefill attention backend:
    #   blockwise        – jnp online-softmax scan (the XLA oracle; default)
    #   flash            – Pallas flash-attention kernel (fwd + custom-VJP
    #                      bwd) on TPU; silently falls back to blockwise on
    #                      other backends so presets stay lowerable anywhere
    #   flash_interpret  – force the kernel in interpret mode (CPU
    #                      validation / tests; slow)
    attn_backend: str = "blockwise"
    # decode (serving) attention backend, mirroring ssm/rwkv backends:
    #   reference        – jnp masked softmax over the full cache (default;
    #                      materializes the (B, KV, G, 1, S_max) score row)
    #   kernel           – Pallas split-KV flash-decode kernel on TPU;
    #                      silently falls back to reference off-TPU
    #   kernel_interpret – force the kernel in interpret mode (CPU tests)
    decode_backend: str = "reference"
    rope_theta: float = 10000.0
    pos_emb: str = "rope"  # rope | learned | none
    # block options
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp: str = "swiglu"  # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # global: paper-era single global capacity buffer (pathological under
    # SPMD — see EXPERIMENTS.md §Perf); row_local: per-batch-row ranking,
    # shard-local dispatch arithmetic (production default)
    moe_dispatch: str = "row_local"
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_kernel: int = 4
    # SSD train/prefill backend (mirrors attn_backend):
    #   reference        – pure-jnp chunked scan (the oracle; default)
    #   kernel           – Pallas SSD kernel (fwd + custom-VJP bwd) on TPU;
    #                      silently falls back to reference off-TPU so
    #                      presets stay lowerable anywhere
    #   kernel_interpret – force the kernel in interpret mode (CPU tests)
    ssm_backend: str = "reference"
    # hybrid (zamba2): one *shared* attention+MLP block applied every attn_every
    # SSM layers (shared weights, per-application KV cache)
    attn_every: int = 0
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    rwkv_chunk: int = 64
    # WKV train/prefill backend: reference | kernel | kernel_interpret
    # (same semantics as ssm_backend)
    rwkv_backend: str = "reference"
    # modality frontend stubs (backbone-only per the assignment):
    #   none           – token LM
    #   audio_frames   – input_specs provide precomputed frame embeddings (B,S,D)
    #   vision_patches – tokens plus a fixed image-patch embedding prefix (B,P,D)
    frontend: str = "none"
    prefix_tokens: int = 0
    max_seq_len: int = 532480  # generous default; shapes clamp per cell
    # numerics
    logits_softcap: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports 500K-context decode (SSM/hybrid/linear)."""
        return self.family in ("rwkv", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell. kind selects which step function is lowered."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


# The assigned LM shape set (identical across the 10 architectures).
LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)


@dataclass(frozen=True)
class SLWConfig:
    """Sequence Length Warmup — the paper's contribution (Section 4)."""

    enabled: bool = True
    # pacing: linear (paper default) | root | two_stage (Shortformer baseline)
    #         | variance_gated (beyond-paper) | constant
    pacing: str = "linear"
    start_seq_len: int = 8  # seqlen_s
    end_seq_len: int = 0  # seqlen_e; 0 -> full shape seq_len
    duration_steps: int = 0  # T;  0 -> 2x LR warmup steps
    root_degree: float = 2.0
    # hardware rounding. Paper: 8 (V100 tensor cores). TPU: 128 (lane dim).
    round_multiple: int = 8
    # bucketing bounds the number of XLA recompiles (TPU adaptation; the paper's
    # eager implementation pays no recompile cost).
    max_buckets: int = 32
    # truncate: paper-faithful (drops tail tokens).  repack: beyond-paper —
    # reshape (B, S) -> (B*S//s_t, s_t) so token throughput stays constant.
    mode: str = "truncate"
    # two_stage (Shortformer) parameters
    two_stage_short_len: int = 128
    two_stage_switch_step: int = 0  # 0 -> duration_steps
    # variance_gated parameters: advance only while var_max < gate * trailing
    variance_gate: float = 2.0


@dataclass(frozen=True)
class BatchWarmupConfig:
    """GPT-3 style batch-size warmup (baseline the paper compares against)."""

    enabled: bool = False
    start_batch: int = 16
    warmup_tokens: int = 4_000_000_000


@dataclass(frozen=True)
class RegulatorSpec:
    """One entry in ``TrainConfig.regulators`` — the composable control plane.

    ``kind`` selects the regulator; the remaining fields parameterize the
    kinds that have no legacy config of their own.  Kinds with a legacy
    config (``seqlen`` <- SLWConfig, ``batch_warmup`` <- BatchWarmupConfig,
    ``lr`` <- OptimizerConfig) read their parameters from those configs, so
    one spec entry is just an opt-in switch for them.

    Kinds:
      seqlen            — SLW curriculum (pacing + variance gate), SLWConfig
      batch_warmup      — GPT-3-style linear batch warmup, BatchWarmupConfig
      lr                — token-/step-wise LR schedule, OptimizerConfig
      grad_noise_batch  — adaptive batch sizing from the relative std of the
                          gradient norm (Lau et al.-style telemetry-driven
                          batch schedule)
      var_lr_throttle   — multiplicative LR/grad-clip backoff while the Adam
                          variance max spikes above its trailing mean
                          (Kosson et al.-style warmup-free LR control)
      critical_batch    — B_noise-measured batch warmup (repro.gns): grow
                          the batch while the measured gradient noise scale
                          exceeds ``TrainConfig.gns.headroom`` x the current
                          batch, hold otherwise.  Supersedes the
                          grad_noise_batch grad-norm-EMA proxy; reads its
                          parameters from ``TrainConfig.gns``.
    """

    kind: str
    # grad_noise_batch
    min_batch: int = 0  # 0 -> full_batch // 8
    noise_window: int = 16  # EMA horizon (steps) for grad-norm stats
    noise_target: float = 0.25  # grow batch while rel. grad-norm std exceeds
    growth: float = 1.5  # multiplicative batch growth per trigger
    # var_lr_throttle
    gate: float = 2.0  # throttle when var_max > gate * trailing mean
    floor: float = 0.1  # never scale LR below floor * scheduled
    backoff: float = 0.5  # scale *= backoff on a spike
    recovery: float = 1.2  # scale *= recovery per calm step (capped at 1)


@dataclass(frozen=True)
class GNSConfig:
    """Gradient-noise-scale measurement + pre-spike forecasting (repro.gns).

    ``enabled`` turns on the in-step estimator: the batch is viewed as
    ``shards`` emulated data-parallel replicas inside the jitted train step
    and the per-shard/full-batch gradient-norm pair feeds the unbiased
    ``B_noise = tr(Sigma)/|G|^2`` estimate (McCandlish et al.).  The
    precursor fields parameterize the Molybog et al.-style time-lagged
    autocorrelation of per-leaf gradient *directions* (random-sign sketches
    in a short ring) that forecasts a loss spike before the detector's
    var/norm excursion fires.  The critical-batch fields drive the
    ``critical_batch`` regulator kind (B_noise-measured batch warmup).
    """

    enabled: bool = False
    # emulated per-replica shard count for the small-batch estimator (the
    # realized count is the largest divisor of the step's batch <= this)
    shards: int = 4
    # EMA horizon (steps) for the |G|^2 / tr(Sigma) numerator+denominator
    ema_window: int = 32
    # observations before B_noise is considered warmed up
    warmup_obs: int = 8
    # --- critical_batch regulator -------------------------------------
    min_batch: int = 0        # 0 -> full_batch // 8
    headroom: float = 2.0     # grow batch while B_noise > headroom * batch
    growth: float = 1.5       # multiplicative batch growth per trigger
    # --- pre-spike precursor ------------------------------------------
    precursor_window: int = 12   # sketch ring length (0 disables sketches)
    precursor_dim: int = 16      # random-projection sketch dimension
    precursor_lags: int = 3      # autocorrelation lags averaged per leaf
    precursor_gate: float = 0.8  # absolute per-leaf correlation gate
                                 # (ambient plateau correlation measures
                                 # ~0.75 peak on the bench corpus; real
                                 # excursions reach 0.9+)
    precursor_rise: float = 0.25  # ... and score - trailing > rise.
                                  # Additive on purpose: scores are
                                  # bounded cosines, so a multiplicative
                                  # baseline gate would be unreachable
                                  # for naturally-correlated leaves
    precursor_grace: int = 6     # score observations before firing is legal
    precursor_cooldown_steps: int = 8   # LR cool-down window on an event
    precursor_cooldown_factor: float = 0.5  # LR multiplier during cool-down
    sketch_seed: int = 17        # fixed PRNG seed for the per-leaf signs


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 6e-4
    min_lr: float = 1e-5
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # --- composable optimizer chain (repro.optim.transforms) -------------
    # core preconditioner: adamw (legacy-exact default) | sm3 | shampoo
    # (block-diagonal Kronecker preconditioning grafted onto the Adam
    # update magnitude)
    optimizer: str = "adamw"
    # weight-decay mask: "all" decays every leaf (legacy-exact default);
    # "std" exempts biases/norm gains (1-D-per-layer leaves)
    decay_mask: str = "all"
    # adaptive gradient clipping (Brock et al.): per-leaf grad/param-norm
    # ratio clip, composing after the global clip (grad_clip=0 replaces it;
    # the global-norm telemetry is still measured).  0 disables.
    agc_clip: float = 0.0
    agc_eps: float = 1e-3
    # per-leaf LR scaling: ((label_substring, factor), ...) — factors
    # multiply the update of every param leaf whose label matches
    lr_scales: Tuple[Tuple[str, float], ...] = ()
    # telemetry: "scalar" (legacy globals only — one reduction pass) |
    # "per_leaf" (adds fixed-size named vectors: var_max / grad-norm /
    # update-norm / param-norm per labeled leaf, for per-layer regulators)
    telemetry_level: str = "scalar"
    # sm3 heavy-ball momentum on the preconditioned update (0 disables)
    sm3_momentum: float = 0.9
    # shampoo: max block side preconditioned (bigger leaves fall back to
    # Adam), eigh refresh cadence, and the statistics/eigenvalue ridge
    shampoo_block_size: int = 128
    shampoo_interval: int = 10
    shampoo_eps: float = 1e-6
    # token_wise cosine decay (paper Appendix A.2) or step_wise (baseline GPT-2)
    schedule: str = "token_cosine"  # token_cosine | step_cosine | constant
    warmup_steps: int = 0
    warmup_tokens: int = 0
    total_steps: int = 0
    total_tokens: int = 0
    # 1-bit-Adam style compressed gradient all-reduce (beyond-paper extension)
    grad_compression: bool = False
    compression_warmup_steps: int = 0


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    slw: SLWConfig = field(default_factory=SLWConfig)
    batch_warmup: BatchWarmupConfig = field(default_factory=BatchWarmupConfig)
    # Composable control plane (core.regulators).  Empty tuple = derive from
    # the legacy configs above: seqlen if slw.enabled, batch_warmup if
    # batch_warmup.enabled, and always the LR schedule — so the paper's
    # *joint* recipe (SLW + 8x batch + 4x/40x LR warmup) is just "enable
    # both".  A non-empty tuple overrides the derivation entirely.
    regulators: Tuple[RegulatorSpec, ...] = ()
    # gradient-noise-scale measurement + pre-spike forecasting (repro.gns);
    # disabled by default — the train step's trace is untouched unless on
    gns: GNSConfig = field(default_factory=GNSConfig)
    seq_len: int = 1024
    global_batch: int = 512
    seed: int = 1234
    # remat: none | full | dots  (activation checkpointing policy for the layer scan)
    remat: str = "full"
    # sharding rule set: "baseline" (paper-era DP+TP) | "fsdp" (optimized)
    sharding_rules: str = "fsdp"
    # cast params to bf16 *before* they are consumed (so FSDP all-gathers move
    # bf16 bytes, not fp32) — perf lever, see EXPERIMENTS.md §Perf
    cast_params_before_use: bool = True
    eval_interval: int = 100
    log_interval: int = 10
    checkpoint_interval: int = 500
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class ArchSpec:
    """An assigned architecture: model + its shape cells + dry-run notes."""

    model: ModelConfig
    shapes: Tuple[ShapeConfig, ...] = LM_SHAPES
    source: str = ""
    notes: str = ""

    def shape(self, name: str) -> ShapeConfig:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.model.name} has no shape {name!r}")

    def runnable_shapes(self) -> Tuple[ShapeConfig, ...]:
        """Cells actually lowered. long_500k only for sub-quadratic archs."""
        out = []
        for s in self.shapes:
            if s.name == "long_500k" and not self.model.sub_quadratic:
                continue  # documented skip: full-attention arch at 500K context
            out.append(s)
        return tuple(out)
