"""phi3-mini-3.8b — dense, RoPE + SwiGLU + GQA.

[arXiv:2404.14219; unverified]  32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    # stays on blockwise: head_dim = 3072/32 = 96 is not a multiple of the
    # 128-lane TPU tile, so the flash kernel would pad every block — switch
    # after the kernel grows a head_dim-padding path (see ROADMAP)
)

SPEC = ArchSpec(
    model=MODEL,
    source="arXiv:2404.14219 (unverified tier)",
    notes="long_500k skipped: pure full attention (quadratic)",
)
