"""zamba2-2.7b — hybrid Mamba-2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  The shared attention+MLP block (one set of weights) is applied
every `attn_every` SSM layers, each application with its own KV cache.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,  # 54 / 6 = 9 shared-block applications
    ssm_backend="kernel",  # Pallas SSD fwd+bwd on TPU (reference off-TPU)
    decode_backend="kernel",  # split-KV flash-decode for the shared attn block
)

SPEC = ArchSpec(
    model=MODEL,
    source="arXiv:2411.15242 (hf-verified)",
    notes="hybrid: long_500k runs (sub-quadratic backbone; shared-attn KV caches "
    "are sequence-sharded over the data axis)",
)
