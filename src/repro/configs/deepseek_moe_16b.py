"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts, top-6.

[arXiv:2401.06066; hf]  28L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert)
vocab=102400.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
)

SPEC = ArchSpec(
    model=MODEL,
    source="arXiv:2401.06066",
    notes="EP: experts sharded over the model axis; long_500k skipped: full attention",
)
