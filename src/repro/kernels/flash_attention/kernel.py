"""Pallas TPU flash-attention forward (causal, GQA).

TPU-native tiling: grid (batch*heads, q_blocks, kv_blocks) with the kv axis
minor — TPU executes the grid sequentially, so the online-softmax carry
(m, l, acc) lives in VMEM scratch across kv iterations of one (bh, q) cell.
Each grid cell streams one (block_k, head_dim) K/V tile from HBM into VMEM
and one (block_q, head_dim) Q tile; compute is two MXU matmuls per tile.
Causal block-skipping: fully-masked kv blocks are skipped with pl.when
(fetches still occur; the flops are skipped — the lever that removes the 2x
causal waste the pure-XLA path pays).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                block_q: int, block_k: int, scale: float, causal: bool,
                kv_blocks: int, valid_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        if causal:
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        if valid_len < kv_blocks * block_k:  # padded tail keys
            s = jnp.where(k_pos < valid_len, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip kv blocks strictly above the diagonal
        @pl.when(ki * block_k <= qi * block_q + block_q - 1)
        def _run():
            _body()
    else:
        _body()

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128, valid_len: int = 0,
                        interpret: bool = False) -> jax.Array:
    """q: (BH, S, D); k, v: (BH, S, D) (GQA repeat handled by ops.py).
    Returns (BH, S, D). `valid_len` masks padded tail keys (0 = none)."""
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    q_blocks = s // block_q
    kv_blocks = s // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _fwd_kernel, block_q=block_q, block_k=block_k, scale=scale,
        causal=causal, kv_blocks=kv_blocks, valid_len=valid_len or s)

    return pl.pallas_call(
        kernel,
        grid=(bh, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),  # m: running row max
            _vmem((block_q, 1), jnp.float32),  # l: running row sum
            _vmem((block_q, d), jnp.float32),  # acc: weighted values
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
