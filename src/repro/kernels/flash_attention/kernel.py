"""Pallas TPU flash attention: forward *and* backward (causal, GQA-expanded).

Forward — grid (batch*heads, q_blocks, kv_blocks) with the kv axis minor; the
TPU executes the grid sequentially, so the online-softmax carry (m, l, acc)
lives in VMEM scratch across kv iterations of one (bh, q) cell.  Besides the
output block the kernel emits the per-row logsumexp ``lse = m + log(l)`` —
the residual that lets the backward recompute softmax rows without a second
online pass.

Causal grid pruning — fully-masked kv blocks (strictly above the diagonal)
are pruned at the *index map*: the kv block index is clamped to the last
in-diagonal block, so every pruned grid step maps to the block already
resident in VMEM and Pallas elides the HBM fetch (the pipeline only issues a
copy when the mapped index changes).  ``pl.when`` still skips the flops.
Previously only the flops were skipped and the fetches still occurred.

Backward — FlashAttention-2 style split into three kernels, all reusing the
same causal block-skipping and ``valid_len`` tail masking as the forward:

* ``_bwd_preprocess_kernel``: ``delta = rowsum(dO * O)`` per row — the
  softmax-Jacobian correction term, grid (bh, q_blocks).
* ``_bwd_dq_kernel``: grid (bh, q_blocks, kv_blocks), kv minor; recomputes
  ``p = exp(s - lse)`` per tile and accumulates
  ``dq += (p * (dO @ V^T - delta)) @ K * scale`` in VMEM scratch.
* ``_bwd_dkv_kernel``: grid (bh, kv_blocks, q_blocks), q minor; accumulates
  ``dv += p^T @ dO`` and ``dk += (p * (dO @ V^T - delta))^T @ Q * scale``.
  Causal pruning mirrors the forward: the q index map clamps to the first
  in-diagonal q block for this kv block.

All accumulation is fp32 in scratch; outputs are cast to the input dtype at
the final grid step of each (bh, major) cell.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _last_kv_block(qi, block_q: int, block_k: int):
    """Last kv block intersecting the causal diagonal for q block `qi`."""
    return (qi * block_q + block_q - 1) // block_k


def _first_q_block(ki, block_q: int, block_k: int):
    """First q block intersecting the causal diagonal for kv block `ki`."""
    return (ki * block_k) // block_q


def _kv_index_map(block_q: int, block_k: int, causal: bool):
    """K/V index map for (bh, q_blocks, kv_blocks) grids.  Causal pruning
    clamps above-diagonal steps onto the already-resident block so Pallas
    elides the fetch (shared by fwd and the dQ kernel)."""
    if causal:
        return lambda b, qi, ki: (
            b, jnp.minimum(ki, _last_kv_block(qi, block_q, block_k)), 0)
    return lambda b, qi, ki: (b, ki, 0)


def _q_index_maps(block_q: int, block_k: int, causal: bool):
    """(tensor, per-row) Q-side index maps for the (bh, kv_blocks, q_blocks)
    dK/dV grid — the mirror-image clamp onto the first in-diagonal q block."""
    if causal:
        def qi_of(ki, qi):
            return jnp.maximum(qi, _first_q_block(ki, block_q, block_k))
        return (lambda b, ki, qi: (b, qi_of(ki, qi), 0),
                lambda b, ki, qi: (b, qi_of(ki, qi)))
    return (lambda b, ki, qi: (b, qi, 0), lambda b, ki, qi: (b, qi))


def _masked_scores(q, k, qi, ki, *, block_q, block_k, scale, causal,
                   valid_len, kv_len):
    """(block_q, block_k) fp32 scores with causal + padded-tail masking."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    if causal:
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
    if valid_len < kv_len:  # padded tail keys
        s = jnp.where(k_pos < valid_len, s, NEG_INF)
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                block_q: int, block_k: int, scale: float, causal: bool,
                kv_blocks: int, valid_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = _masked_scores(q, k, qi, ki, block_q=block_q, block_k=block_k,
                           scale=scale, causal=causal, valid_len=valid_len,
                           kv_len=kv_blocks * block_k)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip kv blocks strictly above the diagonal (their fetch is elided
        # by the clamped index map — see module docstring)
        @pl.when(ki <= _last_kv_block(qi, block_q, block_k))
        def _run():
            _body()
    else:
        _body()

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(l))[:, 0]


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128, valid_len: int = 0,
                        interpret: bool = False
                        ) -> tuple[jax.Array, jax.Array]:
    """q, k, v: (BH, S, D) (GQA repeat handled by ops.py).

    Returns (o (BH, S, D), lse (BH, S) fp32).  `valid_len` masks padded tail
    keys (0 = none).
    """
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    q_blocks = s // block_q
    kv_blocks = s // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _fwd_kernel, block_q=block_q, block_k=block_k, scale=scale,
        causal=causal, kv_blocks=kv_blocks, valid_len=valid_len or s)

    kv_map = _kv_index_map(block_q, block_k, causal)

    return pl.pallas_call(
        kernel,
        grid=(bh, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),  # m: running row max
            _vmem((block_q, 1), jnp.float32),  # l: running row sum
            _vmem((block_q, d), jnp.float32),  # acc: weighted values
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _bwd_preprocess_kernel(o_ref, do_ref, delta_ref):
    o = o_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    delta_ref[0] = jnp.sum(o * do, axis=-1)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   dq_scr, *, block_q: int, block_k: int, scale: float,
                   causal: bool, kv_blocks: int, valid_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]      # (block_q, 1)
        delta = delta_ref[0][:, None]  # (block_q, 1)
        s = _masked_scores(q, k, qi, ki, block_q=block_q, block_k=block_k,
                           scale=scale, causal=causal, valid_len=valid_len,
                           kv_len=kv_blocks * block_k)
        p = jnp.exp(s - lse)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal:
        @pl.when(ki <= _last_kv_block(qi, block_q, block_k))
        def _run():
            _body()
    else:
        _body()

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref,
                    dv_ref, dk_scr, dv_scr, *, block_q: int, block_k: int,
                    scale: float, causal: bool, q_blocks: int,
                    kv_blocks: int, valid_len: int):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]
        delta = delta_ref[0][:, None]
        s = _masked_scores(q, k, qi, ki, block_q=block_q, block_k=block_k,
                           scale=scale, causal=causal, valid_len=valid_len,
                           kv_len=kv_blocks * block_k)
        p = jnp.exp(s - lse)  # (block_q, block_k)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal:
        @pl.when(qi >= _first_q_block(ki, block_q, block_k))
        def _run():
            _body()
    else:
        _body()

    @pl.when(qi == q_blocks - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        o: jax.Array, lse: jax.Array, do: jax.Array, *,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128, valid_len: int = 0,
                        interpret: bool = False
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Backward pass at the flattened (BH, S, D) layout.

    q, k, v, o, do: (BH, S, D); lse: (BH, S) fp32 from the forward.
    Returns (dq, dk, dv) with the input dtypes.
    """
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    q_blocks = s // block_q
    kv_blocks = s // block_k
    scale = 1.0 / math.sqrt(d)
    valid_len = valid_len or s

    # delta = rowsum(dO * O): the softmax-Jacobian correction term
    delta = pl.pallas_call(
        _bwd_preprocess_kernel,
        grid=(bh, q_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi: (b, qi, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, qi: (b, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda b, qi: (b, qi)),
        out_shape=jax.ShapeDtypeStruct((bh, s), jnp.float32),
        interpret=interpret,
    )(o, do)

    # dQ: kv minor, online accumulation into VMEM scratch
    kv_map = _kv_index_map(block_q, block_k, causal)
    q_map3 = lambda b, qi, ki: (b, qi, 0)
    q_row3 = lambda b, qi, ki: (b, qi)
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, causal=causal, kv_blocks=kv_blocks,
                          valid_len=valid_len),
        grid=(bh, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map3),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_q, d), q_map3),
            pl.BlockSpec((1, block_q), q_row3),
            pl.BlockSpec((1, block_q), q_row3),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map3),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[_vmem((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dK/dV: q minor, two accumulators in VMEM scratch
    q_clamp, q_row_clamp = _q_index_maps(block_q, block_k, causal)
    kv_map2 = lambda b, ki, qi: (b, ki, 0)
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, causal=causal, q_blocks=q_blocks,
                          kv_blocks=kv_blocks, valid_len=valid_len),
        grid=(bh, kv_blocks, q_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_clamp),
            pl.BlockSpec((1, block_k, d), kv_map2),
            pl.BlockSpec((1, block_k, d), kv_map2),
            pl.BlockSpec((1, block_q, d), q_clamp),
            pl.BlockSpec((1, block_q), q_row_clamp),
            pl.BlockSpec((1, block_q), q_row_clamp),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), kv_map2),
            pl.BlockSpec((1, block_k, d), kv_map2),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), k.dtype),
            jax.ShapeDtypeStruct((bh, s, d), v.dtype),
        ],
        scratch_shapes=[
            _vmem((block_k, d), jnp.float32),
            _vmem((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
