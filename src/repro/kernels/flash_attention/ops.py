"""Differentiable jit'd public wrapper for the flash-attention kernels.

``flash_attention`` is a ``jax.custom_vjp`` at the model-facing layout
(q: (B, S, H, D); k, v: (B, S, KV, D) with H = KV * G):

* forward: expands KV heads to Q heads (GQA), flattens to (B*H, S, D), pads
  the sequence to a block multiple (padded tail keys masked via
  ``valid_len``), and runs the fused Pallas forward — saving the
  ``(q, k, v, o, lse)`` residuals with k/v kept *unexpanded*, so the k/v
  share of residual memory scales with KV heads, not Q heads (o and lse
  are per-Q-head by nature).
* backward: re-expands/pads, runs the three Pallas backward kernels
  (preprocess delta, dQ, dK/dV — see kernel.py), then accumulates the
  per-Q-head dK/dV back to the (B, S, KV, D) layout by summing over each
  KV head's group of G query heads.

Off-TPU the kernels run in interpret mode (this container is CPU:
``interpret=True`` executes the kernel body in Python for validation);
``jax.grad`` through ``flash_attention`` therefore works on every backend.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.kernel import (flash_attention_bwd,
                                                  flash_attention_fwd)


def _flatten(x: jax.Array, g: int, pad: int) -> jax.Array:
    """(B, S, Hx, D) -> (B*Hx*g, S+pad, D): GQA-expand, head-major, pad."""
    b, s, h, d = x.shape
    if g > 1:
        x = jnp.repeat(x, g, axis=2)
    x = x.transpose(0, 2, 1, 3).reshape(b * h * g, s, d)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


def _unflatten(x: jax.Array, b: int, s: int) -> jax.Array:
    """(B*H, S_pad, D) -> (B, S, H, D): unpad, head-minor."""
    bh, s_pad, d = x.shape
    return x[:, :s, :].reshape(b, bh // b, s, d).transpose(0, 2, 1, 3)


def _prep(q, k, v, block_q, block_k):
    """Shared fwd/bwd prologue: resolve blocks + padding, flatten q/k/v.

    Returns (g, bq, bk, pad, qf, kf, vf) — the one definition of the layout
    the residuals are saved in and the backward re-derives.
    """
    s = q.shape[1]
    g = q.shape[2] // k.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, s)
    # the padded length must be divisible by *both* blocks, not just the
    # larger one (e.g. s=96, bq=64, bk=96 needs lcm padding, not zero)
    pad = (-s) % math.lcm(bq, bk)
    return (g, bq, bk, pad, _flatten(q, 1, pad), _flatten(k, g, pad),
            _flatten(v, g, pad))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, block_q, block_k, interpret):
    out, _ = _flash_fwd(q, k, v, causal, block_q, block_k, interpret)
    return out


def _flash_fwd(q, k, v, causal, block_q, block_k, interpret):
    b, s = q.shape[:2]
    g, bq, bk, pad, qf, kf, vf = _prep(q, k, v, block_q, block_k)
    of, lse = flash_attention_fwd(qf, kf, vf, causal=causal, block_q=bq,
                                  block_k=bk, valid_len=s,
                                  interpret=interpret)
    out = _unflatten(of, b, s)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, block_q, block_k, interpret, res, do):
    q, k, v, out, lse = res
    b, s, _, d = q.shape
    kv = k.shape[2]
    g, bq, bk, pad, qf, kf, vf = _prep(q, k, v, block_q, block_k)
    of = _flatten(out, 1, pad)
    dof = _flatten(do, 1, pad)
    dqf, dkf, dvf = flash_attention_bwd(
        qf, kf, vf, of, lse, dof, causal=causal, block_q=bq, block_k=bk,
        valid_len=s, interpret=interpret)
    dq = _unflatten(dqf, b, s)
    # accumulate per-Q-head dK/dV over each KV head's group of G query
    # heads — in fp32, so bf16 inputs don't compound rounding over G adds
    dk = (_unflatten(dkf, b, s).astype(jnp.float32)
          .reshape(b, s, kv, g, d).sum(axis=3))
    dv = (_unflatten(dvf, b, s).astype(jnp.float32)
          .reshape(b, s, kv, g, d).sum(axis=3))
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, KV, D) with H = KV * G. Returns like q.

    Differentiable end-to-end: ``jax.grad`` routes through the Pallas
    backward kernels via the custom VJP above.
    """
    return _flash(q, k, v, causal, block_q, block_k,
                  resolve_interpret(interpret))
