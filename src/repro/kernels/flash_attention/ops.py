"""jit'd public wrapper for the flash-attention kernel.

Handles the model-facing layout (B, S, H, D) + GQA head grouping + padding
to block multiples, and falls back to interpret mode off-TPU (this container
is CPU: interpret=True executes the kernel body in Python for validation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, KV, D) with H = KV * G. Returns like q."""
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    # expand KV heads to match Q heads (GQA); layout to (B*H, S, D)
    kx = jnp.repeat(k, g, axis=2)
    vx = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = kx.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = vx.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    bq = min(block_q, s)
    bk = min(block_k, s)
    pad = (-s) % max(bq, bk)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = flash_attention_fwd(qf, kf, vf, causal=causal, block_q=bq,
                              block_k=bk, valid_len=s, interpret=interpret)
    if pad:
        out = out[:, :s, :]
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
