"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q, k, v: (BH, S, D). Dense softmax attention in fp32."""
    bh, s, d = q.shape
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_reference_gqa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True) -> jax.Array:
    """Model-layout oracle: q (B, S, H, D); k, v (B, S, KV, D), H = KV * G.

    The one place the GQA expand/flatten layout is defined alongside the
    dense reference — tests and benchmarks diff kernel outputs/grads
    against this instead of hand-rolling the transpose each time.
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = jnp.repeat(k, g, 2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = jnp.repeat(v, g, 2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    o = attention_reference(qf, kf, vf, causal=causal)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)
