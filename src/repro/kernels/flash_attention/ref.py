"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q, k, v: (BH, S, D). Dense softmax attention in fp32."""
    bh, s, d = q.shape
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
