"""Pallas TPU kernels for the compute hot spots.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper, interpret-mode fallback off-TPU), ref.py (pure-jnp oracle).

* flash_attention — causal GQA flash attention (the B*L^2*H term SLW
  modulates; prunes above-diagonal block fetches the XLA path pays for).
  Differentiable: custom_vjp over fused fwd (o + logsumexp) and three
  Pallas bwd kernels (delta preprocess, dQ, dK/dV) — selected on the
  training hot path via ``ModelConfig.attn_backend = "flash"``.
* ssd             — Mamba-2 chunked SSD scan (zamba2 backbone, long_500k).
  Differentiable: custom_vjp over a carry-emitting fwd and a fused
  reverse-chunk-scan bwd kernel — selected via
  ``ModelConfig.ssm_backend = "kernel"``.
* rwkv6           — chunked WKV with data-dependent per-channel decay;
  likewise differentiable (``ModelConfig.rwkv_backend = "kernel"``).
* flash_decode    — split-KV decode attention on the serving hot path: one
  query row per slot against a KV-blocked cache with per-slot valid-length
  masking; emits (m, l, o) partials so the sharded flash-decoding merge
  consumes the same algebra.  Inference-only (no backward); selected via
  ``ModelConfig.decode_backend = "kernel"``.

The shared backend/interpret resolution lives here so the three ops.py
wrappers agree on one rule: kernels compile only on real TPU; everywhere
else they run in interpret mode (Python evaluation of the kernel body —
slow, but it makes ``jax.grad`` through every kernel testable on CPU).
"""
import jax


def on_tpu() -> bool:
    """True iff the default JAX backend is a real TPU."""
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: "bool | None") -> bool:
    """Resolve an ``interpret: bool | None`` kernel argument.

    ``None`` (the default everywhere) means "compiled on TPU, interpret
    mode elsewhere"; an explicit ``True``/``False`` is passed through
    untouched (tests force ``True``; TPU perf runs may force ``False``).
    """
    return not on_tpu() if interpret is None else interpret


def resolve_backend(backend: str, field: str) -> "tuple[bool, bool]":
    """Map a model-config kernel-backend value to (use_kernel, interpret).

    One rule for ``ssm_backend`` and ``rwkv_backend`` (``field`` only names
    the offender in the error): "kernel" compiles on TPU and falls back to
    the jnp reference elsewhere; "kernel_interpret" forces interpret mode
    (CPU validation); "reference" never touches the kernel.
    """
    if backend not in ("reference", "kernel", "kernel_interpret"):
        raise ValueError(f"unknown {field} {backend!r}")
    if backend == "kernel_interpret":
        return True, True
    return backend == "kernel" and on_tpu(), False


def chunk_padding(s: int, chunk: int) -> "tuple[int, int]":
    """Clamp ``chunk`` to the sequence length and return (chunk, pad).

    The shared uneven-tail contract of the ssd/wkv6 wrappers: ``pad``
    zero-extends the sequence to the next chunk multiple (zero inputs with
    zero log-decay are state-safe in both recurrences), and the wrapper
    slices the padded rows back off the output.
    """
    chunk = min(chunk, s)
    return chunk, (-s) % chunk


from repro.kernels.flash_attention.ops import flash_attention  # noqa: E402,F401
from repro.kernels.flash_decode.ops import (flash_decode,  # noqa: E402,F401
                                            flash_decode_paged,
                                            flash_decode_paged_partials,
                                            flash_decode_partials)
from repro.kernels.rwkv6.ops import wkv6  # noqa: E402,F401
from repro.kernels.ssd.ops import ssd  # noqa: E402,F401
