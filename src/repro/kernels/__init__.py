"""Pallas TPU kernels for the compute hot spots.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper, interpret-mode fallback off-TPU), ref.py (pure-jnp oracle).

* flash_attention — causal GQA flash attention (the B*L^2*H term SLW
  modulates; prunes above-diagonal block fetches the XLA path pays for).
  Differentiable: custom_vjp over fused fwd (o + logsumexp) and three
  Pallas bwd kernels (delta preprocess, dQ, dK/dV) — selected on the
  training hot path via ``ModelConfig.attn_backend = "flash"``.
* ssd             — Mamba-2 chunked SSD scan (zamba2 backbone, long_500k);
  forward-only (bwd falls back to XLA AD of the reference — see ROADMAP)
* rwkv6           — chunked WKV with data-dependent per-channel decay;
  forward-only likewise
"""
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.rwkv6.ops import wkv6  # noqa: F401
from repro.kernels.ssd.ops import ssd  # noqa: F401
