"""Oracle for the SSD kernel: the model's own chunked-scan reference
(layout-adapted)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.mamba2 import ssd_reference


def ssd_fwd_reference(x, dt, a_coef, b_in, c_in, *, chunk: int = 128):
    """Same signature/layout as kernel.ssd_fwd: x (B,H,S,P), dt (B,H,S)."""
    xs = x.transpose(0, 2, 1, 3)   # (B,S,H,P)
    dts = dt.transpose(0, 2, 1)    # (B,S,H)
    y, state = ssd_reference(xs, dts, a_coef, b_in, c_in, chunk)
    return y.transpose(0, 2, 1, 3), state
