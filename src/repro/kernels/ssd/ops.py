"""jit'd wrapper for the SSD kernel (interpret fallback off-TPU)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.kernel import ssd_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_coef, b_in, c_in, *, chunk: int = 128,
        interpret: bool | None = None):
    """x: (B, H, S, P); dt: (B, H, S); a_coef: (H,); b_in/c_in: (B, S, N)."""
    if interpret is None:
        interpret = not _on_tpu()
    return ssd_fwd(x, dt, a_coef, b_in, c_in, chunk=chunk,
                   interpret=interpret)
