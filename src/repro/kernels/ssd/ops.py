"""Differentiable jit'd public wrapper for the SSD kernels.

``ssd`` is a ``jax.custom_vjp`` over the Pallas forward/backward pair in
kernel.py:

* forward: pads the sequence to a chunk multiple when needed (dt = 0 pad
  steps decay by exp(0) = 1 and inject nothing, so the final state is
  unaffected), runs the carry-emitting forward, and saves
  ``(x, dt, a_coef, b_in, c_in, carries)`` as residuals.  ``carries`` is
  the (B, H, nc, N, P) tensor of states *entering* each chunk — the
  chunk-compressed residual layout: everything quadratic-in-chunk the
  backward needs (scores, decay tile, cumulative log-decays) is recomputed
  per chunk from the inputs, so nothing O(S^2) or O(S, N, P) beyond the
  nc inter-chunk carries is ever materialized.
* backward: one reverse-chunk-scan Pallas kernel carrying the (N, P)
  state cotangent in VMEM (seeded with the final-state cotangent), then
  two cheap jnp reductions outside the kernel: dB/dC are emitted per-head
  and summed over H here (b_in/c_in are head-shared — the same
  accumulate-outside idiom as flash attention's GQA dK/dV), and the
  per-head scalar dA = sum_{b,s} dt * dlog contracts the kernel's
  log-decay cotangent.

Off-TPU the kernels run in interpret mode (see ``resolve_interpret``), so
``jax.grad`` through ``ssd`` works on every backend; padding/slicing lives
*outside* the custom_vjp, so AD handles the uneven-tail case for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import chunk_padding, resolve_interpret
from repro.kernels.ssd.kernel import ssd_bwd, ssd_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, a_coef, b_in, c_in, chunk, interpret):
    y, state = ssd_fwd(x, dt, a_coef, b_in, c_in, chunk=chunk,
                       interpret=interpret)
    return y, state


def _ssd_fwd_rule(x, dt, a_coef, b_in, c_in, chunk, interpret):
    y, state, carries = ssd_fwd(x, dt, a_coef, b_in, c_in, chunk=chunk,
                                interpret=interpret, return_carries=True)
    return (y, state), (x, dt, a_coef, b_in, c_in, carries)


def _ssd_bwd_rule(chunk, interpret, res, cts):
    x, dt, a_coef, b_in, c_in, carries = res
    dy, dstate = cts
    dx, ddt, dlog, db_h, dc_h = ssd_bwd(
        x, dt, a_coef, b_in, c_in, carries, dy.astype(jnp.float32),
        dstate.astype(jnp.float32), chunk=chunk, interpret=interpret)
    da = jnp.einsum("bhs,bhs->h", dt.astype(jnp.float32), dlog)
    return (dx.astype(x.dtype), ddt.astype(dt.dtype), da.astype(a_coef.dtype),
            db_h.sum(axis=1).astype(b_in.dtype),
            dc_h.sum(axis=1).astype(c_in.dtype))


_ssd.defvjp(_ssd_fwd_rule, _ssd_bwd_rule)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_coef, b_in, c_in, *, chunk: int = 128,
        interpret: bool | None = None):
    """x: (B, H, S, P); dt: (B, H, S); a_coef: (H,); b_in/c_in: (B, S, N).
    Returns (y (B,H,S,P), final_state (B,H,N,P)).

    Differentiable end-to-end: ``jax.grad`` routes through the fused Pallas
    reverse-scan kernel via the custom VJP above.  Sequence lengths that
    are not chunk multiples are zero-padded (state-safe) and sliced back.
    """
    interpret = resolve_interpret(interpret)
    s = x.shape[2]
    chunk, pad = chunk_padding(s, chunk)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    y, state = _ssd(x, dt, a_coef, b_in, c_in, chunk, interpret)
    return (y[:, :, :s] if pad else y), state
