"""Pallas TPU kernels for the Mamba-2 SSD chunked scan — forward and backward.

Forward — grid (B, H, n_chunks) with the chunk axis minor: TPU's sequential
grid execution carries the (N, P) inter-chunk state in VMEM scratch, so the
recurrence never round-trips HBM between chunks (the GPU implementation's
equivalent trick is a separate state-passing kernel; on TPU the sequential
grid makes it one kernel).  Per chunk the intra term is two MXU matmuls over
a (Q, Q) decay-masked score tile.  With ``return_carries=True`` the kernel
additionally emits the state *entering* each chunk — a (B, H, nc, N, P)
tensor, the chunk-compressed residual the backward recomputes from (nc = S/Q
blocks of the (N, P) state instead of any (S, S) attention-like tensor).

Backward — same grid shape with the chunk axis *reversed* via the index
maps, so one kernel runs the reverse scan: the (N, P) cotangent of the
running state is carried in VMEM scratch from the last chunk to the first,
initialized with the cotangent of the final-state output.  Per chunk it
recomputes the forward's intra-chunk tile (scores, decay, cumulative
log-decays) from the saved inputs + carry, then emits all five input
cotangents.  dB/dC are written per-head (the ops.py wrapper sums over H,
mirroring the flash-attention GQA accumulation) and dA arrives as the
log-decay cotangent ``dlog`` (dA = sum_{b,s} dt * dlog per head, reduced in
ops.py) so the kernel needs no cross-chunk scalar accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, *refs, chunk: int,
                n_chunks: int, with_carries: bool):
    if with_carries:
        y_ref, state_out_ref, carry_ref, state_scr = refs
    else:
        (y_ref, state_out_ref, state_scr), carry_ref = refs, None
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (1, Q)
    a = a_ref[0]                              # scalar negative decay coef
    bq = b_ref[0].astype(jnp.float32)        # (Q, N)
    cq = c_ref[0].astype(jnp.float32)        # (Q, N)

    log_decay = dt[0] * a                    # (Q,)
    cum = jnp.cumsum(log_decay)              # (Q,) inclusive
    x_dt = x * dt[0][:, None]                # (Q, P)

    # intra-chunk: (C B^T (.) decay) @ x_dt
    scores = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    gap = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(iota_i >= iota_j, gap, NEG_INF))
    y = jax.lax.dot_general(scores * decay, x_dt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: C_i exp(cum_i) @ state_prev
    state = state_scr[...]                   # (N, P)
    if carry_ref is not None:
        carry_ref[0, 0, 0] = state           # residual: state entering chunk
    c_scaled = cq * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(c_scaled, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: exp(cum_last) * state + sum_j exp(cum_last - cum_j) B_j x_dt_j
    b_scaled = bq * jnp.exp(cum[-1] - cum)[:, None]  # (Q, N)
    new_state = (jnp.exp(cum[-1]) * state
                 + jax.lax.dot_general(b_scaled, x_dt,
                                       (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))
    state_scr[...] = new_state
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        state_out_ref[0, 0] = new_state


def ssd_fwd(x: jax.Array, dt: jax.Array, a_coef: jax.Array, b_in: jax.Array,
            c_in: jax.Array, *, chunk: int = 128,
            interpret: bool = False, return_carries: bool = False):
    """x: (B, H, S, P); dt: (B, H, S); a_coef: (H,); b_in/c_in: (B, S, N).
    Returns (y (B,H,S,P), final_state (B,H,N,P)); with ``return_carries``
    also the (B,H,nc,N,P) per-chunk entry states (the bwd residual)."""
    b, h, s, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc,
                               with_carries=return_carries)
    dt3 = dt.reshape(b, h, 1, s)  # keep last-two-dims tiling friendly
    out_specs = [
        pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
        pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
    ]
    if return_carries:
        out_specs.append(pl.BlockSpec((1, 1, 1, n, p),
                                      lambda bi, hi, ci: (bi, hi, ci, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, h, nc, n, p), jnp.float32))
    outs = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, 0, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[_vmem((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt3, a_coef.astype(jnp.float32), b_in, c_in)
    return tuple(outs)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _ssd_bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, carry_ref, dy_ref,
                    dstate_ref, dx_ref, ddt_ref, dlog_ref, db_ref, dc_ref,
                    g_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)  # reversed: index maps serve chunk nc-1-ci

    @pl.when(ci == 0)
    def _init():  # cotangent of the final-state output seeds the carry
        g_scr[...] = dstate_ref[0, 0]

    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0][0].astype(jnp.float32)  # (Q,)
    a = a_ref[0]
    bq = b_ref[0].astype(jnp.float32)         # (Q, N)
    cq = c_ref[0].astype(jnp.float32)         # (Q, N)
    state = carry_ref[0, 0, 0]                # (N, P) state entering chunk
    dy = dy_ref[0, 0].astype(jnp.float32)     # (Q, P)
    g = g_scr[...]                            # (N, P) d(chunk-final state)

    # recompute the forward's intra-chunk tile
    log_decay = dt * a
    cum = jnp.cumsum(log_decay)               # (Q,) inclusive
    x_dt = x * dt[:, None]
    e = jnp.exp(cum)                          # (Q,)  carried-state decay
    f = jnp.exp(cum[-1] - cum)                # (Q,)  decay-to-chunk-end
    alpha = e[-1]
    scores = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    gap = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(iota_i >= iota_j, gap, NEG_INF))

    def mm(lhs, rhs, dims):
        return jax.lax.dot_general(lhs, rhs, (dims, ((), ())),
                                   preferred_element_type=jnp.float32)

    # d(x * dt): intra term (M^T dy) + state-update term (f B) G
    m = scores * decay                        # (Q, Q) causal mixing weights
    dxdt = mm(m, dy, ((0,), (0,))) + mm(f[:, None] * bq, g, ((1,), (0,)))

    # w_ij = decay_ij * (dy_i . x_dt_j) — shared by dB, dC and the decay grad
    dyx = mm(dy, x_dt, ((1,), (1,)))          # (Q, Q)
    w = decay * dyx
    dc = mm(w, bq, ((1,), (0,))) + e[:, None] * mm(dy, state, ((1,), (1,)))
    db = mm(w, cq, ((0,), (0,))) + f[:, None] * mm(x_dt, g, ((1,), (1,)))

    # cotangent of the inclusive cumulative log-decay, term by term:
    #   t = scores (.) w            — the pairwise exp(cum_i - cum_j) factors
    #   t2 = e_i (C_i S_prev).dy_i  — the carried-state decay
    #   u = f_j (B_j G).x_dt_j      — the decay-to-end factors (state update)
    #   alpha <S_prev, G>           — the carried-state factor (last row only)
    t = scores * w
    u = f * jnp.sum(mm(bq, g, ((1,), (0,))) * x_dt, axis=-1)
    t2 = e * jnp.sum(mm(cq, state, ((1,), (0,))) * dy, axis=-1)
    dcum = t.sum(axis=1) - t.sum(axis=0) + t2 - u
    last = jnp.sum(u) + alpha * jnp.sum(state * g)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    dcum = dcum + jnp.where(row == chunk - 1, last, 0.0)
    # cum = cumsum(log_decay)  =>  dlog_i = sum_{k >= i} dcum_k
    dlog = jnp.sum(dcum) - jnp.cumsum(dcum) + dcum

    dx_ref[0, 0] = dxdt * dt[:, None]
    ddt_ref[0, 0] = (a * dlog + jnp.sum(dxdt * x, axis=-1))[None, :]
    dlog_ref[0, 0] = dlog[None, :]
    db_ref[0, 0] = db
    dc_ref[0, 0] = dc

    # reverse carry into the previous chunk
    g_scr[...] = alpha * g + mm(e[:, None] * cq, dy, ((0,), (0,)))


def ssd_bwd(x: jax.Array, dt: jax.Array, a_coef: jax.Array, b_in: jax.Array,
            c_in: jax.Array, carries: jax.Array, dy: jax.Array,
            dstate: jax.Array, *, chunk: int, interpret: bool = False):
    """Reverse chunk scan.  Layouts as ``ssd_fwd`` plus carries (B,H,nc,N,P),
    dy (B,H,S,P) and dstate (B,H,N,P) — the two output cotangents.

    Returns fp32 (dx (B,H,S,P), ddt (B,H,S), dlog (B,H,S),
    db_h (B,H,S,N), dc_h (B,H,S,N)): per-head dB/dC (summed over H by the
    caller) and the log-decay cotangent dlog (dA = sum_{b,s} dt * dlog).
    """
    b, h, s, p = x.shape
    n = b_in.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_bwd_kernel, chunk=chunk, n_chunks=nc)
    dt3 = dt.reshape(b, h, 1, s)
    # the reverse scan: chunk grid axis minor, index maps serve nc-1-ci
    seq_p = pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, nc - 1 - ci, 0))
    seq_dt = pl.BlockSpec((1, 1, 1, chunk),
                          lambda bi, hi, ci: (bi, hi, 0, nc - 1 - ci))
    seq_n = pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, nc - 1 - ci, 0))
    seq_hn = pl.BlockSpec((1, 1, chunk, n),
                          lambda bi, hi, ci: (bi, hi, nc - 1 - ci, 0))
    dx, ddt3, dlog3, db_h, dc_h = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            seq_p,
            seq_dt,
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            seq_n,
            seq_n,
            pl.BlockSpec((1, 1, 1, n, p),
                         lambda bi, hi, ci: (bi, hi, nc - 1 - ci, 0, 0)),
            seq_p,
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[seq_p, seq_dt, seq_dt, seq_hn, seq_hn],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 1, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt3, a_coef.astype(jnp.float32), b_in, c_in, carries, dy, dstate)
    return dx, ddt3.reshape(b, h, s), dlog3.reshape(b, h, s), db_h, dc_h


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
