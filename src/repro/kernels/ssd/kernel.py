"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid (B, H, n_chunks) with the chunk axis minor — TPU's sequential grid
execution carries the (N, P) inter-chunk state in VMEM scratch, so the
recurrence never round-trips HBM between chunks (the GPU implementation's
equivalent trick is a separate state-passing kernel; on TPU the sequential
grid makes it one kernel).  Per chunk the intra term is two MXU matmuls over
a (Q, Q) decay-masked score tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (1, Q)
    a = a_ref[0]                              # scalar negative decay coef
    bq = b_ref[0].astype(jnp.float32)        # (Q, N)
    cq = c_ref[0].astype(jnp.float32)        # (Q, N)

    log_decay = dt[0] * a                    # (Q,)
    cum = jnp.cumsum(log_decay)              # (Q,) inclusive
    x_dt = x * dt[0][:, None]                # (Q, P)

    # intra-chunk: (C B^T (.) decay) @ x_dt
    scores = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    gap = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(iota_i >= iota_j, gap, NEG_INF))
    y = jax.lax.dot_general(scores * decay, x_dt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: C_i exp(cum_i) @ state_prev
    state = state_scr[...]                   # (N, P)
    c_scaled = cq * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(c_scaled, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: exp(cum_last) * state + sum_j exp(cum_last - cum_j) B_j x_dt_j
    b_scaled = bq * jnp.exp(cum[-1] - cum)[:, None]  # (Q, N)
    new_state = (jnp.exp(cum[-1]) * state
                 + jax.lax.dot_general(b_scaled, x_dt,
                                       (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))
    state_scr[...] = new_state
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        state_out_ref[0, 0] = new_state


def ssd_fwd(x: jax.Array, dt: jax.Array, a_coef: jax.Array, b_in: jax.Array,
            c_in: jax.Array, *, chunk: int = 128,
            interpret: bool = False):
    """x: (B, H, S, P); dt: (B, H, S); a_coef: (H,); b_in/c_in: (B, S, N).
    Returns (y (B,H,S,P), final_state (B,H,N,P))."""
    b, h, s, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    dt3 = dt.reshape(b, h, 1, s)  # keep last-two-dims tiling friendly
    y, state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, 0, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[_vmem((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt3, a_coef.astype(jnp.float32), b_in, c_in)
    return y, state


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
