"""Pure-jnp oracles for the flash-decode kernel.

Both functions use the shared decode masking convention — **lengths[b] is
the count of valid cache entries** for slot ``b``: cache row ``j`` attends
iff ``j < lengths[b]``.  ``decode_partials_reference`` is also the local
(per-shard) term ``distributed.collectives.flash_decode_sharded`` merges,
so kernel, jnp decode path and the sharded merge agree on one algebra.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_partials_reference(q: jax.Array, k_cache: jax.Array,
                              v_cache: jax.Array, lengths: jax.Array
                              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partial-softmax triple for one decode step.

    q: (B, H, D); k_cache, v_cache: (B, S, KV, D) with H = KV * G;
    lengths: (B,) int32 counts of valid entries.  Returns fp32
    ``(o (B, KV, G, D) unnormalized, m (B, KV, G), l (B, KV, G))``;
    fully-masked slots yield (0, NEG_INF, 0), so a psum/pmax merge across
    shards drops them exactly like the kernel does.
    """
    b, h, d = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bjkd->bkgj", qg,
                   k_cache.astype(jnp.float32))
    valid = jnp.arange(k_cache.shape[1])[None, :] < lengths[:, None]  # (B, S)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.where(valid[:, None, None], jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    return o, m, l


def decode_attention_reference(q: jax.Array, k_cache: jax.Array,
                               v_cache: jax.Array, lengths: jax.Array
                               ) -> jax.Array:
    """Normalized decode attention: q (B, H, D) -> context (B, H, D)."""
    b, h, d = q.shape
    o, _, l = decode_partials_reference(q, k_cache, v_cache, lengths)
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)


def gather_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Densify a paged cache: ``(n_pages, page_size, ...)`` pool +
    ``(B, max_pages)`` page table -> ``(B, max_pages * page_size, ...)``
    per-slot rows, with unowned (``-1``) pages zeroed.  The jnp fallback
    read path for paged decode and the oracle the paged kernel is tested
    against (garbage beyond ``lengths`` is masked downstream either way —
    the zeroing just keeps the densified cache reproducible)."""
    b, max_pages = page_table.shape
    page_size = pool.shape[1]
    pages = pool[jnp.maximum(page_table, 0)]  # (B, max_pages, page_size, ...)
    valid = (page_table >= 0).reshape(
        (b, max_pages) + (1,) * (pool.ndim - 1))
    pages = jnp.where(valid, pages, 0)
    return pages.reshape((b, max_pages * page_size) + pool.shape[2:])


def paged_decode_partials_reference(q: jax.Array, k_pool: jax.Array,
                                    v_pool: jax.Array,
                                    page_table: jax.Array,
                                    lengths: jax.Array
                                    ) -> tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """Oracle for the paged kernel: gather-then-dense partials."""
    return decode_partials_reference(q, gather_pages(k_pool, page_table),
                                     gather_pages(v_pool, page_table),
                                     lengths)


def paged_decode_attention_reference(q: jax.Array, k_pool: jax.Array,
                                     v_pool: jax.Array,
                                     page_table: jax.Array,
                                     lengths: jax.Array) -> jax.Array:
    """Normalized paged decode attention (gather-then-dense oracle)."""
    return decode_attention_reference(q, gather_pages(k_pool, page_table),
                                      gather_pages(v_pool, page_table),
                                      lengths)
