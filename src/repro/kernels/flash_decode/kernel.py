"""Pallas TPU flash-decode: split-KV decode attention over a slot cache.

One query row per slot (the fused decode step's token) attends to that
slot's KV cache rows, of which only the first ``lengths[b]`` are valid
("pos = count of valid entries" — the convention shared with
``models.attention.decode_attention`` and
``distributed.collectives.flash_decode_sharded``).  The jnp decode path
materializes the full ``(slots, KV, G, 1, S_max)`` score tensor per layer
per token; here the cache is streamed in KV blocks and the online-softmax
carry ``(m, l, acc)`` lives in VMEM scratch, so the high-water is
O(G * block_k) per (slot, kv-head) cell.

Grid ``(B, KV, kv_blocks)`` with the kv axis minor: the TPU executes the
grid sequentially, so each (slot, kv-head) cell accumulates its partial
softmax across kv iterations and finalizes at the last block.  GQA is
native — the query block is the whole ``(G, D)`` group for one kv head, so
no head expansion ever materializes.  There is no backward pass: decode is
inference-only.

The kernel emits *partials* ``(o_unnormalized, m, l)`` rather than the
normalized context: ops.py divides for the single-host path, and
``distributed.collectives.flash_decode_sharded`` merges per-shard partials
with pmax/psum — the same (m, l, o) algebra in both places.

Blocks entirely past a slot's valid length skip their flops via
``pl.when``; their HBM fetches are *not* yet elided (that needs
scalar-prefetch index maps so the block index can be clamped by
``lengths`` — see the ROADMAP TPU bring-up checklist).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   m_scr, l_scr, acc_scr, *, block_k: int, kv_blocks: int,
                   scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0, 0]  # this slot's count of valid cache entries

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_k, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        col = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        valid = col < length
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # mask p explicitly: on a fully-masked block m_new stays NEG_INF and
        # exp(s - m_new) would be exp(0) = 1, polluting l with dead columns
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    # skip blocks entirely past this slot's valid length (flops only; the
    # fetch still happens — see module docstring)
    @pl.when(ki * block_k < length)
    def _run():
        _body()

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[:, 0]
        l_ref[0, 0] = l_scr[:, 0]


def flash_decode_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, block_k: int = 128,
                     interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """q: (B, KV, G, D); k, v: (B, S, KV, D); lengths: (B,) int32 counts.

    S must be a multiple of ``block_k`` (ops.py pads; padded rows are dead
    because ``lengths <= S_orig``).  Returns fp32 partials
    ``(o (B, KV, G, D) unnormalized, m (B, KV, G), l (B, KV, G))`` — the
    caller normalizes ``o / l`` or psum-merges across sequence shards.
    """
    b, kvh, g, d = q.shape
    s = k.shape[1]
    assert s % block_k == 0, (s, block_k)
    kv_blocks = s // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               kv_blocks=kv_blocks, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, kvh, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, ki: (b, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, ki: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, g), jnp.float32),
        ],
        scratch_shapes=[
            _vmem((g, 1), jnp.float32),  # m: running row max
            _vmem((g, 1), jnp.float32),  # l: running row sum
            _vmem((g, d), jnp.float32),  # acc: weighted values
        ],
        interpret=interpret,
    )(q, k, v, lengths.astype(jnp.int32).reshape(b, 1))


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref,
                         o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                         page_size: int, max_pages: int, scale: float):
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[bi]       # this slot's count of valid cache entries
    owned = pt_ref[bi, pi] >= 0

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # global column index of in-page row j is pi * page_size + j
        col = pi * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        valid = col < length
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    # pages past the valid length and unowned (-1) table entries contribute
    # nothing; since the page id feeds the index map via scalar prefetch,
    # their HBM fetch is also elided on TPU (the map clamps -1 to page 0
    # but this body never reads the block)
    @pl.when((pi * page_size < length) & owned)
    def _run():
        _body()

    @pl.when(pi == max_pages - 1)
    def _finalize():
        o_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[:, 0]
        l_ref[0, 0] = l_scr[:, 0]


def flash_decode_paged_fwd(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *, interpret: bool = False
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Page-table-walking flash decode over a shared KV pool.

    q: (B, KV, G, D); k_pool, v_pool: (n_pages, page_size, KV, D);
    page_table: (B, max_pages) int32 page ids, ``-1`` = unowned;
    lengths: (B,) int32 counts (slot ``b``'s token ``j`` lives in page
    ``page_table[b, j // page_size]`` at offset ``j % page_size``).

    The page table and lengths ride scalar prefetch
    (``PrefetchScalarGridSpec``), so the k/v index maps resolve the *page
    id* per grid step — the kernel walks each slot's page list and never
    touches pages the slot doesn't own (ROADMAP TPU caveat (f), solved
    structurally here: the dense variant can only ``pl.when``-skip its
    fetches).  Masking and the (m, l, o) online-softmax merge are the
    dense kernel's, unchanged — they were already page-shape-agnostic.
    Returns the same fp32 partials as ``flash_decode_fwd``.
    """
    from jax.experimental.pallas import tpu as pltpu

    b, kvh, g, d = q.shape
    page_size = k_pool.shape[1]
    max_pages = page_table.shape[1]
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_paged_decode_kernel, page_size=page_size,
                               max_pages=max_pages, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b, h, pi, pt, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda b, h, pi, pt, lens:
                         (jnp.maximum(pt[b, pi], 0), 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda b, h, pi, pt, lens:
                         (jnp.maximum(pt[b, pi], 0), 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, pi, pt, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, pi, pt, lens: (b, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, pi, pt, lens: (b, h, 0)),
        ],
        scratch_shapes=[
            _vmem((g, 1), jnp.float32),  # m: running row max
            _vmem((g, 1), jnp.float32),  # l: running row sum
            _vmem((g, d), jnp.float32),  # acc: weighted values
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, g), jnp.float32),
        ],
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pool, v_pool)
