"""Jit'd public wrappers for the flash-decode kernel.

Model-facing layout: q ``(B, H, D)`` (one token per slot), cache
``(B, S, KV, D)``, ``lengths (B,)`` int32 = count of valid entries per
slot.  The wrapper folds GQA to the kernel's native ``(B, KV, G, D)``
query grouping (no head expansion), zero-pads the cache sequence to a
``block_k`` multiple (dead rows: ``lengths <= S``), and either normalizes
the partials (``flash_decode``) or hands them to the caller
(``flash_decode_partials`` — the per-shard term of
``distributed.collectives.flash_decode_sharded``).

Off-TPU the kernel runs in interpret mode (see kernels.resolve_interpret),
so the serving tests validate the exact kernel body on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import chunk_padding, resolve_interpret
from repro.kernels.flash_decode.kernel import (flash_decode_fwd,
                                               flash_decode_paged_fwd)


def _run_kernel(q, k_cache, v_cache, lengths, block_k, interpret):
    b, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    block_k, pad = chunk_padding(s, block_k)
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return flash_decode_fwd(qg, k_cache, v_cache, lengths,
                            block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, block_k: int = 128,
                 interpret: bool | None = None) -> jax.Array:
    """Normalized decode attention: returns context ``(B, H, D)`` like q."""
    o, _, l = _run_kernel(q, k_cache, v_cache, lengths, block_k,
                          resolve_interpret(interpret))
    out = o / jnp.maximum(l[..., None], 1e-30)
    b, h, d = q.shape
    return out.reshape(b, h, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_partials(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, lengths: jax.Array, *,
                          block_k: int = 128,
                          interpret: bool | None = None
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Unnormalized fp32 partials ``(o (B,KV,G,D), m (B,KV,G), l (B,KV,G))``.

    Merge rule (what ``flash_decode_sharded`` runs across shards):
    ``gm = max(m); out = sum(o * exp(m-gm)) / sum(l * exp(m-gm))``.
    """
    return _run_kernel(q, k_cache, v_cache, lengths, block_k,
                       resolve_interpret(interpret))


def _run_paged_kernel(q, k_pool, v_pool, page_table, lengths, interpret):
    b, h, d = q.shape
    kvh = k_pool.shape[2]
    qg = q.reshape(b, kvh, h // kvh, d)
    return flash_decode_paged_fwd(qg, k_pool, v_pool, page_table, lengths,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       page_table: jax.Array, lengths: jax.Array, *,
                       interpret: bool | None = None) -> jax.Array:
    """Normalized paged decode attention: context ``(B, H, D)`` like q.

    Same model-facing layout as ``flash_decode`` except the cache is a
    shared ``(n_pages, page_size, KV, D)`` pool indexed through
    ``page_table (B, max_pages)`` (``-1`` = unowned — see serve/paging.py).
    One page per kv block: no tail padding is ever needed (pages are the
    block granule), and unowned/past-length pages are skipped fetch-and-all
    via scalar-prefetch index maps.
    """
    o, _, l = _run_paged_kernel(q, k_pool, v_pool, page_table, lengths,
                                resolve_interpret(interpret))
    out = o / jnp.maximum(l[..., None], 1e-30)
    b, h, d = q.shape
    return out.reshape(b, h, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged_partials(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, page_table: jax.Array,
                                lengths: jax.Array, *,
                                interpret: bool | None = None
                                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Paged variant of ``flash_decode_partials`` (same merge algebra)."""
    return _run_paged_kernel(q, k_pool, v_pool, page_table, lengths,
                             resolve_interpret(interpret))
