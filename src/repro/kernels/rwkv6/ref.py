"""Oracle for the WKV6 kernel: the model's chunked reference
(layout-adapted) plus a fully-sequential scan for double-checking."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.rwkv6 import wkv6_reference


def wkv6_fwd_reference(r, k, v, log_w, u, *, chunk: int = 32):
    """Same layout as kernel.wkv6_fwd: (B, H, S, D)."""
    tr = lambda t: t.transpose(0, 2, 1, 3)  # -> (B,S,H,D)
    y, state = wkv6_reference(tr(r), tr(k), tr(v), tr(log_w), u, chunk)
    return tr(y), state


def wkv6_sequential(r, k, v, log_w, u):
    """Step-by-step recurrence (independent oracle for the chunked math)."""
    b, h, s, d = r.shape
    f32 = jnp.float32

    def step(state, inp):
        rt, kt, vt, lwt = inp  # (B,H,D)
        bonus = jnp.einsum("bhd,hd,bhd->bh", rt, u.astype(f32), kt)
        y = jnp.einsum("bhd,bhde->bhe", rt, state) + bonus[..., None] * vt
        state = (jnp.exp(lwt)[..., None] * state
                 + jnp.einsum("bhd,bhe->bhde", kt, vt))
        return state, y

    xs = tuple(jnp.moveaxis(t.astype(f32), 2, 0) for t in (r, k, v, log_w))
    state0 = jnp.zeros((b, h, d, d), f32)
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype), state
