"""jit'd wrapper for the WKV6 kernel (interpret fallback off-TPU)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6.kernel import wkv6_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, log_w, u, *, chunk: int = 32, interpret: bool | None = None):
    """r/k/v/log_w: (B, H, S, D); u: (H, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    return wkv6_fwd(r, k, v, log_w, u, chunk=chunk, interpret=interpret)
