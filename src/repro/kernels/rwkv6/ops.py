"""Differentiable jit'd public wrapper for the WKV6 kernels.

``wkv6`` is a ``jax.custom_vjp`` over the Pallas forward/backward pair in
kernel.py:

* forward: pads the sequence to a chunk multiple when needed (log_w = 0 /
  k = 0 pad steps decay by exp(0) = 1 and inject nothing, so the final
  state is unaffected), runs the carry-emitting forward, and saves
  ``(r, k, v, log_w, u, carries)`` as residuals.  ``carries`` is the
  (B, H, nc, D, D) tensor of per-head states *entering* each chunk — the
  chunk-compressed residual layout: the (Q, Q, D) pairwise decay tensor is
  recomputed per chunk inside the backward kernel, never materialized at
  sequence scale.
* backward: one reverse-chunk-scan Pallas kernel carrying the (D, D)
  state cotangent in VMEM (seeded with the final-state cotangent),
  emitting dr/dk/dv/d_log_w per chunk and accumulating the per-head bonus
  gradient du across the sweep; the only jnp epilogue is the batch-sum of
  du (u is batch-shared) and the cotangent dtype casts.

Off-TPU the kernels run in interpret mode (see ``resolve_interpret``), so
``jax.grad`` through ``wkv6`` works on every backend; padding/slicing
lives *outside* the custom_vjp, so AD handles the uneven-tail case free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import chunk_padding, resolve_interpret
from repro.kernels.rwkv6.kernel import wkv6_bwd, wkv6_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _wkv6(r, k, v, log_w, u, chunk, interpret):
    y, state = wkv6_fwd(r, k, v, log_w, u, chunk=chunk, interpret=interpret)
    return y, state


def _wkv6_fwd_rule(r, k, v, log_w, u, chunk, interpret):
    y, state, carries = wkv6_fwd(r, k, v, log_w, u, chunk=chunk,
                                 interpret=interpret, return_carries=True)
    return (y, state), (r, k, v, log_w, u, carries)


def _wkv6_bwd_rule(chunk, interpret, res, cts):
    r, k, v, log_w, u, carries = res
    dy, dstate = cts
    dr, dk, dv, dlw, du_part = wkv6_bwd(
        r, k, v, log_w, u, carries, dy.astype(jnp.float32),
        dstate.astype(jnp.float32), chunk=chunk, interpret=interpret)
    return (dr.astype(r.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            dlw.astype(log_w.dtype), du_part.sum(axis=0).astype(u.dtype))


_wkv6.defvjp(_wkv6_fwd_rule, _wkv6_bwd_rule)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, log_w, u, *, chunk: int = 32, interpret: bool | None = None):
    """r/k/v/log_w: (B, H, S, D); u: (H, D).
    Returns (y (B,H,S,D), final_state (B,H,D,D)).

    Differentiable end-to-end: ``jax.grad`` routes through the fused Pallas
    reverse-scan kernel via the custom VJP above.  Sequence lengths that
    are not chunk multiples are zero-padded (state-safe) and sliced back.
    """
    interpret = resolve_interpret(interpret)
    s = r.shape[2]
    chunk, pad = chunk_padding(s, chunk)
    if pad:
        padw = ((0, 0), (0, 0), (0, pad), (0, 0))
        r, k, v, log_w = (jnp.pad(t, padw) for t in (r, k, v, log_w))
    y, state = _wkv6(r, k, v, log_w, u, chunk, interpret)
    return (y[:, :, :s] if pad else y), state
