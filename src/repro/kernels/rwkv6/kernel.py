"""Pallas TPU kernels for the RWKV-6 chunked WKV recurrence — fwd and bwd.

Forward — grid (B, H, n_chunks), chunk axis minor; the (D, D) per-head state
is carried in VMEM scratch across chunks.  Per-channel data-dependent decay
means the intra-chunk pairwise tensor is (Q, Q, D) — kept in registers/VMEM
for one chunk only (Q<=64), with all exponents non-positive by construction
(the decays are <= 1 and only backward-in-time products appear), so no
secondary renormalization is needed.  With ``return_carries=True`` the
kernel additionally emits the (B, H, nc, D, D) states entering each chunk —
the chunk-compressed backward residual.

Backward — the same grid with the chunk axis reversed via the index maps:
one kernel runs the reverse scan, carrying the (D, D) state cotangent in
VMEM scratch (seeded from the final-state cotangent).  Per chunk it
recomputes the (Q, Q, D) pairwise decay tensor from the saved inputs and
emits dr/dk/dv/d_log_w; du (the per-head current-token bonus) accumulates
in a second scratch across the whole reverse sweep and is written at the
final grid step, then summed over batch by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, *refs, chunk: int,
                 n_chunks: int, with_carries: bool):
    if with_carries:
        y_ref, state_out_ref, carry_ref, state_scr = refs
    else:
        (y_ref, state_out_ref, state_scr), carry_ref = refs, None
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)   # (Q, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)  # (Q, D) log decay <= 0
    u = u_ref[0].astype(jnp.float32)       # (D,) current-token bonus

    cum = jnp.cumsum(lw, axis=0)           # (Q, D) inclusive
    cum_in = cum - lw                      # exclusive

    # intra-chunk, strictly causal: att[i,j] = sum_d r_i exp(cum_in_i - cum_j) k_j
    gap = cum_in[:, None, :] - cum[None, :, :]  # (Q, Q, D)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (iota_i > iota_j)[:, :, None]
    w_pair = jnp.exp(jnp.where(strict, gap, NEG_INF))  # (Q, Q, D)
    att = jnp.einsum("id,ijd,jd->ij", r, w_pair, k)
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # current-token bonus: (r_i . u*k_i) v_i
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    y = y + bonus * v

    # carried state: (r_i (.) exp(cum_in_i)) @ S_prev
    state = state_scr[...]                 # (D, D)
    if carry_ref is not None:
        carry_ref[0, 0, 0] = state         # residual: state entering chunk
    y = y + jax.lax.dot_general(r * jnp.exp(cum_in), state,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: diag(exp(cum_last)) S + sum_j (k_j exp(cum_last - cum_j)) (x) v_j
    k_scaled = k * jnp.exp(cum[-1][None, :] - cum)
    new_state = (jnp.exp(cum[-1])[:, None] * state
                 + jax.lax.dot_general(k_scaled, v, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))
    state_scr[...] = new_state
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        state_out_ref[0, 0] = new_state


def wkv6_fwd(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
             u: jax.Array, *, chunk: int = 32, interpret: bool = False,
             return_carries: bool = False):
    """r/k/v/log_w: (B, H, S, D); u: (H, D).
    Returns (y (B,H,S,D), final_state (B,H,D,D)); with ``return_carries``
    also the (B,H,nc,D,D) per-chunk entry states (the bwd residual)."""
    b, h, s, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=nc,
                               with_carries=return_carries)
    seq_spec = pl.BlockSpec((1, 1, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0))
    out_specs = [
        seq_spec,
        pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, h, s, d), r.dtype),
        jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
    ]
    if return_carries:
        out_specs.append(pl.BlockSpec((1, 1, 1, d, d),
                                      lambda bi, hi, ci: (bi, hi, ci, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, h, nc, d, d), jnp.float32))
    outs = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, d), lambda bi, hi, ci: (hi, 0))],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[_vmem((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u)
    return tuple(outs)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _wkv6_bwd_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, carry_ref, dy_ref,
                     dstate_ref, dr_ref, dk_ref, dv_ref, dlw_ref, du_ref,
                     g_scr, du_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)  # reversed: index maps serve chunk nc-1-ci

    @pl.when(ci == 0)
    def _init():  # cotangent of the final-state output seeds the carry
        g_scr[...] = dstate_ref[0, 0]
        du_scr[...] = jnp.zeros_like(du_scr)

    r = r_ref[0, 0].astype(jnp.float32)    # (Q, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)       # (D,)
    state = carry_ref[0, 0, 0]             # (D, D) state entering chunk
    dy = dy_ref[0, 0].astype(jnp.float32)  # (Q, D)
    g = g_scr[...]                         # (D, D) d(chunk-final state)

    # recompute the forward's per-chunk decay geometry
    cum = jnp.cumsum(lw, axis=0)
    cum_in = cum - lw
    e_in = jnp.exp(cum_in)                         # (Q, D)
    alpha = jnp.exp(cum[-1])                       # (D,)
    f = jnp.exp(cum[-1][None, :] - cum)            # (Q, D)
    gap = cum_in[:, None, :] - cum[None, :, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (iota_i > iota_j)[:, :, None]
    w_pair = jnp.exp(jnp.where(strict, gap, NEG_INF))  # (Q, Q, D)

    def mm(lhs, rhs, dims):
        return jax.lax.dot_general(lhs, rhs, (dims, ((), ())),
                                   preferred_element_type=jnp.float32)

    p = mm(dy, v, ((1,), (1,)))            # (Q, Q): p_ij = dy_i . v_j
    diag_p = jnp.sum(dy * v, axis=-1)      # (Q,):  p_ii

    att = jnp.einsum("id,ijd,jd->ij", r, w_pair, k)
    bonus_coef = jnp.sum(r * u[None, :] * k, axis=-1)  # (Q,)

    # dv: intra attention rows + current-token bonus + state-update outer prod
    dv = (mm(att, dy, ((0,), (0,))) + bonus_coef[:, None] * dy
          + mm(k * f, g, ((1,), (0,))))

    # dr/dk split by source term — the intra and carried-state parts double
    # as the decay cotangent below (d log-decay couples through the same
    # products), so keep them separate until the end
    dr_intra = jnp.einsum("ijd,jd,ij->id", w_pair, k, p)
    dr_state = e_in * mm(dy, state, ((1,), (1,)))      # (Q, D)
    dk_intra = jnp.einsum("ijd,id,ij->jd", w_pair, r, p)
    dk_state = f * mm(v, g, ((1,), (1,)))              # (Q, D)
    dr = dr_intra + dr_state + u[None, :] * k * diag_p[:, None]
    dk = dk_intra + dk_state + u[None, :] * r * diag_p[:, None]

    du_scr[...] += jnp.sum(r * k * diag_p[:, None], axis=0)[None, :]

    # cotangent of the cumulative log-decays: the exclusive cumsum couples
    # through the pairwise tensor rows and the carried-state decay, the
    # inclusive one through the pairwise columns, the decay-to-end factors
    # and (last row only) the state's own decay
    dcum_in = r * (dr_intra + dr_state)
    dcum = -(k * (dk_intra + dk_state))
    last = (jnp.sum(k * dk_state, axis=0)
            + alpha * jnp.sum(state * g, axis=-1))    # (D,)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    dcum = dcum + jnp.where(row == chunk - 1, last[None, :], 0.0)
    # cum = cumsum(lw), cum_in = cum - lw =>
    #   dlw_m = sum_{i >= m} (dcum_i + dcum_in_i) - dcum_in_m
    total = dcum + dcum_in
    rev = jnp.sum(total, axis=0, keepdims=True) - jnp.cumsum(total, axis=0) \
        + total
    dlw = rev - dcum_in

    dr_ref[0, 0] = dr
    dk_ref[0, 0] = dk
    dv_ref[0, 0] = dv
    dlw_ref[0, 0] = dlw

    # reverse carry into the previous chunk
    g_scr[...] = alpha[:, None] * g + mm(r * e_in, dy, ((0,), (0,)))

    @pl.when(ci == n_chunks - 1)
    def _final():
        du_ref[0, 0] = du_scr[0]


def wkv6_bwd(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
             u: jax.Array, carries: jax.Array, dy: jax.Array,
             dstate: jax.Array, *, chunk: int, interpret: bool = False):
    """Reverse chunk scan.  Layouts as ``wkv6_fwd`` plus carries
    (B,H,nc,D,D), dy (B,H,S,D) and dstate (B,H,D,D) output cotangents.

    Returns fp32 (dr, dk, dv, d_log_w (B,H,S,D), du_part (B,H,D)); du_part
    is per-(batch, head) and summed over batch by the caller.
    """
    b, h, s, d = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_wkv6_bwd_kernel, chunk=chunk, n_chunks=nc)
    # the reverse scan: chunk grid axis minor, index maps serve nc-1-ci
    seq_rev = pl.BlockSpec((1, 1, chunk, d),
                           lambda bi, hi, ci: (bi, hi, nc - 1 - ci, 0))
    f32 = jnp.float32
    dr, dk, dv, dlw, du_part = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            seq_rev, seq_rev, seq_rev, seq_rev,
            pl.BlockSpec((1, d), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, 1, d, d),
                         lambda bi, hi, ci: (bi, hi, nc - 1 - ci, 0, 0)),
            seq_rev,
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[seq_rev, seq_rev, seq_rev, seq_rev,
                   pl.BlockSpec((1, 1, d), lambda bi, hi, ci: (bi, hi, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), f32),
            jax.ShapeDtypeStruct((b, h, s, d), f32),
            jax.ShapeDtypeStruct((b, h, s, d), f32),
            jax.ShapeDtypeStruct((b, h, s, d), f32),
            jax.ShapeDtypeStruct((b, h, d), f32),
        ],
        scratch_shapes=[_vmem((d, d), jnp.float32),
                        _vmem((1, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u, carries, dy, dstate)
    return dr, dk, dv, dlw, du_part


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
