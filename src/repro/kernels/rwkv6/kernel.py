"""Pallas TPU kernel for the RWKV-6 chunked WKV recurrence.

Grid (B, H, n_chunks), chunk axis minor; the (D, D) per-head state is carried
in VMEM scratch across chunks.  Per-channel data-dependent decay means the
intra-chunk pairwise tensor is (Q, Q, D) — kept in registers/VMEM for one
chunk only (Q<=64), with all exponents non-positive by construction (the
decays are <= 1 and only backward-in-time products appear), so no secondary
renormalization is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, state_out_ref,
                 state_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)   # (Q, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)  # (Q, D) log decay <= 0
    u = u_ref[0].astype(jnp.float32)       # (D,) current-token bonus

    cum = jnp.cumsum(lw, axis=0)           # (Q, D) inclusive
    cum_in = cum - lw                      # exclusive

    # intra-chunk, strictly causal: att[i,j] = sum_d r_i exp(cum_in_i - cum_j) k_j
    gap = cum_in[:, None, :] - cum[None, :, :]  # (Q, Q, D)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (iota_i > iota_j)[:, :, None]
    w_pair = jnp.exp(jnp.where(strict, gap, NEG_INF))  # (Q, Q, D)
    att = jnp.einsum("id,ijd,jd->ij", r, w_pair, k)
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # current-token bonus: (r_i . u*k_i) v_i
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    y = y + bonus * v

    # carried state: (r_i (.) exp(cum_in_i)) @ S_prev
    state = state_scr[...]                 # (D, D)
    y = y + jax.lax.dot_general(r * jnp.exp(cum_in), state,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: diag(exp(cum_last)) S + sum_j (k_j exp(cum_last - cum_j)) (x) v_j
    k_scaled = k * jnp.exp(cum[-1][None, :] - cum)
    new_state = (jnp.exp(cum[-1])[:, None] * state
                 + jax.lax.dot_general(k_scaled, v, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32))
    state_scr[...] = new_state
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        state_out_ref[0, 0] = new_state


def wkv6_fwd(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
             u: jax.Array, *, chunk: int = 32, interpret: bool = False):
    """r/k/v/log_w: (B, H, S, D); u: (H, D).
    Returns (y (B,H,S,D), final_state (B,H,D,D))."""
    b, h, s, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=nc)
    seq_spec = pl.BlockSpec((1, 1, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0))
    y, state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, d), lambda bi, hi, ci: (hi, 0))],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[_vmem((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u)
    return y, state


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
