"""Stability instrumentation from the paper's analysis (Section 3).

* loss ratio  — current-step loss / min previous loss; >1.2 counts as a
  spike (Table 1).
* Adam variance telemetry — l1 norm and max element of sqrt(v_t) (Fig. 1
  c–f), plus momentum l1 norm (A.3.2).
* Pearson correlation between the loss-ratio series and the variance series
  (Table 3), with the exact t-distribution p-value.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# loss-ratio tracking (host side)
# ---------------------------------------------------------------------------

@dataclass
class LossRatioTracker:
    spike_threshold: float = 1.2
    min_loss: float = float("inf")
    max_ratio: float = 0.0
    n_steps: int = 0
    n_spikes: int = 0
    ratios: List[float] = field(default_factory=list)

    def update(self, loss: float) -> float:
        """Returns the loss ratio for this step (1.0 on the first step)."""
        ratio = loss / self.min_loss if np.isfinite(self.min_loss) else 1.0
        self.ratios.append(ratio)
        self.n_steps += 1
        if ratio > self.spike_threshold:
            self.n_spikes += 1
        self.max_ratio = max(self.max_ratio, ratio)
        self.min_loss = min(self.min_loss, loss)
        return ratio

    def summary(self) -> Dict[str, float]:
        return {
            "steps": self.n_steps,
            "spikes": self.n_spikes,
            "spike_frac": self.n_spikes / max(self.n_steps, 1),
            "max_loss_ratio": self.max_ratio,
        }

    def state_dict(self) -> Dict[str, Any]:
        return {"min_loss": self.min_loss, "max_ratio": self.max_ratio,
                "n_steps": self.n_steps, "n_spikes": self.n_spikes,
                "spike_threshold": self.spike_threshold}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        for k, v in d.items():
            setattr(self, k, v)


# ---------------------------------------------------------------------------
# Adam state telemetry (inside the jitted train step)
# ---------------------------------------------------------------------------

def variance_stats(v_tree: Any) -> Dict[str, jax.Array]:
    """l1 norm and max element of sqrt(v_t) — the paper's Fig. 1 series.
    (l1 to avoid outlier amplification, per the paper's footnote 5.)"""
    leaves = [jnp.sqrt(x.astype(jnp.float32))
              for x in jax.tree_util.tree_leaves(v_tree)]
    l1 = sum(jnp.sum(x) for x in leaves)
    mx = jnp.stack([jnp.max(x) for x in leaves]).max()
    return {"var_l1": l1, "var_max": mx}


def momentum_stats(m_tree: Any) -> Dict[str, jax.Array]:
    leaves = [jnp.abs(x.astype(jnp.float32))
              for x in jax.tree_util.tree_leaves(m_tree)]
    return {"mom_l1": sum(jnp.sum(x) for x in leaves)}


# ---------------------------------------------------------------------------
# correlation analysis (Table 3)
# ---------------------------------------------------------------------------

def pearson(x: np.ndarray, y: np.ndarray) -> Tuple[float, float]:
    """Pearson r + two-sided p-value via the exact t distribution
    (regularized incomplete beta; no scipy dependency)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    n = x.size
    if n < 3:
        return float("nan"), float("nan")
    xc = x - x.mean()
    yc = y - y.mean()
    denom = np.sqrt((xc * xc).sum() * (yc * yc).sum())
    if denom == 0:
        return float("nan"), float("nan")
    r = float(np.clip((xc * yc).sum() / denom, -1.0, 1.0))
    df = n - 2
    if abs(r) >= 1.0:
        return r, 0.0
    t2 = df * r * r / (1.0 - r * r)
    # two-sided p = I_{df/(df+t^2)}(df/2, 1/2)
    from jax.scipy.special import betainc
    p = float(betainc(df / 2.0, 0.5, df / (df + t2)))
    return r, p
