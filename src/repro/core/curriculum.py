"""SLW curriculum controller: the host-side state machine that drives the
per-step sequence length, applies it to batches, and does token accounting.

Two batch transforms:

* ``truncate`` — paper-faithful (§4): keep the first ``s_t`` tokens of each
  pre-indexed full-length sequence; the rest of the step's tokens are dropped
  (the paper accepts this and notes the index-recording alternative).
* ``repack`` — beyond-paper: reshape ``(B, S) -> (B * S//s_t, s_t)`` so no
  token is dropped and tokens/step stays constant during warmup.  This
  removes the "fewer tokens per step" side of the recipe (token-wise LR decay
  then coincides with step-wise), trading data-order fidelity for constant
  throughput.

All slicing happens host-side on numpy arrays *before* device transfer, so a
warmup step moves only ``B * s_t`` tokens over PCIe/ICI, not the full batch.

The controller's state (step, tokens_seen, variance-gate level) is part of
the training checkpoint: a restart mid-warmup resumes the curriculum exactly
(re-running long sequences early after a crash would reintroduce the very
instability SLW removes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import SLWConfig
from repro.core import pacing


@dataclass
class CurriculumState:
    step: int = 0
    tokens_seen: int = 0
    gate_level: int = 0  # index into the bucket ladder (variance_gated)
    var_trailing: float = 0.0  # trailing mean of Adam variance-max


def apply_seqlen(batch: Dict[str, np.ndarray], s_t: int,
                 mode: str = "truncate") -> Tuple[Dict[str, np.ndarray], int]:
    """Apply sequence length ``s_t`` to a host-side batch.

    Standalone so the trainer can execute a ``StepPlan`` without owning a
    curriculum object.  Sequence-axis keys are truncated/repacked; a
    vision-patch prefix (``patch_embeds``) is passed through untouched (SLW
    warms up only the text segment).  Returns (batch, tokens_this_step),
    prefix tokens included in the count.
    """
    seq_keys = [k for k in ("tokens", "labels", "loss_mask", "frames")
                if k in batch]
    full = batch[seq_keys[0]].shape[1]
    s_t = min(s_t, full)
    out = dict(batch)
    if mode == "truncate" or s_t == full:
        for k in seq_keys:
            out[k] = batch[k][:, :s_t]
    elif mode == "repack":
        folds = full // s_t
        for k in seq_keys:
            v = batch[k][:, :folds * s_t]
            out[k] = v.reshape((v.shape[0] * folds, s_t) + v.shape[2:])
        if "patch_embeds" in out:
            out["patch_embeds"] = np.repeat(out["patch_embeds"], folds,
                                            axis=0)
    else:
        raise ValueError(f"unknown SLW mode {mode!r}")
    tokens = int(np.prod(out[seq_keys[0]].shape[:2]))
    if "patch_embeds" in out:
        tokens += int(out["patch_embeds"].shape[0]
                      * out["patch_embeds"].shape[1])
    return out, tokens


class SLWCurriculum:
    def __init__(self, cfg: SLWConfig, full_seq: int, warmup_steps_hint: int = 0,
                 prefix_tokens: int = 0):
        self.cfg = cfg
        self.full_seq = full_seq
        self.warmup_steps_hint = warmup_steps_hint
        self.prefix_tokens = prefix_tokens  # vlm: frozen image-patch prefix
        self.ladder = pacing.bucket_ladder(cfg, full_seq - prefix_tokens)
        self.state = CurriculumState()

    # -- schedule -----------------------------------------------------------
    def seqlen_for_step(self, step: Optional[int] = None) -> int:
        step = self.state.step if step is None else step
        if self.cfg.enabled and self.cfg.pacing == "variance_gated":
            envelope = pacing.seqlen_at(
                self.cfg, step, self.full_seq - self.prefix_tokens,
                self.warmup_steps_hint, self.ladder)
            gated = self.ladder[min(self.state.gate_level,
                                    len(self.ladder) - 1)]
            return min(envelope, gated) if step else min(
                envelope, self.ladder[0])
        return pacing.seqlen_at(self.cfg, step,
                                self.full_seq - self.prefix_tokens,
                                self.warmup_steps_hint, self.ladder)

    def observe(self, var_max: float) -> None:
        """variance_gated pacing: advance the ladder only while the Adam
        variance max element stays below gate * trailing mean (beyond-paper;
        closes the loop on the paper's §3 correlation)."""
        st = self.state
        if st.var_trailing == 0.0:
            st.var_trailing = var_max
        ok = var_max <= self.cfg.variance_gate * st.var_trailing
        st.var_trailing = 0.9 * st.var_trailing + 0.1 * var_max
        if ok and st.gate_level < len(self.ladder) - 1:
            st.gate_level += 1

    # -- batch transform ------------------------------------------------------
    def apply(self, batch: Dict[str, np.ndarray], seqlen: Optional[int] = None
              ) -> Tuple[Dict[str, np.ndarray], int]:
        """Apply the current sequence length. Returns (batch, tokens_this_step).

        Sequence-axis keys are truncated/repacked; the vision-patch prefix is
        passed through untouched (SLW warms up only the text segment).
        """
        s_t = self.seqlen_for_step() if seqlen is None else seqlen
        return apply_seqlen(batch, s_t, self.cfg.mode)

    # -- accounting -----------------------------------------------------------
    def step_complete(self, tokens_this_step: int) -> None:
        self.state.step += 1
        self.state.tokens_seen += tokens_this_step

    @property
    def at_full_length(self) -> bool:
        return self.seqlen_for_step() >= self.full_seq - self.prefix_tokens

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> Dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: Dict) -> None:
        self.state = CurriculumState(**d)
