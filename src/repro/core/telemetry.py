"""Per-parameter instability telemetry: the label pass + leaf reductions.

The paper's Section 3 analysis (and Molybog et al.'s Adam-instability
theory in PAPERS.md) predicts that a loss spike is heralded by extreme
values of the Adam variance state in *specific components* of the model —
the time-domain correlation of per-layer gradient/update components is the
precursor.  The trainer historically reduced that signal to two global
scalars (``var_max``/``var_l1``), so regulators and the recovery controller
could only act blindly on the whole model.

This module is the per-parameter layer underneath:

* :func:`param_labels` — a deterministic labeling pass over any model-zoo
  parameter pytree.  Labels are the tree paths (``layers/attn/wq``), in
  ``tree_leaves`` order, so a ``(n_leaves,)`` vector reduced inside the
  jitted step lines up with the labels host-side.  Because the model zoo
  stacks layers on a leading scan axis, one leaf *is* one layer-group of
  the network — exactly the granularity the per-layer blame needs.
* :func:`leaf_norms` / :func:`leaf_var_max` — the fixed-size named-vector
  reductions the optimizer chain emits when
  ``OptimizerConfig.telemetry_level == "per_leaf"``.
* :class:`PerLeafStats` helpers — host-side conversion between the jitted
  step's vectors and JSON-serializable dicts (the checkpointed
  ``ControllerState`` and the ``--metrics-jsonl`` rows both carry them).

``variance_stats``/``momentum_stats`` (the legacy global scalars) stay in
``core.stability``; everything here is additive and opt-in.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

# keys of the per-leaf vectors the jitted step may emit in its metrics
# dict (each value is (n_leaves,) f32 in param_labels order, except
# leaf_gns_sketch: (n_leaves, d) — the random-projection direction sketch
# the pre-spike precursor rings up host-side)
PER_LEAF_KEYS = ("leaf_var_max", "leaf_grad_norm", "leaf_update_norm",
                 "leaf_param_norm", "leaf_gns_small_sq", "leaf_gns_big_sq",
                 "leaf_gns_sketch")


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def param_labels(params: Any) -> Tuple[str, ...]:
    """Deterministic leaf labels for a parameter pytree, in the same order
    ``jax.tree_util.tree_leaves`` flattens it (so jitted per-leaf vectors
    line up host-side)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return tuple("/".join(_path_str(p) for p in path) for path, _ in flat)


def leaf_norms(tree: Any) -> jax.Array:
    """(n_leaves,) vector of per-leaf l2 norms, f32."""
    return jnp.stack([
        jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
        for x in jax.tree_util.tree_leaves(tree)])


def leaf_var_max(v_tree: Any) -> jax.Array:
    """(n_leaves,) vector of per-leaf max sqrt(v) — the paper's Fig. 1
    series, one entry per labeled parameter group."""
    return jnp.stack([
        jnp.max(jnp.sqrt(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(v_tree)])


# ---------------------------------------------------------------------------
# host-side plumbing
# ---------------------------------------------------------------------------

def split_metrics(metrics: Dict[str, Any]
                  ) -> Tuple[Dict[str, Any], Optional[Dict[str, np.ndarray]]]:
    """Split a jitted step's metrics dict into (scalars, per-leaf vectors).

    The per-leaf vectors are renamed without their ``leaf_`` prefix and a
    derived ``grad_to_weight`` ratio is added when both norms are present.
    Returns ``(scalars, None)`` when the step ran at scalar telemetry level.
    """
    scalars = {k: v for k, v in metrics.items() if k not in PER_LEAF_KEYS}
    vectors = {k[len("leaf_"):]: np.asarray(jax.device_get(metrics[k]),
                                            np.float32)
               for k in PER_LEAF_KEYS if k in metrics}
    if not vectors:
        return scalars, None
    if "grad_norm" in vectors and "param_norm" in vectors:
        vectors["grad_to_weight"] = (
            vectors["grad_norm"] / np.maximum(vectors["param_norm"], 1e-12))
    return scalars, vectors


def per_leaf_to_host(per_leaf: Optional[Dict[str, np.ndarray]]
                     ) -> Optional[Dict[str, List[float]]]:
    """JSON-serializable form (checkpoints, JSONL rows)."""
    if per_leaf is None:
        return None
    return {k: np.asarray(v, np.float64).tolist() for k, v in per_leaf.items()}


def per_leaf_from_host(d: Optional[Dict[str, Any]]
                       ) -> Optional[Dict[str, np.ndarray]]:
    if d is None:
        return None
    return {k: np.asarray(v, np.float32) for k, v in d.items()}


def read_metrics_jsonl(path: str
                       ) -> Tuple[Tuple[str, ...], List[Dict[str, Any]]]:
    """Parse a ``--metrics-jsonl`` stream back into Python.

    Returns ``(leaf_labels, rows)``: the labels from the one-time header
    row (empty tuple when the run never emitted per-leaf vectors) and the
    row dicts in step order, with each row's ``per_leaf`` dict converted
    back to ``np.float32`` arrays via :func:`per_leaf_from_host`.  The
    round-trip inverse of ``MetricsJsonlHook``; reused by ``bench_gns``
    to pull measured series out of a run.
    """
    import json
    labels: Tuple[str, ...] = ()
    rows: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            if "leaf_labels" in row:
                labels = tuple(row["leaf_labels"])
            if row.get("per_leaf") is not None:
                row["per_leaf"] = per_leaf_from_host(row["per_leaf"])
            rows.append(row)
    return labels, rows


def blame(labels: Tuple[str, ...], ratios: np.ndarray) -> str:
    """Name the leaf with the largest excursion ratio (empty when the
    shapes don't line up — e.g. telemetry from a different model)."""
    if not labels or ratios.shape[0] != len(labels):
        return ""
    return labels[int(np.argmax(ratios))]
