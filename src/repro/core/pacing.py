"""Pacing functions (paper Section 4) + TPU bucket quantization.

The paper's pacing function is step-wise linear:

    seqlen_t = seqlen_s + (seqlen_e - seqlen_s) * min(t / T, 1)

with a post-processing ``seqlen_t -= seqlen_t mod 8`` for V100 tensor cores.
Also implemented: the root variant (paper §4 item ii), the Shortformer
discrete 2-stage schedule (the baseline §5.1 shows diverging at the switch),
and a constant schedule.

TPU adaptation: every distinct sequence length is an XLA recompilation, so
the raw pacing value is quantized onto a bounded *bucket ladder* —
geometric doubling from ``seqlen_s`` up to the rounding multiple, then
arithmetic steps of the multiple, thinned to at most ``max_buckets`` values.
jax.jit's shape-keyed executable cache then holds one compiled step per
bucket.  The paper's eager implementation is the special case
``round_multiple=8, max_buckets=big``.
"""
from __future__ import annotations

import bisect
import math
from typing import List, Sequence, Tuple

from repro.configs.base import SLWConfig


def raw_seqlen(cfg: SLWConfig, step: int, full_len: int,
               warmup_steps_hint: int = 0) -> float:
    """Un-quantized pacing value at `step` (paper formulas)."""
    s0 = cfg.start_seq_len
    s1 = cfg.end_seq_len or full_len
    T = cfg.duration_steps or max(2 * warmup_steps_hint, 1)
    if not cfg.enabled or cfg.pacing == "constant":
        return float(s1)
    if cfg.pacing == "linear":
        return s0 + (s1 - s0) * min(step / T, 1.0)
    if cfg.pacing == "root":
        return s0 + (s1 - s0) * min((step / T) ** (1.0 / cfg.root_degree), 1.0)
    if cfg.pacing == "two_stage":  # Shortformer baseline
        switch = cfg.two_stage_switch_step or T
        return float(cfg.two_stage_short_len if step < switch else s1)
    if cfg.pacing == "variance_gated":
        # beyond-paper: driven by observed Adam variance-max; the curriculum
        # controller owns the gate state and calls `raw_seqlen` only for the
        # linear upper envelope.
        return s0 + (s1 - s0) * min(step / T, 1.0)
    raise ValueError(f"unknown pacing {cfg.pacing!r}")


def bucket_ladder(cfg: SLWConfig, full_len: int) -> Tuple[int, ...]:
    """Monotone ladder of allowed sequence lengths, |ladder| <= max_buckets."""
    s0 = cfg.start_seq_len
    s1 = cfg.end_seq_len or full_len
    m = cfg.round_multiple
    if not cfg.enabled:
        return (s1,)
    ladder: List[int] = []
    # geometric sub-multiple region
    v = s0
    while v < min(m, s1):
        ladder.append(v)
        v *= 2
    # arithmetic multiples of m
    lo = max(m, s0 - s0 % m or m)
    n_arith = max(1, (s1 - lo) // m + 1)
    budget = max(1, cfg.max_buckets - len(ladder))
    stride = max(1, math.ceil(n_arith / budget))
    v = lo
    while v < s1:
        ladder.append(v)
        v += stride * m
    ladder.append(s1)
    # Smallest admissible bucket: s0 itself when s0 is below the rounding
    # multiple, else s0 rounded *down* to the multiple (the arithmetic
    # anchor).  Filtering at s0 would delete that anchor whenever s0 is not
    # a multiple of m, leaving the smallest bucket *above* s0 — early
    # warmup steps would silently run longer than configured.
    floor = s0 if s0 < m else s0 - s0 % m
    ladder = sorted(set(x for x in ladder if floor <= x <= s1 or x == s1))
    return tuple(ladder)


def quantize(raw: float, ladder: Sequence[int]) -> int:
    """Largest ladder value <= raw (paper's round-*down* semantics);
    clamps to the smallest bucket."""
    i = bisect.bisect_right(ladder, raw) - 1
    return ladder[max(i, 0)]


def seqlen_at(cfg: SLWConfig, step: int, full_len: int,
              warmup_steps_hint: int = 0,
              ladder: Sequence[int] = None) -> int:
    if ladder is None:
        ladder = bucket_ladder(cfg, full_len)
    return quantize(raw_seqlen(cfg, step, full_len, warmup_steps_hint), ladder)
