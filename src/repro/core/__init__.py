"""The paper's contribution: Sequence Length Warmup + its instrumentation."""
from repro.core.batch_warmup import BatchWarmup  # noqa: F401
from repro.core.curriculum import (  # noqa: F401
    CurriculumState,
    SLWCurriculum,
    apply_seqlen,
)
from repro.core.regulators import (  # noqa: F401
    BatchSizeRegulator,
    ControllerState,
    GradNoiseBatchRegulator,
    LRScheduleRegulator,
    Regulator,
    RegulatorStack,
    SeqLenRegulator,
    StepPlan,
    StepTelemetry,
    VarianceLRThrottle,
    auto_specs,
    build_stack,
    predict_trajectory,
)
from repro.core.pacing import (  # noqa: F401
    bucket_ladder,
    quantize,
    raw_seqlen,
    seqlen_at,
)
from repro.core.recovery import (  # noqa: F401
    DivergenceDetector,
    DivergenceError,
    DivergenceEvent,
    RecoveryConfig,
    RecoveryHook,
    RecoveryRegulator,
    RollbackController,
    StateRing,
)
from repro.core.stability import (  # noqa: F401
    LossRatioTracker,
    momentum_stats,
    pearson,
    variance_stats,
)
from repro.core.tuning import (  # noqa: F401
    TuneResult,
    significant_fluctuation,
    tune_slw,
)
