"""GPT-3-style batch-size warmup — the related work the paper compares
against (§5.1) and finds provides *no* stability benefit.

Start at ``start_batch`` and grow linearly (in tokens) to the full batch over
``warmup_tokens``.  The method's structural limitation discussed in the paper
is enforced here: the batch must be a multiple of the data-parallel size, so
on a large mesh the warmup is quantized coarsely (vs SLW's fixed "multiple of
8/128" that is independent of the mesh).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.configs.base import BatchWarmupConfig


def quantize_batch(raw: float, dp_size: int, min_batch: int,
                   full_batch: int) -> int:
    """Round down to a multiple of the data-parallel size and clip to
    [max(min_batch, dp_size), full_batch] — the paper's §5.1 structural
    constraint, shared by every batch-sizing regulator."""
    b = int(raw) - int(raw) % dp_size
    return int(np.clip(b, max(min_batch, dp_size), full_batch))


@dataclass
class BatchWarmup:
    cfg: BatchWarmupConfig
    full_batch: int
    dp_size: int = 1  # the "multiple of data-parallel size" constraint

    def batch_for_tokens(self, tokens_seen: int) -> int:
        if not self.cfg.enabled:
            return self.full_batch
        frac = min(tokens_seen / max(self.cfg.warmup_tokens, 1), 1.0)
        raw = self.cfg.start_batch + frac * (self.full_batch
                                             - self.cfg.start_batch)
        return quantize_batch(raw, self.dp_size, self.cfg.start_batch,
                              self.full_batch)

    def apply(self, batch: Dict[str, np.ndarray], tokens_seen: int
              ) -> Tuple[Dict[str, np.ndarray], int]:
        b = self.batch_for_tokens(tokens_seen)
        out = {k: v[:b] for k, v in batch.items()}
        first = next(iter(out.values()))
        tokens = int(np.prod(first.shape[:2])) if first.ndim >= 2 else b
        return out, tokens
