"""Composable training control plane: regulators -> one StepPlan per step.

The paper's recipe is a *joint* schedule — sequence-length warmup is what
makes the aggressive 8x-batch / 4x-40x-LR recipe trainable — yet the seed
trainer hardcoded SLW and batch warmup as mutually exclusive branches and
computed the LR out of band.  This module turns each schedule into a
``Regulator``: a small host-side state machine that reads the shared
per-step :class:`StepTelemetry` and contributes to the :class:`StepPlan`
(sequence-length bucket, batch size, LR, grad-clip scale) that the trainer
then executes mechanically.

Composition semantics (deliberately simple, so stacks stay predictable):

* ``seq_len`` and ``batch_size`` contributions fold by **min** — any
  regulator may hold the step shorter/smaller, none may exceed the full
  shape (which bounds the jit compile cache exactly as before);
* the LR schedule regulator **sets** the scheduled value; modifiers after
  it in the stack (e.g. :class:`VarianceLRThrottle`) **multiply** it.

Regulators run in stack order for both ``plan`` (before the step) and
``observe`` (after the step, with the step's realized telemetry).  All of
their state round-trips through one :class:`ControllerState`, which is the
single host-state payload the checkpoint carries — a restart mid-warmup
resumes every schedule exactly.

Beyond-paper clients of the same protocol (see PAPERS.md):

* :class:`GradNoiseBatchRegulator` — telemetry-driven batch sizing in the
  spirit of Lau et al., *Adaptive Batch Size Schedules for Distributed
  Training of Language Models*: grow the batch only while the relative
  std of the gradient norm says averaging would help.
* :class:`VarianceLRThrottle` — Kosson et al.-style warmup-free LR
  control: multiplicatively back off the LR (and grad clip) while the
  Adam variance max spikes above its trailing mean, recover when calm.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import (BatchWarmupConfig, OptimizerConfig,
                                RegulatorSpec, SLWConfig, TrainConfig)
from repro.core.batch_warmup import BatchWarmup, quantize_batch
from repro.core.curriculum import SLWCurriculum, apply_seqlen
from repro.optim.schedule import lr_at


# ---------------------------------------------------------------------------
# shared step records
# ---------------------------------------------------------------------------

@dataclass
class StepTelemetry:
    """What every regulator sees.  ``step``/``tokens_seen`` are the exact
    host-side counters; the float fields are the *last completed* step's
    observations when planning (NaN before the first step) and the current
    step's observations in ``observe``.

    ``per_leaf`` (opt-in via ``OptimizerConfig.telemetry_level ==
    "per_leaf"``) carries the fixed-size named vectors the optimizer chain
    reduced inside the jitted step — ``var_max`` / ``grad_norm`` /
    ``update_norm`` / ``param_norm`` / ``grad_to_weight``, each
    ``(n_leaves,)`` in ``leaf_labels`` order — so regulators can act on
    *which* parameter group is excursing rather than one global scalar."""

    step: int = 0
    tokens_seen: int = 0
    loss: float = float("nan")
    loss_ratio: float = float("nan")
    # grad_norm is the RAW pre-clip global norm (measured before the clip
    # scales anything) — the variance signal regulators act on.
    # grad_norm_clipped is the post-clip norm, reported separately so the
    # two can never be conflated again: under sustained clipping it
    # saturates at the clip limit and carries no noise information.
    grad_norm: float = float("nan")
    grad_norm_clipped: float = float("nan")
    var_max: float = float("nan")
    var_l1: float = float("nan")
    # gradient-noise-scale pair (NaN unless TrainConfig.gns is enabled and
    # the step realized >= 2 emulated shards): mean per-shard / full-batch
    # squared gradient norms and the shard/batch sizes they were measured
    # at — everything GNSEstimator needs for the unbiased B_noise estimate
    gns_small_sq: float = float("nan")
    gns_big_sq: float = float("nan")
    gns_b_small: float = float("nan")
    gns_b_big: float = float("nan")
    per_leaf: Optional[Dict[str, np.ndarray]] = None
    leaf_labels: Tuple[str, ...] = ()


@dataclass
class StepPlan:
    """The control decision for one step, executed by the trainer."""

    seq_len: int
    batch_size: int
    lr: float
    grad_clip_scale: float = 1.0


@dataclass
class ControllerState:
    """Unified checkpointable state of the whole control plane (replaces the
    per-object ``state_dict`` plumbing: curriculum + tracker + ad-hoc
    counters each riding the checkpoint separately)."""

    step: int = 0
    tokens_seen: int = 0
    regulators: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    tracker: Dict[str, Any] = field(default_factory=dict)

    def to_host(self) -> Dict[str, Any]:
        return {"step": self.step, "tokens_seen": self.tokens_seen,
                "regulators": self.regulators, "tracker": self.tracker}

    @classmethod
    def from_host(cls, d: Dict[str, Any]) -> "ControllerState":
        return cls(step=int(d.get("step", 0)),
                   tokens_seen=int(d.get("tokens_seen", 0)),
                   regulators=dict(d.get("regulators", {})),
                   tracker=dict(d.get("tracker", {})))


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------

class Regulator:
    """Base class; regulators override what they need.  ``name`` keys the
    regulator's slice of :class:`ControllerState` and must be unique within
    a stack."""

    name: str = "regulator"

    def plan(self, tele: StepTelemetry, plan: StepPlan) -> StepPlan:
        return plan

    def observe(self, tele: StepTelemetry, tokens_step: int) -> None:
        pass

    def state_dict(self) -> Dict[str, Any]:
        return {}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        pass


class SeqLenRegulator(Regulator):
    """SLW curriculum (paper §4) on the protocol: pacing function + bucket
    ladder + the variance gate, state-carried by the wrapped curriculum."""

    name = "seqlen"

    def __init__(self, cfg: SLWConfig, full_seq: int,
                 warmup_steps_hint: int = 0, prefix_tokens: int = 0):
        self.cfg = cfg
        self.curriculum = SLWCurriculum(
            cfg, full_seq, warmup_steps_hint=warmup_steps_hint,
            prefix_tokens=prefix_tokens)

    @property
    def mode(self) -> str:
        return self.cfg.mode

    def plan(self, tele: StepTelemetry, plan: StepPlan) -> StepPlan:
        plan.seq_len = min(plan.seq_len, self.curriculum.seqlen_for_step())
        return plan

    def observe(self, tele: StepTelemetry, tokens_step: int) -> None:
        if self.cfg.pacing == "variance_gated" and math.isfinite(tele.var_max):
            self.curriculum.observe(tele.var_max)
        self.curriculum.step_complete(tokens_step)

    def state_dict(self) -> Dict[str, Any]:
        return self.curriculum.state_dict()

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.curriculum.load_state_dict(d)


class BatchSizeRegulator(Regulator):
    """GPT-3-style linear batch warmup (paper §5.1 baseline), quantized to
    the data-parallel size — the method's structural limitation on a large
    mesh, now actually engaged because the trainer passes ``dp_size``."""

    name = "batch_warmup"

    def __init__(self, cfg: BatchWarmupConfig, full_batch: int,
                 dp_size: int = 1):
        self.warmup = BatchWarmup(cfg, full_batch, dp_size=dp_size)

    def plan(self, tele: StepTelemetry, plan: StepPlan) -> StepPlan:
        plan.batch_size = min(plan.batch_size,
                              self.warmup.batch_for_tokens(tele.tokens_seen))
        return plan


class LRScheduleRegulator(Regulator):
    """Token-wise (paper A.2) / step-wise / constant LR schedule.  Sets the
    scheduled value; place multiplicative modifiers after it."""

    name = "lr"

    def __init__(self, cfg: OptimizerConfig):
        self.cfg = cfg

    def plan(self, tele: StepTelemetry, plan: StepPlan) -> StepPlan:
        plan.lr = lr_at(self.cfg, tele.step, tele.tokens_seen)
        return plan


class GradNoiseBatchRegulator(Regulator):
    """Adaptive batch sizing from gradient-norm noise (beyond-paper).

    Tracks EMA mean/second-moment of the **raw pre-clip** gradient norm;
    while the relative std exceeds ``noise_target`` (gradient estimates are
    noisy, so more averaging pays for itself — the critical-batch-size
    argument), grows the batch multiplicatively.  Monotone non-decreasing,
    quantized to the data-parallel size, capped at the full batch.

    The pre-clip contract matters: a *post*-clip norm saturates at the clip
    limit whenever training clips persistently, so its relative std reads
    ~0 and the regulator never grows — the global clip would erase exactly
    the noise signal being regulated on.  ``StepTelemetry.grad_norm`` is
    that raw norm (``clip_global_norm`` measures before scaling and reports
    the post-clip value separately as ``grad_norm_clipped``); the
    regression test pinning this is in ``tests/test_regulators.py``.

    Superseded by the measured-noise-scale ``critical_batch`` kind
    (``repro.gns.regulator``) when ``TrainConfig.gns`` is enabled.
    """

    name = "grad_noise_batch"

    def __init__(self, spec: RegulatorSpec, full_batch: int, dp_size: int = 1):
        self.spec = spec
        self.full_batch = full_batch
        self.dp_size = max(dp_size, 1)
        self.batch = self._quantize(spec.min_batch or full_batch // 8)
        self.ema_g = 0.0
        self.ema_g2 = 0.0
        self.n_obs = 0

    def _quantize(self, b: float) -> int:
        return quantize_batch(b, self.dp_size, self.dp_size, self.full_batch)

    def plan(self, tele: StepTelemetry, plan: StepPlan) -> StepPlan:
        plan.batch_size = min(plan.batch_size, self.batch)
        return plan

    def observe(self, tele: StepTelemetry, tokens_step: int) -> None:
        g = tele.grad_norm  # raw pre-clip norm — see the class docstring
        if not math.isfinite(g):
            return
        if self.n_obs == 0:
            # seed at the first observation — zero-init EMAs would read as
            # huge relative variance and trigger spurious growth
            self.ema_g, self.ema_g2 = g, g * g
        else:
            a = 2.0 / (self.spec.noise_window + 1.0)
            self.ema_g = (1 - a) * self.ema_g + a * g
            self.ema_g2 = (1 - a) * self.ema_g2 + a * g * g
        self.n_obs += 1
        if self.n_obs < self.spec.noise_window:
            return  # EMAs not warmed up yet
        var = max(self.ema_g2 - self.ema_g ** 2, 0.0)
        rel_std = math.sqrt(var) / max(self.ema_g, 1e-12)
        if rel_std > self.spec.noise_target and self.batch < self.full_batch:
            self.batch = self._quantize(
                max(self.batch * self.spec.growth, self.batch + self.dp_size))

    def state_dict(self) -> Dict[str, Any]:
        return {"batch": self.batch, "ema_g": self.ema_g,
                "ema_g2": self.ema_g2, "n_obs": self.n_obs}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.batch = int(d["batch"])
        self.ema_g = float(d["ema_g"])
        self.ema_g2 = float(d["ema_g2"])
        self.n_obs = int(d["n_obs"])


class VarianceLRThrottle(Regulator):
    """Warmup-free LR control (beyond-paper): back the LR off
    multiplicatively while the Adam variance max spikes above ``gate`` x its
    trailing mean — the paper's §3 spike precursor — and recover when calm.
    Also tightens the grad clip by the same factor while throttled.

    When the step runs with per-leaf telemetry, the gate is evaluated
    *per parameter group* against per-leaf trailing means (Molybog et
    al.'s per-component precursor), and ``blamed`` names the group with
    the largest excursion ratio — the answer to "which layer is unstable"
    that the global scalar could never give."""

    name = "var_lr_throttle"

    # per-leaf vectors the gate watches: ``var_max`` is the paper's spike
    # precursor; ``grad_norm`` is reduced from the *raw* (pre-clip) grads,
    # so a gradient explosion the global clip normalizes away — invisible
    # to the Adam variance — still trips the gate and names its leaf
    GATE_KEYS = ("var_max", "grad_norm")

    def __init__(self, spec: RegulatorSpec):
        self.spec = spec
        self.scale = 1.0
        self.trailing = 0.0
        self.leaf_trailing: Dict[str, np.ndarray] = {}
        self.blamed = ""
        self.blamed_ratio = 0.0

    def plan(self, tele: StepTelemetry, plan: StepPlan) -> StepPlan:
        plan.lr *= self.scale
        plan.grad_clip_scale *= self.scale
        return plan

    def _observe_per_leaf(self, tele: StepTelemetry) -> Optional[bool]:
        """Per-leaf gate.  Returns None when per-leaf telemetry is absent
        or unusable, else whether any leaf excursed (and records blame)."""
        if tele.per_leaf is None:
            return None
        usable = spiking = False
        for key in self.GATE_KEYS:
            v = tele.per_leaf.get(key)
            if v is None:
                continue
            v = np.asarray(v, np.float64)
            if not np.all(np.isfinite(v)):
                continue
            usable = True
            trail = self.leaf_trailing.get(key)
            if trail is None or trail.shape != v.shape:
                self.leaf_trailing[key] = v.copy()
                continue
            ratios = v / np.maximum(trail, 1e-30)
            if bool(np.any(ratios > self.spec.gate)):
                spiking = True
                if float(np.max(ratios)) > self.blamed_ratio:
                    # keep the blame of the *largest* excursion seen, not
                    # the latest: the layer that started a divergence spikes
                    # orders of magnitude harder than the downstream
                    # turbulence it causes
                    from repro.core.telemetry import blame
                    worst = blame(tele.leaf_labels, ratios)
                    if worst:
                        self.blamed = worst
                        self.blamed_ratio = float(np.max(ratios))
            self.leaf_trailing[key] = 0.9 * trail + 0.1 * v
        return spiking if usable else None

    def observe(self, tele: StepTelemetry, tokens_step: int) -> None:
        spiking = self._observe_per_leaf(tele)
        v = tele.var_max
        if spiking is None:
            if not math.isfinite(v):
                return
            if self.trailing == 0.0:
                self.trailing = v
            spiking = v > self.spec.gate * self.trailing
            self.trailing = 0.9 * self.trailing + 0.1 * v
        if spiking:
            self.scale = max(self.scale * self.spec.backoff, self.spec.floor)
        else:
            self.scale = min(self.scale * self.spec.recovery, 1.0)

    def state_dict(self) -> Dict[str, Any]:
        return {"scale": self.scale, "trailing": self.trailing,
                "leaf_trailing": {k: v.tolist()
                                  for k, v in self.leaf_trailing.items()},
                "blamed": self.blamed, "blamed_ratio": self.blamed_ratio}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.scale = float(d["scale"])
        self.trailing = float(d["trailing"])
        lt = d.get("leaf_trailing")
        self.leaf_trailing = ({k: np.asarray(v, np.float64)
                               for k, v in lt.items()}
                              if isinstance(lt, dict) else {})
        self.blamed = str(d.get("blamed", ""))
        self.blamed_ratio = float(d.get("blamed_ratio", 0.0))


# ---------------------------------------------------------------------------
# the stack
# ---------------------------------------------------------------------------

class RegulatorStack:
    """Ordered regulators + plan execution.  The trainer's whole control
    surface: ``plan`` before the step, ``apply`` the plan to the host batch,
    ``observe`` after, ``controller_state`` into the checkpoint."""

    def __init__(self, regulators: Sequence[Regulator], full_seq: int,
                 full_batch: int, base_lr: float):
        names = [r.name for r in regulators]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate regulator names: {names}")
        self.regulators = list(regulators)
        self.full_seq = full_seq
        self.full_batch = full_batch
        self.base_lr = base_lr

    def __getitem__(self, name: str) -> Regulator:
        for r in self.regulators:
            if r.name == name:
                return r
        raise KeyError(name)

    def __contains__(self, name: str) -> bool:
        return any(r.name == name for r in self.regulators)

    @property
    def seq_mode(self) -> str:
        return (self["seqlen"].mode if "seqlen" in self else "truncate")

    def plan(self, tele: StepTelemetry) -> StepPlan:
        p = StepPlan(seq_len=self.full_seq, batch_size=self.full_batch,
                     lr=self.base_lr)
        for r in self.regulators:
            p = r.plan(tele, p)
        return p

    def apply(self, batch: Dict[str, np.ndarray], plan: StepPlan
              ) -> Tuple[Dict[str, np.ndarray], int]:
        """Execute the plan host-side: row-slice to the batch size, then
        truncate/repack to the seqlen bucket.  Returns (batch, tokens)."""
        first = next(iter(batch.values()))
        if plan.batch_size < first.shape[0]:
            batch = {k: v[:plan.batch_size] for k, v in batch.items()}
        return apply_seqlen(batch, plan.seq_len, self.seq_mode)

    def observe(self, tele: StepTelemetry, tokens_step: int) -> None:
        for r in self.regulators:
            r.observe(tele, tokens_step)

    # -- unified checkpoint state -------------------------------------------
    def controller_state(self, step: int, tokens_seen: int,
                         tracker_state: Optional[Dict[str, Any]] = None
                         ) -> ControllerState:
        return ControllerState(
            step=step, tokens_seen=tokens_seen,
            regulators={r.name: r.state_dict() for r in self.regulators},
            tracker=tracker_state or {})

    def load_controller_state(self, cs: ControllerState) -> None:
        for r in self.regulators:
            if r.name in cs.regulators:
                r.load_state_dict(cs.regulators[r.name])


# ---------------------------------------------------------------------------
# construction from config
# ---------------------------------------------------------------------------

def auto_specs(tc: TrainConfig) -> Tuple[RegulatorSpec, ...]:
    """Back-compat derivation from the legacy configs: the enabled legacy
    schedules compose (they no longer exclude each other) and the LR
    schedule always runs."""
    specs: List[RegulatorSpec] = []
    if tc.slw.enabled:
        specs.append(RegulatorSpec(kind="seqlen"))
    if tc.batch_warmup.enabled:
        specs.append(RegulatorSpec(kind="batch_warmup"))
    specs.append(RegulatorSpec(kind="lr"))
    return tuple(specs)


def build_stack(tc: TrainConfig, *, dp_size: int = 1,
                warmup_steps_hint: int = 0,
                prefix_tokens: int = 0) -> RegulatorStack:
    """Build the control plane for a TrainConfig.  ``tc.regulators`` is the
    explicit stack; empty means :func:`auto_specs` (legacy derivation)."""
    specs = tc.regulators or auto_specs(tc)
    regs: List[Regulator] = []
    for spec in specs:
        if spec.kind == "seqlen":
            regs.append(SeqLenRegulator(
                tc.slw, tc.seq_len, warmup_steps_hint=warmup_steps_hint,
                prefix_tokens=prefix_tokens))
        elif spec.kind == "batch_warmup":
            regs.append(BatchSizeRegulator(tc.batch_warmup, tc.global_batch,
                                           dp_size=dp_size))
        elif spec.kind == "lr":
            regs.append(LRScheduleRegulator(tc.optimizer))
        elif spec.kind == "grad_noise_batch":
            regs.append(GradNoiseBatchRegulator(spec, tc.global_batch,
                                                dp_size=dp_size))
        elif spec.kind == "var_lr_throttle":
            regs.append(VarianceLRThrottle(spec))
        elif spec.kind == "critical_batch":
            # deferred import: repro.gns depends on this module's protocol
            from repro.gns.regulator import CriticalBatchRegulator
            regs.append(CriticalBatchRegulator(tc.gns, tc.global_batch,
                                               dp_size=dp_size))
        else:
            raise ValueError(f"unknown regulator kind {spec.kind!r}")
    return RegulatorStack(regs, full_seq=tc.seq_len,
                          full_batch=tc.global_batch, base_lr=tc.optimizer.lr)


def predict_trajectory(tc: TrainConfig, n_steps: int, *, dp_size: int = 1,
                       warmup_steps_hint: int = 0, prefix_tokens: int = 0
                       ) -> List[StepPlan]:
    """Replay the stack's open-loop trajectory without training: the exact
    (seq_len, batch, lr) sequence the trainer will execute when no
    telemetry-driven regulator intervenes.  Telemetry-driven regulators see
    *calm* synthetic telemetry (constant unit var_max/grad_norm), so e.g.
    variance_gated pacing replays its calm-run envelope rather than sitting
    at the smallest bucket forever on NaN observations.  Token accounting
    mirrors the trainer's truncate-mode counting (batch * seqlen per
    step)."""
    stack = build_stack(tc, dp_size=dp_size,
                        warmup_steps_hint=warmup_steps_hint,
                        prefix_tokens=prefix_tokens)
    plans: List[StepPlan] = []
    tokens = 0
    for step in range(n_steps):
        tele = StepTelemetry(step=step, tokens_seen=tokens,
                             var_max=1.0, var_l1=1.0, grad_norm=1.0)
        plan = stack.plan(tele)
        plans.append(plan)
        if stack.seq_mode == "repack":
            folds = max(tc.seq_len // plan.seq_len, 1)
            tokens_step = plan.batch_size * folds * plan.seq_len
        else:
            tokens_step = plan.batch_size * plan.seq_len
        stack.observe(dataclasses.replace(tele), tokens_step)
        tokens += tokens_step
    return plans
