"""The paper's low-cost hyperparameter tuning strategy (Section 4).

    (1) start with seqlen_s = 8 and T = a few multiples of the LR warmup;
    (2) increase seqlen_s until validation perplexity no longer has
        significant fluctuation at the very beginning;
    (3) binary-search the largest T with no significant fluctuation during
        the first few multiples of LR warmup steps,

where "significant fluctuation" = validation perplexity > 1.3x the previous
best (the paper's heuristic).  Only the probe window is trained — a small
fraction of the full pre-training cost.

The probe is injected as a callable so the same tuner drives tiny CPU runs
(benchmarks) and full-scale launches (``launch/train.py --tune``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

from repro.configs.base import SLWConfig

# probe(slw_cfg) -> list of validation perplexities sampled during the probe
# window (e.g. every eval_interval steps for the first N steps).
ProbeFn = Callable[[SLWConfig], List[float]]


def significant_fluctuation(ppls: Sequence[float],
                            threshold: float = 1.3) -> bool:
    """Paper §4: perplexity exceeding `threshold` x the previous best."""
    best = float("inf")
    for p in ppls:
        if p > threshold * best:
            return True
        best = min(best, p)
    return False


@dataclass
class TuneResult:
    seqlen_s: int
    duration: int
    trials: List[Tuple[int, int, bool]]  # (seqlen_s, T, fluctuated)

    @property
    def probe_runs(self) -> int:
        return len(self.trials)


def tune_slw(probe: ProbeFn, base: SLWConfig, warmup_steps: int,
             seqlen_s_grid: Sequence[int] = (8, 16, 32, 64),
             t_multiple_range: Tuple[int, int] = (1, 16),
             fluctuation_threshold: float = 1.3) -> TuneResult:
    """Implements the 3-step recipe. Cost: O(len(grid) + log(range)) probe
    runs, each only `probe`'s window long — no full trainings."""
    trials: List[Tuple[int, int, bool]] = []

    def fluctuates(s0: int, t: int) -> bool:
        cfg = base.replace_slw(start_seq_len=s0, duration_steps=t) \
            if hasattr(base, "replace_slw") else _replace(base, s0, t)
        bad = significant_fluctuation(probe(cfg), fluctuation_threshold)
        trials.append((s0, t, bad))
        return bad

    # step 1+2: smallest seqlen_s with a calm start, at the shortest duration
    t0 = max(t_multiple_range[0] * warmup_steps, 1)
    seqlen_s = seqlen_s_grid[-1]
    for s0 in seqlen_s_grid:
        if not fluctuates(s0, t0):
            seqlen_s = s0
            break

    # step 3: binary search the largest calm T in [lo, hi] * warmup_steps
    lo, hi = t_multiple_range
    best = lo
    while lo <= hi:
        mid = (lo + hi) // 2
        if fluctuates(seqlen_s, mid * warmup_steps):
            hi = mid - 1
        else:
            best = mid
            lo = mid + 1
    return TuneResult(seqlen_s=seqlen_s, duration=best * warmup_steps,
                      trials=trials)


def _replace(cfg: SLWConfig, s0: int, t: int) -> SLWConfig:
    import dataclasses
    return dataclasses.replace(cfg, start_seq_len=s0, duration_steps=t,
                               enabled=True)
