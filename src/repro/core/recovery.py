"""Divergence-aware recovery: detect, roll back, intervene, retry.

The paper's central failure mode — a large-batch/large-LR run that silently
diverges (loss spike -> NaN) and wastes everything since the last good
state — is a *telemetry* problem before it is a checkpoint problem: the
``var_max`` series the trainer already collects spikes ahead of the loss
(§3 correlation; Molybog et al.'s Adam-instability analysis in PAPERS.md),
and the loss-ratio tracker flags the spike itself.  ``TrainSupervisor``
only reacts to Python exceptions, so a diverging-but-running step stream
sails straight through it.  This module closes that gap in-process:

* :class:`DivergenceDetector` — per-step classification of the realized
  :class:`StepTelemetry` into ``nan_loss`` / ``nan_grad`` / ``loss_spike``
  / ``var_excursion`` events (NaN always fires; the soft triggers carry a
  grace period and a post-rollback cooldown so replayed steps and early
  noise don't retrigger).
* :class:`StateRing` — a short in-run ring of host-side snapshots
  (train-state pytree + ``ControllerState`` + last telemetry), pushed only
  on detector-clean steps, so a rollback never needs to touch disk.
* :class:`RecoveryRegulator` — the intervention surface, living *inside*
  the regulator stack so its state checkpoints/resumes through the same
  ``ControllerState`` as every schedule: a multiplicative LR/grad-clip
  backoff, a seq-len clamp measured in bucket-ladder rungs, and a data
  window offset (skip the offending batches).
* :class:`RollbackController` — ties it together: on an event, restore the
  newest valid snapshot, re-seat the controller state (schedules resume
  exactly), apply the next rung of the escalation ladder
  (deepen LR backoff -> clamp seq-len one rung -> skip the data window),
  bounded by a :class:`~repro.distributed.fault_tolerance.RetryPolicy`
  shared with the process-level ``TrainSupervisor``.
* :class:`RecoveryHook` — the trainer wiring (duck-typed ``TrainerHook``):
  feed the detector after each step, push ring snapshots, trigger
  rollbacks, clear the trainer's divergence stop when recovery succeeds.

Every path here is exercised by deterministic fault injection
(``repro.distributed.fault_injection``) rather than assumed.
"""
from __future__ import annotations

import copy
import dataclasses
import math
import os
import shutil
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.regulators import (ControllerState, Regulator, StepPlan,
                                   StepTelemetry)
from repro.distributed.fault_tolerance import RetryPolicy


class DivergenceError(RuntimeError):
    """In-process recovery exhausted its retry budget (hard failure).

    Raised (when ``RecoveryConfig.escalate == "raise"``) so a wrapping
    ``TrainSupervisor`` can take over with a process-level restart — the
    two layers share one ``RetryPolicy`` notion of "how many times".
    """


@dataclass(frozen=True)
class DivergenceEvent:
    kind: str  # nan_loss | nan_grad | loss_spike | var_excursion
    step: int
    detail: str = ""

    def __str__(self) -> str:
        return f"{self.kind}@{self.step}({self.detail})"


@dataclass(frozen=True)
class RecoveryConfig:
    """Thresholds + intervention parameters for the rollback controller."""

    # detector
    spike_ratio: float = 3.0     # loss / running-min-loss that counts as
                                 # divergence (tracker's >1.2 is a *spike*;
                                 # recovery acts on the catastrophic ones)
    var_gate: float = 8.0        # var_max vs trailing mean excursion gate
    var_sustain: int = 4         # consecutive excursion steps before firing
    grace_steps: int = 5         # soft triggers silent this many first obs
    cooldown_steps: int = 3      # soft triggers silent after a rollback
    # snapshot ring
    snapshot_interval: int = 5   # steps between ring snapshots
    ring: int = 3                # snapshots kept in memory
    ring_dir: str = ""           # spill the ring here on drain; "" derives
                                 # <checkpoint_dir>/ring when one exists
    # escalation ladder
    lr_backoff: float = 0.5      # recovery LR scale multiplier per rung-1 hit
    lr_floor: float = 0.05       # never scale the LR below this
    skip_window_steps: int = 4   # data batches skipped at rung 3
    # retry budget (shared shape with TrainSupervisor)
    policy: RetryPolicy = RetryPolicy(max_retries=3)
    # on exhaustion: "stop" marks the run diverged and halts the loop;
    # "raise" surfaces DivergenceError (for TrainSupervisor pairing)
    escalate: str = "stop"


class DivergenceDetector:
    """Classifies per-step telemetry into divergence events.

    NaN/inf loss or grad norm fires unconditionally.  The two soft triggers
    (loss-ratio spike, sustained var_max excursion) observe a grace period
    at start and a cooldown after each rollback, and the var trailing mean
    is only updated with non-excursion samples so the gate does not chase
    the spike it is supposed to catch.
    """

    def __init__(self, cfg: RecoveryConfig):
        self.cfg = cfg
        self.n_obs = 0
        self.cooldown = 0
        self.var_trailing = 0.0
        self.var_streak = 0
        # per-leaf blame (per-parameter telemetry, when the step emits it):
        # trailing mean per labeled leaf + the group blamed for the last
        # var excursion, so the seq-clamp/data-skip rungs know *which*
        # component diverged, not just that one did.  var_max is the
        # paper's precursor; raw-grad leaf norms catch explosions the
        # global clip normalizes away before Adam's variance sees them.
        self.leaf_trailing: Dict[str, np.ndarray] = {}
        self.blamed = ""

    GATE_KEYS = ("var_max", "grad_norm")

    def begin_cooldown(self) -> None:
        self.cooldown = self.cfg.cooldown_steps
        self.var_streak = 0

    def _leaf_blame(self, tele: StepTelemetry, update_trailing: bool) -> str:
        """Track per-leaf trailing var_max / raw-grad norms; return the
        label of the worst excursion above the gate ('' when telemetry is
        absent or every leaf is calm)."""
        if tele.per_leaf is None:
            return ""
        worst, worst_ratio = "", 0.0
        for key in self.GATE_KEYS:
            v = tele.per_leaf.get(key)
            if v is None:
                continue
            v = np.asarray(v, np.float64)
            if not np.all(np.isfinite(v)):
                continue
            trail = self.leaf_trailing.get(key)
            if trail is None or trail.shape != v.shape:
                self.leaf_trailing[key] = v.copy()
                continue
            ratios = v / np.maximum(trail, 1e-30)
            if np.any(ratios > self.cfg.var_gate) \
                    and float(np.max(ratios)) > worst_ratio:
                from repro.core.telemetry import blame
                label = blame(tele.leaf_labels, ratios)
                if label:
                    worst, worst_ratio = label, float(np.max(ratios))
            if update_trailing:
                self.leaf_trailing[key] = 0.9 * trail + 0.1 * v
        return worst

    def update(self, tele: StepTelemetry) -> Optional[DivergenceEvent]:
        self.n_obs += 1
        if not math.isfinite(tele.loss):
            return DivergenceEvent("nan_loss", tele.step,
                                   f"loss={tele.loss}")
        if not math.isfinite(tele.grad_norm):
            return DivergenceEvent("nan_grad", tele.step,
                                   f"grad_norm={tele.grad_norm}")
        if self.cooldown > 0:
            self.cooldown -= 1
            return None
        if self.n_obs <= self.cfg.grace_steps:
            if math.isfinite(tele.var_max):
                self.var_trailing = (tele.var_max if self.var_trailing == 0.0
                                     else 0.9 * self.var_trailing
                                     + 0.1 * tele.var_max)
            self._leaf_blame(tele, update_trailing=True)
            return None
        if math.isfinite(tele.loss_ratio) \
                and tele.loss_ratio > self.cfg.spike_ratio:
            blamed = self._leaf_blame(tele, update_trailing=False)
            if blamed:
                self.blamed = blamed
            return DivergenceEvent(
                "loss_spike", tele.step,
                f"ratio={tele.loss_ratio:.2f}>{self.cfg.spike_ratio}"
                + (f" leaf={blamed}" if blamed else ""))
        if math.isfinite(tele.var_max) and self.var_trailing > 0.0 \
                and tele.var_max > self.cfg.var_gate * self.var_trailing:
            self.var_streak += 1
            # the leaf trailing mean is *not* chased during a streak, for
            # the same reason the global one is not
            blamed = self._leaf_blame(tele, update_trailing=False)
            if blamed:
                self.blamed = blamed
            if self.var_streak >= self.cfg.var_sustain:
                return DivergenceEvent(
                    "var_excursion", tele.step,
                    f"var_max={tele.var_max:.3g}>"
                    f"{self.cfg.var_gate}x{self.var_trailing:.3g}"
                    f" for {self.var_streak}"
                    + (f" leaf={self.blamed}" if self.blamed else ""))
            return None
        self.var_streak = 0
        if math.isfinite(tele.var_max):
            self.var_trailing = (tele.var_max if self.var_trailing == 0.0
                                 else 0.9 * self.var_trailing
                                 + 0.1 * tele.var_max)
        self._leaf_blame(tele, update_trailing=True)
        return None


@dataclass
class Snapshot:
    """One host-side restore point (everything a rollback re-seats)."""

    step: int
    tokens_seen: int
    state: Any                    # train-state pytree of np.ndarray copies
    controller: Dict[str, Any]    # ControllerState.to_host() deep copy
    telemetry: StepTelemetry      # trainer's _last (plan inputs resume too)


def _telemetry_to_host(tele: StepTelemetry) -> Dict[str, Any]:
    """JSON-safe dict for a ring manifest (per-leaf vectors -> lists)."""
    from repro.core.telemetry import per_leaf_to_host
    d = dataclasses.asdict(tele)
    d["leaf_labels"] = list(tele.leaf_labels)
    d["per_leaf"] = (per_leaf_to_host(tele.per_leaf)
                     if tele.per_leaf is not None else None)
    return d


def _telemetry_from_host(d: Dict[str, Any]) -> StepTelemetry:
    from repro.core.telemetry import per_leaf_from_host
    d = dict(d)
    pl = d.pop("per_leaf", None)
    labels = tuple(d.pop("leaf_labels", ()))
    fields = {f.name for f in dataclasses.fields(StepTelemetry)}
    kept = {k: v for k, v in d.items()
            if k in fields and k not in ("per_leaf", "leaf_labels")}
    return StepTelemetry(
        per_leaf=per_leaf_from_host(pl) if pl is not None else None,
        leaf_labels=labels, **kept)


class StateRing:
    """Short in-memory ring of train-state snapshots.

    Host copies (``jax.device_get``) so the donated device buffers the
    train step recycles are never aliased; restoring hands back fresh
    ``jnp`` arrays, so the ring entry survives repeated rollbacks to the
    same point.

    :meth:`save` / :meth:`load` spill/restore the ring through the
    checkpoint module (one atomic, crc-validated ``step_*`` directory per
    snapshot under a ``ring/`` sibling of the checkpoint dir), so a drained
    preemption keeps its in-run restore points: ``--recover`` resumes with
    the same rollback candidates it had when the SIGTERM landed.
    """

    def __init__(self, capacity: int = 3):
        self.capacity = max(capacity, 1)
        self._ring: Deque[Snapshot] = deque(maxlen=self.capacity)

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def steps(self) -> List[int]:
        return [s.step for s in self._ring]

    def push(self, step: int, tokens_seen: int, state: Any,
             controller: ControllerState, telemetry: StepTelemetry) -> None:
        host_state = jax.tree_util.tree_map(
            lambda x: np.array(jax.device_get(x)), state)
        self._ring.append(Snapshot(
            step=step, tokens_seen=tokens_seen, state=host_state,
            controller=copy.deepcopy(controller.to_host()),
            telemetry=dataclasses.replace(telemetry)))

    def newest(self) -> Optional[Snapshot]:
        return self._ring[-1] if self._ring else None

    def drop_newest(self) -> None:
        if self._ring:
            self._ring.pop()

    def materialize(self, snap: Snapshot) -> Any:
        """Fresh device arrays from a snapshot (safe to donate)."""
        return jax.tree_util.tree_map(jnp.asarray, snap.state)

    # -- disk persistence (drain / --recover) --------------------------------
    def save(self, directory: str) -> List[int]:
        """Spill every ring snapshot to ``directory`` (atomic per-snapshot
        checkpoint dirs; already-persisted steps are skipped, stale ones
        pruned).  Returns the persisted step list."""
        from repro.checkpoint import checkpoint as ckpt_lib
        on_disk = set(ckpt_lib.available_steps(directory))
        for snap in self._ring:
            if snap.step in on_disk:
                continue
            ckpt_lib.save(directory, snap.step, snap.state, {
                "ring": True,
                "tokens_seen": snap.tokens_seen,
                "controller": snap.controller,
                "telemetry": _telemetry_to_host(snap.telemetry),
            })
        keep = set(self.steps)
        for step in on_disk - keep:
            shutil.rmtree(os.path.join(directory, f"step_{step:012d}"),
                          ignore_errors=True)
        return self.steps

    def load(self, directory: str, like: Any) -> int:
        """Refill the ring from a :meth:`save` spill (oldest first, newest
        ``capacity`` kept).  ``like`` is the abstract train-state tree the
        snapshots restore into; corrupt entries are skipped — the ring is a
        best-effort optimization over the real checkpoint, never a reason
        to fail a resume.  Returns the number of snapshots restored."""
        from repro.checkpoint import checkpoint as ckpt_lib
        steps = sorted(ckpt_lib.available_steps(directory))[-self.capacity:]
        n = 0
        for step in steps:
            try:
                tree, host = ckpt_lib.restore(directory, step, like)
            except (ckpt_lib.CheckpointCorruption, ValueError):
                continue
            self._ring.append(Snapshot(
                step=step,
                tokens_seen=int(host.get("tokens_seen", 0)),
                state=tree,
                controller=dict(host.get("controller", {})),
                telemetry=_telemetry_from_host(host.get("telemetry", {}))))
            n += 1
        return n


class RecoveryRegulator(Regulator):
    """The intervention surface, as a regulator so it checkpoints.

    Placed at the end of the stack: the LR schedule has already set the
    scheduled value (``lr_scale`` multiplies it, like the variance
    throttle), seq_len folds by min against the ladder clamp, and
    ``data_offset`` is read by the trainer when indexing the data pipeline.
    All three persist through ``ControllerState`` — a restart resumes the
    intervention exactly, not just the schedules it protects.
    """

    name = "recovery"

    def __init__(self, ladder: Tuple[int, ...], cfg: RecoveryConfig):
        self.ladder = tuple(ladder)
        self.cfg = cfg
        self.lr_scale = 1.0
        self.seq_drop = 0       # bucket-ladder rungs to clamp down
        self.data_offset = 0    # extra batches skipped in the data stream
        # per-leaf LR backoff: label -> multiplicative scale, applied by
        # the chain as hyper["leaf_lr_scale"] so rung 1 can act on the
        # *blamed* layer group before touching the global multiplier
        self.leaf_lr_scales: Dict[str, float] = {}
        # precursor-driven pre-emptive cooldown: a temporary global LR
        # scale with a step TTL (the early warning fired before any
        # divergence — cool the whole run briefly instead of escalating)
        self.cool_scale = 1.0
        self.cool_ttl = 0

    # -- escalation ladder ---------------------------------------------------
    def deepen_lr(self, blamed: str = "") -> None:
        """Deepen the LR backoff.  With a ``blamed`` leaf label, the
        backoff lands on that leaf alone (per-leaf scale through the
        chain's runtime ``leaf_lr_scale`` vector); without one — or on
        repeat rollbacks — it falls back to the global multiplier."""
        if blamed:
            cur = self.leaf_lr_scales.get(blamed, 1.0)
            self.leaf_lr_scales[blamed] = max(
                cur * self.cfg.lr_backoff, self.cfg.lr_floor)
            return
        self.lr_scale = max(self.lr_scale * self.cfg.lr_backoff,
                            self.cfg.lr_floor)

    def clamp_seq(self) -> None:
        self.seq_drop = min(self.seq_drop + 1, len(self.ladder) - 1)

    def skip_data(self) -> None:
        self.data_offset += self.cfg.skip_window_steps

    # -- precursor cooldown --------------------------------------------------
    def precursor_cooldown(self, factor: float, steps: int) -> None:
        """Apply a temporary LR cool-down (most-severe merge: the scale
        only tightens, the TTL only extends)."""
        self.cool_scale = max(min(self.cool_scale, factor),
                              self.cfg.lr_floor)
        self.cool_ttl = max(self.cool_ttl, int(steps))

    def leaf_lr_vector(self, labels: Tuple[str, ...]):
        """(n_leaves,) f32 scale vector in label order, or None when no
        per-leaf backoff is active (so the default trace stays intact)."""
        if not self.leaf_lr_scales:
            return None
        return np.asarray([self.leaf_lr_scales.get(lbl, 1.0)
                           for lbl in labels], np.float32)

    # -- regulator protocol --------------------------------------------------
    def plan(self, tele: StepTelemetry, plan: StepPlan) -> StepPlan:
        scale = self.lr_scale
        if self.cool_ttl > 0:
            scale *= self.cool_scale
        plan.lr *= scale
        plan.grad_clip_scale *= scale
        if self.seq_drop:
            rung = 0
            for i, s in enumerate(self.ladder):
                if s <= plan.seq_len:
                    rung = i
            plan.seq_len = min(plan.seq_len,
                               self.ladder[max(rung - self.seq_drop, 0)])
        return plan

    def observe(self, tele: StepTelemetry, tokens_step: int) -> None:
        if self.cool_ttl > 0:
            self.cool_ttl -= 1
            if self.cool_ttl == 0:
                self.cool_scale = 1.0

    def state_dict(self) -> Dict[str, Any]:
        return {"lr_scale": self.lr_scale, "seq_drop": self.seq_drop,
                "data_offset": self.data_offset,
                "leaf_lr_scales": dict(self.leaf_lr_scales),
                "cool_scale": self.cool_scale, "cool_ttl": self.cool_ttl}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.lr_scale = float(d["lr_scale"])
        self.seq_drop = int(d["seq_drop"])
        self.data_offset = int(d["data_offset"])
        # keys absent in pre-PR-9 checkpoints: default to inactive
        self.leaf_lr_scales = {str(k): float(v) for k, v in
                               dict(d.get("leaf_lr_scales", {})).items()}
        self.cool_scale = float(d.get("cool_scale", 1.0))
        self.cool_ttl = int(d.get("cool_ttl", 0))


class RollbackController:
    """Restore + intervene + retry, with a bounded budget.

    The escalation ladder is cumulative across rollbacks: the first rollback
    deepens the LR backoff (and the ``VarianceLRThrottle``'s own scale when
    one is in the stack — the two throttles share the containment job), the
    second additionally clamps the seq-len plan one ladder rung down, the
    third and later also skip the offending data window.  When the retry
    budget is exhausted the controller either stops the run (``escalate ==
    "stop"``) or raises :class:`DivergenceError` for the process-level
    supervisor.
    """

    def __init__(self, cfg: Optional[RecoveryConfig] = None):
        self.cfg = cfg or RecoveryConfig()
        self.detector = DivergenceDetector(self.cfg)
        self.ring = StateRing(self.cfg.ring)
        self.rollbacks = 0
        self.events: List[str] = []
        self._last_restore_step: Optional[int] = None

    # -- snapshots -----------------------------------------------------------
    def maybe_snapshot(self, trainer) -> None:
        if trainer.step % max(self.cfg.snapshot_interval, 1) == 0 \
                or not len(self.ring):
            self.snapshot(trainer)

    def snapshot(self, trainer) -> None:
        self.ring.push(trainer.step, trainer.tokens_seen, trainer.state,
                       trainer.controller_state(), trainer._last)

    # -- the rollback --------------------------------------------------------
    def handle(self, trainer, event: DivergenceEvent) -> bool:
        """React to a divergence event.  Returns True when the run should
        continue (state restored, intervention applied), False when the
        budget is exhausted (or raises, per ``escalate``)."""
        self.events.append(str(event))
        if self.rollbacks >= self.cfg.policy.max_retries:
            self.events.append(f"gave_up@{event.step}")
            if self.cfg.escalate == "raise":
                raise DivergenceError(
                    f"recovery budget exhausted after "
                    f"{self.rollbacks} rollbacks: {event}")
            return False
        self.rollbacks += 1

        # the intervention regulator's state rides ControllerState, so a
        # restore would also rewind earlier interventions; containment
        # knobs must be monotone across rollbacks, so the pre-restore
        # values are merged back in at their most-severe side
        reg: RecoveryRegulator = trainer.stack["recovery"]
        pre = reg.state_dict()

        snap = self.ring.newest()
        if snap is not None and snap.step == self._last_restore_step \
                and len(self.ring) > 1:
            # the newest restore point failed to hold twice in a row —
            # fall back one snapshot before escalating further
            self.ring.drop_newest()
            snap = self.ring.newest()
        if snap is None:
            # no in-run snapshot yet: a disk checkpoint is the next-best
            # restore point (trainer.resume re-seats controller state too)
            if trainer.ckpt is not None and trainer.resume() is not None:
                self.events.append(f"disk_restore@{trainer.step}")
            else:
                self.events.append(f"no_restore_point@{event.step}")
                if self.cfg.escalate == "raise":
                    raise DivergenceError(f"no restore point for {event}")
                return False
        else:
            trainer.state = self.ring.materialize(snap)
            trainer.load_controller_state(
                ControllerState.from_host(copy.deepcopy(snap.controller)))
            trainer._last = dataclasses.replace(snap.telemetry)
            self._last_restore_step = snap.step
            self.events.append(f"restored@{snap.step}")

        post = reg.state_dict()
        pre_leaf = dict(pre.get("leaf_lr_scales", {}))
        post_leaf = dict(post.get("leaf_lr_scales", {}))
        reg.load_state_dict({
            "lr_scale": min(pre["lr_scale"], post["lr_scale"]),
            "seq_drop": max(pre["seq_drop"], post["seq_drop"]),
            "data_offset": max(pre["data_offset"], post["data_offset"]),
            "leaf_lr_scales": {
                lbl: min(pre_leaf.get(lbl, 1.0), post_leaf.get(lbl, 1.0))
                for lbl in set(pre_leaf) | set(post_leaf)},
            "cool_scale": min(pre.get("cool_scale", 1.0),
                              post.get("cool_scale", 1.0)),
            "cool_ttl": max(pre.get("cool_ttl", 0),
                            post.get("cool_ttl", 0)),
        })
        self._intervene(trainer)
        self.detector.begin_cooldown()
        return True

    # -- precursor (early warning, before any divergence event) --------------
    def handle_precursor(self, trainer, event, factor: float = 0.5,
                         ttl: int = 8) -> None:
        """Proactive reaction to a gradient-direction precursor: push a
        known-good snapshot *now* (the state is still healthy — the whole
        point of firing early) and apply a temporary LR cool-down instead
        of burning a rollback rung.  Costs nothing from the retry budget."""
        self.events.append(str(event))
        self.snapshot(trainer)
        if "recovery" in trainer.stack:
            trainer.stack["recovery"].precursor_cooldown(factor, ttl)

    def _intervene(self, trainer) -> None:
        reg: RecoveryRegulator = trainer.stack["recovery"]
        # rung 1 (every rollback): deepen the LR/grad-clip backoff — on
        # the *first* rollback with a blamed leaf, the backoff is scoped
        # to that leaf alone (per-leaf scale through the chain); repeat
        # rollbacks mean the scoped containment was not enough, so they
        # fall through to the global multiplier
        blamed = self.detector.blamed
        reg.deepen_lr(blamed if (blamed and self.rollbacks == 1) else "")
        if "var_lr_throttle" in trainer.stack:
            th = trainer.stack["var_lr_throttle"]
            th.scale = max(th.scale * th.spec.backoff, th.spec.floor)
        # rung 2: clamp the SLW seq-len plan one bucket down
        if self.rollbacks >= 2:
            reg.clamp_seq()
        # rung 3: skip the offending data window
        if self.rollbacks >= 3:
            reg.skip_data()


class RecoveryHook:
    """Trainer wiring (duck-typed TrainerHook; no import cycle with
    launch.train).  Ordering note: the trainer marks ``diverged``/
    ``stopping`` before hooks run, so a successful rollback clears both and
    the loop continues."""

    def __init__(self, controller: RollbackController):
        self.controller = controller

    def on_run_start(self, tr) -> None:
        # step-0 restore point: a fault before the first interval snapshot
        # must still be recoverable
        self.controller.snapshot(tr)

    def on_step_start(self, tr) -> None:
        pass

    def on_step_end(self, tr, tele: StepTelemetry, plan: StepPlan,
                    metrics: Dict[str, Any]) -> None:
        event = self.controller.detector.update(tele)
        if event is None:
            # no snapshot while a var excursion streak is building: a
            # poisoned-but-finite state must not become a restore point
            if self.controller.detector.var_streak == 0:
                self.controller.maybe_snapshot(tr)
            return
        recovered = self.controller.handle(tr, event)
        tr.result.rollbacks = self.controller.rollbacks
        tr.result.recovery_events = list(self.controller.events)
        if recovered:
            tr.stopping = False
            tr.result.diverged = False
        else:
            tr.result.diverged = True
            tr.stopping = True

    def on_run_end(self, tr) -> None:
        tr.result.rollbacks = self.controller.rollbacks
        tr.result.recovery_events = list(self.controller.events)

    def close(self) -> None:
        pass
