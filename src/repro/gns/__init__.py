"""Gradient-noise-scale subsystem: measured critical batch size +
pre-spike forecasting on the per-leaf telemetry.

Three layers (see the module docstrings):

* :mod:`repro.gns.estimator` — the unbiased ``B_noise = tr(Sigma)/|G|^2``
  estimate from the per-shard/full-batch gradient-norm pair the jitted
  train step emits, EMA-smoothed, with the derived critical-batch-size /
  efficiency curve (McCandlish et al.).
* :mod:`repro.gns.precursor` — bounded-memory random-sign sketches of
  per-leaf gradient directions in a short ring, time-lagged
  autocorrelation as an early-warning event before the divergence
  detector's var/norm excursion (Molybog et al.).
* :mod:`repro.gns.regulator` — ``CriticalBatchRegulator``: batch warmup
  driven by the measured noise scale instead of the grad-norm-EMA proxy.
"""
from repro.gns.estimator import GNSEstimator, gns_estimates
from repro.gns.precursor import GradientPrecursor, PrecursorEvent, \
    PrecursorHook
from repro.gns.regulator import CriticalBatchRegulator

__all__ = [
    "GNSEstimator", "gns_estimates",
    "GradientPrecursor", "PrecursorEvent", "PrecursorHook",
    "CriticalBatchRegulator",
]
