"""Unbiased gradient-noise-scale estimation (McCandlish et al., *An
Empirical Model of Large-Batch Training*; PAPERS.md).

The paper this repo reproduces blames training instability on extreme
gradient-variance values — the quantity the regulators steer on should be
the *measured* noise scale, not a grad-norm-EMA stand-in.  The estimator
here consumes the per-shard / full-batch squared-gradient-norm pair the
jitted train step emits (``launch/steps.py`` views the batch as ``k``
emulated data-parallel shards and reduces both norms before the gradients
are consumed — the pair is free relative to the backward pass):

with ``k`` shards of size ``b = B/k``,

    S_small = mean_i |g_i|^2        (per-shard gradients)
    S_big   = |mean_i g_i|^2        (the full-batch gradient)

are biased estimates of ``|G|^2 + tr(Sigma)/b`` and ``|G|^2 +
tr(Sigma)/B``; solving the 2x2 system gives the unbiased pair

    |G|^2_est     = (B * S_big - b * S_small) / (B - b)
    tr(Sigma)_est = (S_small - S_big) / (1/b - 1/B)

and the noise scale ``B_noise = tr(Sigma) / |G|^2``.  Numerator and
denominator are EMA-smoothed *separately* (the per-step estimates are
noisy and may individually go negative; their ratio-of-EMAs is the stable
quantity — McCandlish et al. Appendix A).

Everything here is host-side numpy and works elementwise, so the same
class smooths the global scalars and the per-leaf ``(n_leaves,)`` vectors
riding ``StepTelemetry.per_leaf``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

ArrayLike = Union[float, np.ndarray]


def gns_estimates(small_sq: ArrayLike, big_sq: ArrayLike,
                  b_small: float, b_big: float
                  ) -> Tuple[ArrayLike, ArrayLike]:
    """The unbiased ``(|G|^2, tr(Sigma))`` pair from one step's norms.

    Elementwise — scalars in, scalars out; ``(n_leaves,)`` vectors in,
    vectors out.  Requires ``b_big > b_small`` (the train step only emits
    the pair when it realized >= 2 shards).
    """
    small_sq = np.asarray(small_sq, np.float64)
    big_sq = np.asarray(big_sq, np.float64)
    g_sq = (b_big * big_sq - b_small * small_sq) / (b_big - b_small)
    tr_sigma = (small_sq - big_sq) / (1.0 / b_small - 1.0 / b_big)
    return g_sq, tr_sigma


class GNSEstimator:
    """EMA-smoothed noise-scale estimate + the derived efficiency curve.

    ``update`` takes one step's ``(S_small, S_big, b, B)`` observation
    (scalars or per-leaf vectors — the state adapts to whichever shape it
    is fed, and a shape change resets the EMAs).  ``b_noise`` is the
    smoothed ``tr(Sigma)/|G|^2``; :meth:`efficiency` is the per-step
    progress ratio ``1 / (1 + B_noise/B)`` — the diminishing-returns curve
    a batch-size schedule should ride (critical batch == B_noise: the
    point where doubling the batch stops halving the steps needed).

    ``state_dict``/``load_state_dict`` round-trip through the regulator's
    slice of ``ControllerState``, so a mid-warmup restore resumes the
    smoothed estimate exactly.
    """

    def __init__(self, ema_window: int = 32, warmup_obs: int = 8):
        self.alpha = 2.0 / (max(ema_window, 1) + 1.0)
        self.warmup_obs = max(warmup_obs, 1)
        self.ema_g_sq: Optional[np.ndarray] = None
        self.ema_tr: Optional[np.ndarray] = None
        self.n_obs = 0

    def update(self, small_sq: ArrayLike, big_sq: ArrayLike,
               b_small: float, b_big: float) -> None:
        if b_big <= b_small or b_small <= 0:
            return
        g_sq, tr = gns_estimates(small_sq, big_sq, b_small, b_big)
        g_sq = np.atleast_1d(np.asarray(g_sq, np.float64))
        tr = np.atleast_1d(np.asarray(tr, np.float64))
        if not (np.all(np.isfinite(g_sq)) and np.all(np.isfinite(tr))):
            return
        if self.ema_g_sq is None or self.ema_g_sq.shape != g_sq.shape:
            self.ema_g_sq, self.ema_tr = g_sq.copy(), tr.copy()
            self.n_obs = 1
            return
        a = self.alpha
        self.ema_g_sq = (1 - a) * self.ema_g_sq + a * g_sq
        self.ema_tr = (1 - a) * self.ema_tr + a * tr
        self.n_obs += 1

    # -- derived quantities --------------------------------------------------
    @property
    def ready(self) -> bool:
        return self.n_obs >= self.warmup_obs

    def _ratio(self) -> np.ndarray:
        """tr(Sigma)/|G|^2 elementwise; +inf where the signal has vanished
        (|G|^2 EMA <= 0 — pure noise, no batch is big enough)."""
        assert self.ema_g_sq is not None and self.ema_tr is not None
        tr = np.maximum(self.ema_tr, 0.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            r = np.where(self.ema_g_sq > 0.0, tr / self.ema_g_sq, np.inf)
        return r

    @property
    def b_noise(self) -> float:
        """The smoothed global noise scale (NaN before any observation).
        When fed per-leaf vectors, recomposes the global ratio as
        ``sum(tr_leaf) / sum(g_sq_leaf)``."""
        if self.ema_g_sq is None:
            return float("nan")
        if self.ema_g_sq.shape == (1,):
            return float(self._ratio()[0])
        g_sq = float(np.sum(self.ema_g_sq))
        tr = float(np.sum(np.maximum(self.ema_tr, 0.0)))
        return tr / g_sq if g_sq > 0.0 else float("inf")

    @property
    def leaf_b_noise(self) -> Optional[np.ndarray]:
        """Per-leaf noise-scale vector when fed per-leaf norms, else None."""
        if self.ema_g_sq is None or self.ema_g_sq.shape == (1,):
            return None
        return self._ratio()

    def critical_batch(self) -> float:
        """McCandlish et al.'s B_crit ~= B_noise: the batch size where the
        compute/time tradeoff turns — below it, growing the batch is nearly
        free in compute; above it, returns diminish linearly."""
        return self.b_noise

    def efficiency(self, batch: float) -> float:
        """Per-step progress at ``batch`` relative to the infinite-batch
        step: ``delta L(B) / delta L_max = 1 / (1 + B_noise/B)``."""
        bn = self.b_noise
        if not np.isfinite(bn) or batch <= 0:
            return float("nan")
        return 1.0 / (1.0 + bn / batch)

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "n_obs": self.n_obs,
            "ema_g_sq": (None if self.ema_g_sq is None
                         else self.ema_g_sq.tolist()),
            "ema_tr": (None if self.ema_tr is None
                       else self.ema_tr.tolist()),
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.n_obs = int(d.get("n_obs", 0))
        g, t = d.get("ema_g_sq"), d.get("ema_tr")
        self.ema_g_sq = None if g is None else np.asarray(g, np.float64)
        self.ema_tr = None if t is None else np.asarray(t, np.float64)
