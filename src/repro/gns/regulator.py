"""B_noise-measured batch warmup: the ``critical_batch`` regulator kind.

``GradNoiseBatchRegulator`` (PR 3) grows the batch while the relative std
of the *scalar gradient norm* is high — a single-replica proxy for the
quantity that actually decides whether averaging pays: the gradient noise
scale ``B_noise = tr(Sigma)/|G|^2``.  This regulator supersedes the proxy
with the measured estimate (Lau et al., *Adaptive Batch Size Schedules*,
argue batch schedules should track exactly this): warmup advances while
``B_noise > headroom * batch`` (noise dominates — a bigger batch converts
almost 1:1 into fewer steps) and holds when the measured headroom is gone
(the efficiency curve ``1/(1 + B_noise/B)`` has flattened; more batch
would only burn compute, the stability-efficiency dilemma's other horn).

It composes on the existing ``RegulatorStack`` exactly like the other
batch regulators (fold-by-min, monotone non-decreasing, quantized to the
data-parallel size) and checkpoints the estimator EMAs through its
``ControllerState`` slice, so a mid-warmup restore resumes both the batch
and the smoothed measurement exactly.
"""
from __future__ import annotations

import math
from typing import Any, Dict

from repro.configs.base import GNSConfig
from repro.core.batch_warmup import quantize_batch
from repro.core.regulators import Regulator, StepPlan, StepTelemetry
from repro.gns.estimator import GNSEstimator


class CriticalBatchRegulator(Regulator):
    """Batch warmup driven by the measured gradient noise scale."""

    name = "critical_batch"

    def __init__(self, cfg: GNSConfig, full_batch: int, dp_size: int = 1):
        self.cfg = cfg
        self.full_batch = full_batch
        self.dp_size = max(dp_size, 1)
        # floor of 2 rows: the estimator needs >= 2 emulated shards to
        # produce a (small, big) norm pair — a 1-row warmup batch would
        # never measure anything and so never grow
        self.batch = self._quantize(
            max(cfg.min_batch or full_batch // 8, 2))
        self.est = GNSEstimator(ema_window=cfg.ema_window,
                                warmup_obs=cfg.warmup_obs)

    def _quantize(self, b: float) -> int:
        return quantize_batch(b, self.dp_size, self.dp_size, self.full_batch)

    def plan(self, tele: StepTelemetry, plan: StepPlan) -> StepPlan:
        plan.batch_size = min(plan.batch_size, self.batch)
        return plan

    def observe(self, tele: StepTelemetry, tokens_step: int) -> None:
        # per-leaf vectors preferred (the global ratio recomposes from
        # them and the leaf breakdown rides along for free); the scalar
        # pair is the fallback when per-leaf telemetry is off
        if tele.per_leaf is not None \
                and "gns_small_sq" in tele.per_leaf \
                and "gns_big_sq" in tele.per_leaf \
                and math.isfinite(tele.gns_b_small):
            self.est.update(tele.per_leaf["gns_small_sq"],
                            tele.per_leaf["gns_big_sq"],
                            tele.gns_b_small, tele.gns_b_big)
        elif math.isfinite(tele.gns_small_sq) \
                and math.isfinite(tele.gns_big_sq) \
                and math.isfinite(tele.gns_b_small):
            self.est.update(tele.gns_small_sq, tele.gns_big_sq,
                            tele.gns_b_small, tele.gns_b_big)
        if not self.est.ready or self.batch >= self.full_batch:
            return
        b_noise = self.est.b_noise
        if math.isfinite(b_noise) or b_noise == float("inf"):
            if b_noise > self.cfg.headroom * self.batch:
                self.batch = self._quantize(
                    max(self.batch * self.cfg.growth,
                        self.batch + self.dp_size))

    def state_dict(self) -> Dict[str, Any]:
        return {"batch": self.batch, "est": self.est.state_dict()}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.batch = int(d["batch"])
        self.est.load_state_dict(dict(d.get("est", {})))
