"""Pre-spike forecasting from per-leaf gradient-direction sketches.

Molybog et al. (*A Theory on Adam Instability*; PAPERS.md) observe that a
loss spike is *preceded* by the per-layer gradient components becoming
time-correlated: in healthy training, consecutive stochastic gradients of
a layer are near-orthogonal (the noise dominates); when a layer's Adam
``v`` state is blown up — the canonical post-gradient-spike state — the
layer's update shrinks, its parameters freeze, and its gradient direction
starts repeating step over step.  That rising autocorrelation shows up
*before* the loss ratio or the sustained var-excursion streak the
``DivergenceDetector`` needs, so divergence can be forecast, not just
detected.

Measuring full per-leaf gradient correlation would need O(n_params) memory
per ring slot.  Instead the jitted step emits a ``(n_leaves, d)``
random-sign bucket sketch per step (``launch/steps.py``): each leaf's
flattened gradient is multiplied by fixed per-leaf Rademacher signs and
bucket-summed into ``d`` dims — an unbiased inner-product sketch
(``E[<s_t, s_u>] = <g_t, g_u>``) at O(n) compute and O(d) memory.  Host
side, :class:`GradientPrecursor` keeps the last ``window`` row-normalized
sketches and fires a :class:`PrecursorEvent` when a leaf's mean lagged
autocorrelation exceeds an absolute gate AND has risen over its own
trailing baseline — correlation *concentrated in a layer*, not ambient
drift (some leaves are legitimately direction-correlated every step).

On an event the :class:`PrecursorHook` (a) records it on ``TrainResult``
and (b) when the rollback controller is armed, pushes a proactive
``StateRing`` snapshot (the pre-excursion state becomes a restore point)
and applies a bounded LR cool-down through the checkpoint-safe
``RecoveryRegulator`` — containment *before* the detector would have to
roll anything back.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, Optional, Tuple

import numpy as np

from repro.configs.base import GNSConfig


@dataclass(frozen=True)
class PrecursorEvent:
    """One early warning: which leaf, how correlated, vs what baseline."""

    step: int
    leaf: str
    score: float      # mean lagged autocorrelation of the hot leaf
    baseline: float   # that leaf's trailing score before the excursion

    def __str__(self) -> str:
        return (f"precursor@{self.step}(leaf={self.leaf} "
                f"corr={self.score:.2f} trail={self.baseline:.2f})")


class GradientPrecursor:
    """Ring of row-normalized sketches + per-leaf lagged autocorrelation.

    Memory is bounded at ``window x n_leaves x d`` floats.  During the
    grace period the trailing per-leaf score EMA always advances — the
    grace window *defines* each leaf's baseline, which matters because
    some leaves (positional embeddings under a fixed-format corpus) are
    legitimately direction-correlated every step and must be absorbed,
    not fired on.  After grace it advances only on calm steps (same
    rationale as the detector's trailing var mean: the baseline must not
    chase the excursion it gates).  A fired event starts a refire
    cooldown so one sustained excursion produces one event, not a stream.
    """

    def __init__(self, cfg: GNSConfig):
        self.cfg = cfg
        self.window = max(cfg.precursor_window, cfg.precursor_lags + 1)
        self.ring: Deque[np.ndarray] = deque(maxlen=self.window)
        self.trailing: Optional[np.ndarray] = None
        self.n_scores = 0
        self.cooldown = 0
        self.last_scores: Optional[np.ndarray] = None

    def _scores(self, unit: np.ndarray) -> Optional[np.ndarray]:
        """Mean over lags 1..L of the per-leaf direction autocorrelation
        between the current sketch and the ring (None until filled)."""
        lags = self.cfg.precursor_lags
        if len(self.ring) < lags:
            return None
        acc = np.zeros(unit.shape[0], np.float64)
        for lag in range(1, lags + 1):
            acc += np.sum(unit * self.ring[-lag], axis=1)
        return acc / lags

    def observe(self, step: int, sketch: np.ndarray,
                labels: Tuple[str, ...]) -> Optional[PrecursorEvent]:
        sk = np.asarray(sketch, np.float64)
        if sk.ndim != 2 or not np.all(np.isfinite(sk)):
            # a NaN step poisons direction history; start over
            self.ring.clear()
            return None
        norms = np.linalg.norm(sk, axis=1, keepdims=True)
        unit = sk / np.maximum(norms, 1e-30)

        event: Optional[PrecursorEvent] = None
        scores = self._scores(unit)
        if scores is not None:
            self.last_scores = scores
            if self.trailing is None or self.trailing.shape != scores.shape:
                self.trailing = scores.copy()
            else:
                self.n_scores += 1
                in_grace = self.n_scores <= self.cfg.precursor_grace
                # hot = above the absolute gate AND risen over the leaf's
                # own baseline.  The rise term is additive — scores are
                # bounded cosines, so a multiplicative baseline gate
                # would be unreachable for leaves whose ambient
                # correlation is already moderate
                hot = (scores > self.cfg.precursor_gate) \
                    & (scores - self.trailing > self.cfg.precursor_rise)
                if self.cooldown > 0:
                    self.cooldown -= 1
                elif not in_grace and bool(np.any(hot)):
                    margin = np.where(hot, scores - self.trailing, -np.inf)
                    i = int(np.argmax(margin))
                    leaf = (labels[i] if i < len(labels)
                            else f"leaf_{i}")
                    event = PrecursorEvent(
                        step=step, leaf=leaf, score=float(scores[i]),
                        baseline=float(self.trailing[i]))
                    self.cooldown = self.cfg.precursor_cooldown_steps
                if in_grace or (event is None and not bool(np.any(hot))):
                    # grace defines the baseline; afterwards only calm
                    # steps advance it
                    self.trailing = 0.9 * self.trailing + 0.1 * scores
        self.ring.append(unit)
        return event


class PrecursorHook:
    """Trainer wiring (duck-typed ``TrainerHook``, like ``RecoveryHook``).

    Feeds the precursor from the per-leaf sketch riding
    ``StepTelemetry.per_leaf`` and, on an event, triggers the rollback
    controller's proactive path (snapshot + LR cool-down).  Without a
    controller (``--gns`` without ``--recover``) events are still recorded
    on ``TrainResult.precursor_events`` for offline analysis.
    """

    def __init__(self, precursor: GradientPrecursor, controller=None,
                 cool: Tuple[float, int] = (0.5, 8)):
        self.precursor = precursor
        self.controller = controller
        self.cool = cool

    def on_run_start(self, tr) -> None:
        pass

    def on_step_start(self, tr) -> None:
        pass

    def on_step_end(self, tr, tele, plan, metrics: Dict[str, Any]) -> None:
        if tele.per_leaf is None:
            return
        sketch = tele.per_leaf.get("gns_sketch")
        if sketch is None:
            return
        event = self.precursor.observe(tele.step, sketch, tele.leaf_labels)
        if event is None:
            return
        tr.result.precursor_events.append(str(event))
        if self.controller is not None:
            self.controller.handle_precursor(tr, event,
                                             factor=self.cool[0],
                                             ttl=self.cool[1])

    def on_run_end(self, tr) -> None:
        pass

    def close(self) -> None:
        pass
