"""Shard-aware, resumable data pipeline.

The paper's implementation pre-indexes raw text into full-length sequences
once and lets the curriculum truncate per step (Section 4) — re-indexing per
length would be prohibitive at 157B tokens.  This pipeline mirrors that: it
always yields full-length ``(B, S)`` batches; `SLWCurriculum.apply` truncates
or repacks them host-side.

Determinism/elasticity: batch `step` is sequence indices
``[step*B_global + r] for r in rank's slice``, pure arithmetic over
(step, dp_rank, dp_size).  Changing dp_size on an elastic restart
re-partitions the stream with no overlap or gap.  The only pipeline state is
the step counter, carried in the checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import SyntheticCorpus


@dataclass
class DataPipeline:
    corpus: SyntheticCorpus
    global_batch: int
    dp_rank: int = 0
    dp_size: int = 1
    model_cfg: Optional[ModelConfig] = None

    def __post_init__(self):
        assert self.global_batch % self.dp_size == 0
        self.local_batch = self.global_batch // self.dp_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        base = step * self.global_batch + self.dp_rank * self.local_batch
        batch = self.corpus.batch(base, self.local_batch)
        cfg = self.model_cfg
        if cfg is not None and cfg.frontend == "vision_patches":
            # stub frontend: deterministic pseudo patch embeddings
            rng = np.random.Generator(np.random.Philox(key=10_000_019 + step))
            batch["patch_embeds"] = rng.standard_normal(
                (self.local_batch, cfg.prefix_tokens, cfg.d_model),
                dtype=np.float32) * 0.02
        if cfg is not None and cfg.frontend == "audio_frames":
            rng = np.random.Generator(np.random.Philox(key=20_000_003 + step))
            batch["frames"] = rng.standard_normal(
                (self.local_batch, self.corpus.seq_len, cfg.d_model),
                dtype=np.float32) * 0.02
        return batch

    # validation stream: disjoint index space (negative side of the corpus)
    def eval_batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        base = 1_000_000_000 + step * batch_size
        return self.corpus.batch(base, batch_size)
