"""Deterministic synthetic corpus with learnable long-range structure.

Each "document" is an affine-recurrence token stream with document-specific
parameters:  x_{t+1} = (a * x_t + b + noise_t) mod V, where (a, b) are drawn
per document and ``noise_t`` flips a random fraction of steps.  A model must
infer (a, b) from context to predict well, so *longer context genuinely
lowers perplexity* — which is what makes the corpus a meaningful testbed for
sequence-length warmup dynamics (the paper's validation-perplexity curves
depend on exactly this property).

Random access is fully deterministic: document i is generated from
``Philox(seed, i)``, so any (rank, step) can regenerate any slice — this is
the property the elastic data-parallel resharding relies on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass(frozen=True)
class SyntheticCorpus:
    vocab_size: int
    seq_len: int  # pre-indexed full sequence length (paper: indexed once)
    seed: int = 1234
    noise: float = 0.15
    n_param_families: int = 8

    def sequence(self, index: int) -> np.ndarray:
        """Token sequence `index`, length seq_len + 1 (for next-token shift)."""
        rng = np.random.Generator(np.random.Philox(key=self.seed + 7919 * index))
        v = self.vocab_size
        fam = rng.integers(0, self.n_param_families)
        frng = np.random.Generator(np.random.Philox(key=self.seed * 31 + fam))
        a = int(frng.integers(1, v - 1)) | 1  # odd -> invertible mod 2^k-ish
        b = int(frng.integers(0, v))
        n = self.seq_len + 1
        noise_mask = rng.random(n) < self.noise
        noise_vals = rng.integers(0, v, size=n)
        x = np.empty(n, dtype=np.int64)
        x[0] = rng.integers(0, v)
        for t in range(1, n):
            x[t] = (a * x[t - 1] + b) % v
            if noise_mask[t]:
                x[t] = noise_vals[t]
        return x.astype(np.int32)

    def batch(self, start_index: int, batch_size: int) -> Dict[str, np.ndarray]:
        seqs = np.stack([self.sequence(start_index + i)
                         for i in range(batch_size)])
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
