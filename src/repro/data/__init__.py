from repro.data.pipeline import DataPipeline  # noqa: F401
from repro.data.synthetic import SyntheticCorpus  # noqa: F401
