"""End-to-end trainer: SLW curriculum + token-wise LR + fault tolerance.

Usable as a library (`train(cfg, ...)` — the benchmarks drive tiny replicas
of the paper's experiments through this exact loop) and as a CLI:

  PYTHONPATH=src python -m repro.launch.train --arch gpt2-117m --reduced \
      --steps 200 --batch 16 --seq 256 --slw --duration 100

The loop is the paper's recipe end to end:
  batch (full length, pre-indexed) -> curriculum truncate/repack ->
  token-wise LR -> jitted train step (one executable per seqlen bucket) ->
  loss-ratio + Adam-variance telemetry -> token-budget termination,
with checkpoint/restart, drain-on-signal and a straggler watchdog wrapped
around it.
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced as reduce_cfg
from repro.configs.base import (
    BatchWarmupConfig, ModelConfig, OptimizerConfig, SLWConfig, TrainConfig)
from repro.core import BatchWarmup, LossRatioTracker, SLWCurriculum
from repro.checkpoint import CheckpointManager
from repro.data import DataPipeline, SyntheticCorpus
from repro.distributed.fault_tolerance import DrainSignal, StepWatchdog
from repro.launch import steps as steps_lib
from repro.models import model_zoo
from repro.optim import lr_at


@dataclass
class TrainResult:
    steps: int = 0
    tokens: int = 0
    diverged: bool = False
    drained: bool = False
    wall_time_s: float = 0.0
    loss_history: List[float] = field(default_factory=list)
    lr_history: List[float] = field(default_factory=list)
    seqlen_history: List[int] = field(default_factory=list)
    var_max_history: List[float] = field(default_factory=list)
    var_l1_history: List[float] = field(default_factory=list)
    grad_norm_history: List[float] = field(default_factory=list)
    val_ppl_history: List[Tuple[int, float]] = field(default_factory=list)
    tracker_summary: Dict[str, float] = field(default_factory=dict)
    watchdog_summary: Dict[str, float] = field(default_factory=dict)
    n_compiles: int = 0
    restored_from_step: Optional[int] = None

    @property
    def loss_ratios(self) -> List[float]:
        return self._ratios

    _ratios: List[float] = field(default_factory=list)


def train(tc: TrainConfig,
          max_steps: Optional[int] = None,
          eval_batch: int = 8,
          resume: bool = False,
          stop_on_nan: bool = True,
          drain: Optional[DrainSignal] = None,
          callback: Optional[Callable[[int, Dict[str, float]], None]] = None,
          fail_at_step: Optional[int] = None,
          quiet: bool = True) -> TrainResult:
    """Run the training loop on the local device(s). Returns full telemetry.

    `fail_at_step` injects a crash (fault-tolerance tests/drills).
    """
    cfg = tc.model
    opt_cfg = tc.optimizer
    model = model_zoo.build_model(cfg, dtype=jnp.float32, remat=tc.remat)
    rng = jax.random.PRNGKey(tc.seed)
    state = steps_lib.init_train_state(rng, cfg)

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
                             seed=tc.seed)
    pipeline = DataPipeline(corpus, tc.global_batch, model_cfg=cfg)
    curriculum = SLWCurriculum(tc.slw, tc.seq_len,
                               warmup_steps_hint=opt_cfg.warmup_steps,
                               prefix_tokens=cfg.prefix_tokens)
    bwarm = BatchWarmup(tc.batch_warmup, tc.global_batch)
    tracker = LossRatioTracker()
    watchdog = StepWatchdog()
    ckpt = (CheckpointManager(tc.checkpoint_dir, tc.keep_checkpoints)
            if tc.checkpoint_dir else None)

    step_fn = jax.jit(steps_lib.make_train_step(model, opt_cfg),
                      donate_argnums=(0,))
    eval_fn = jax.jit(lambda p, b: model.loss(p, b)[1]["loss"])

    result = TrainResult()
    step, tokens_seen = 0, 0

    if resume and ckpt is not None:
        like = steps_lib.abstract_train_state(cfg)
        got_step, got_state, host = ckpt.restore_latest(like)
        if got_step is not None:
            state = got_state
            step = host["step"]
            tokens_seen = host["tokens_seen"]
            curriculum.load_state_dict(host["curriculum"])
            tracker.load_state_dict(host["tracker"])
            result.restored_from_step = got_step

    def save_checkpoint():
        if ckpt is None:
            return
        host = {"step": step, "tokens_seen": tokens_seen,
                "curriculum": curriculum.state_dict(),
                "tracker": tracker.state_dict()}
        ckpt.save(step, state, host)

    total_steps = opt_cfg.total_steps or 10**9
    total_tokens = opt_cfg.total_tokens or 10**18
    if max_steps is not None:
        total_steps = min(total_steps, step + max_steps)

    seen_shapes = set()
    t_start = time.time()
    while step < total_steps and tokens_seen < total_tokens:
        if drain is not None and drain.should_drain:
            save_checkpoint()
            result.drained = True
            break
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")

        watchdog.start()
        batch = pipeline.batch_at(step)
        if tc.slw.enabled:
            batch, tokens_step = curriculum.apply(batch)
        elif tc.batch_warmup.enabled:
            batch, tokens_step = bwarm.apply(batch, tokens_seen)
        else:
            tokens_step = int(np.prod(batch["tokens"].shape[:2])) \
                if "tokens" in batch else int(
                    np.prod(next(iter(batch.values())).shape[:2]))

        lr = lr_at(opt_cfg, step, tokens_seen)
        shape_key = tuple(sorted((k, v.shape) for k, v in batch.items()))
        if shape_key not in seen_shapes:
            seen_shapes.add(shape_key)
            result.n_compiles += 1
        state, metrics = step_fn(state, batch, np.float32(lr))
        loss = float(metrics["loss"])
        var_max = float(metrics["var_max"])

        ratio = tracker.update(loss) if math.isfinite(loss) else float("inf")
        result._ratios.append(ratio)
        result.loss_history.append(loss)
        result.lr_history.append(lr)
        result.seqlen_history.append(
            curriculum.seqlen_for_step() if tc.slw.enabled else tc.seq_len)
        result.var_max_history.append(var_max)
        result.var_l1_history.append(float(metrics["var_l1"]))
        result.grad_norm_history.append(float(metrics["grad_norm"]))
        if callback is not None:
            callback(step, {k: float(v) for k, v in metrics.items()})

        if tc.slw.enabled:
            if tc.slw.pacing == "variance_gated" and math.isfinite(var_max):
                curriculum.observe(var_max)
            curriculum.step_complete(tokens_step)
        tokens_seen += tokens_step
        step += 1
        watchdog.stop()

        if not math.isfinite(loss):
            result.diverged = True
            if stop_on_nan:
                break

        if tc.eval_interval and step % tc.eval_interval == 0:
            ev = pipeline.eval_batch(step // tc.eval_interval, eval_batch)
            ppl = float(np.exp(min(float(eval_fn(state["params"], ev)), 30.0)))
            result.val_ppl_history.append((step, ppl))
            if not quiet:
                print(f"step {step} tokens {tokens_seen} loss {loss:.4f} "
                      f"val_ppl {ppl:.2f} seqlen "
                      f"{result.seqlen_history[-1]} lr {lr:.2e}", flush=True)

        if ckpt is not None and tc.checkpoint_interval and \
                step % tc.checkpoint_interval == 0:
            save_checkpoint()

    if ckpt is not None and not result.drained:
        save_checkpoint()
    result.steps = step
    result.tokens = tokens_seen
    result.wall_time_s = time.time() - t_start
    result.tracker_summary = tracker.summary()
    result.watchdog_summary = watchdog.summary()
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def build_config(args) -> TrainConfig:
    spec = get_arch(args.arch)
    cfg = reduce_cfg(spec.model) if args.reduced else spec.model
    if args.vocab:
        cfg = cfg.replace(vocab_size=args.vocab)
    slw = SLWConfig(
        enabled=args.slw, pacing=args.pacing, start_seq_len=args.start_seq,
        duration_steps=args.duration, round_multiple=args.round_multiple,
        mode=args.slw_mode, max_buckets=args.max_buckets)
    opt = OptimizerConfig(
        lr=args.lr, min_lr=args.min_lr, warmup_steps=args.warmup,
        warmup_tokens=args.warmup * args.batch * args.seq,
        total_steps=args.steps,
        total_tokens=args.tokens or args.steps * args.batch * args.seq,
        schedule=args.schedule, grad_clip=args.clip)
    bw = BatchWarmupConfig(enabled=args.batch_warmup,
                           start_batch=max(args.batch // 8, 1),
                           warmup_tokens=(args.tokens or args.steps
                                          * args.batch * args.seq) // 20)
    return TrainConfig(model=cfg, optimizer=opt, slw=slw, batch_warmup=bw,
                       seq_len=args.seq, global_batch=args.batch,
                       seed=args.seed, remat=args.remat,
                       eval_interval=args.eval_interval,
                       checkpoint_interval=args.ckpt_interval,
                       checkpoint_dir=args.ckpt_dir)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="gpt2-117m")
    p.add_argument("--reduced", action="store_true",
                   help="reduced same-family config (CPU-trainable)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--tokens", type=int, default=0)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--vocab", type=int, default=0)
    p.add_argument("--lr", type=float, default=6e-4)
    p.add_argument("--min-lr", type=float, default=1e-5)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--clip", type=float, default=1.0)
    p.add_argument("--schedule", default="token_cosine",
                   choices=["token_cosine", "step_cosine", "constant"])
    p.add_argument("--slw", action="store_true")
    p.add_argument("--pacing", default="linear",
                   choices=["linear", "root", "two_stage", "variance_gated",
                            "constant"])
    p.add_argument("--start-seq", type=int, default=8)
    p.add_argument("--duration", type=int, default=0)
    p.add_argument("--round-multiple", type=int, default=8)
    p.add_argument("--max-buckets", type=int, default=16)
    p.add_argument("--slw-mode", default="truncate",
                   choices=["truncate", "repack"])
    p.add_argument("--batch-warmup", action="store_true")
    p.add_argument("--remat", default="none",
                   choices=["none", "full", "dots"])
    p.add_argument("--seed", type=int, default=1234)
    p.add_argument("--eval-interval", type=int, default=50)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-interval", type=int, default=100)
    p.add_argument("--resume", action="store_true")
    args = p.parse_args(argv)

    tc = build_config(args)
    drain = DrainSignal()
    res = train(tc, resume=args.resume, drain=drain, quiet=False)
    print(f"\ndone: steps={res.steps} tokens={res.tokens} "
          f"diverged={res.diverged} compiles={res.n_compiles}")
    print("stability:", res.tracker_summary)
    print("watchdog:", res.watchdog_summary)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
