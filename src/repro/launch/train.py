"""End-to-end trainer on the composable regulator control plane.

Usable as a library (`train(cfg, ...)` — the benchmarks drive tiny replicas
of the paper's experiments through this exact loop) and as a CLI:

  PYTHONPATH=src python -m repro.launch.train --arch gpt2-117m --reduced \
      --steps 200 --batch 16 --seq 256 --slw --batch-warmup --duration 100

The loop is the paper's *joint* recipe end to end:
  regulator stack plans the step (seqlen bucket + batch size + LR +
  grad-clip scale, from shared StepTelemetry) -> batch (full length,
  pre-indexed) row-sliced and truncated/repacked host-side -> jitted train
  step (one executable per (seqlen, batch) bucket) -> loss-ratio +
  Adam-variance telemetry fed back into the stack -> token-budget
  termination,
with checkpoint/restart (one unified ControllerState), drain-on-signal and
a straggler watchdog as hooks around it.

The `Trainer` class is the control plane host: eval, checkpointing, drain,
the watchdog and telemetry recording are `TrainerHook`s, so deployments can
add/remove concerns without forking the loop; `train(tc, ...)` stays as the
thin functional wrapper every benchmark/test entry point uses.
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced as reduce_cfg
from repro.configs.base import (
    BatchWarmupConfig, GNSConfig, OptimizerConfig, RegulatorSpec, SLWConfig,
    TrainConfig)
from repro.core import LossRatioTracker
from repro.core import telemetry as telemetry_lib
from repro.core.recovery import (RecoveryConfig, RecoveryHook,
                                 RecoveryRegulator, RollbackController)
from repro.core.regulators import (ControllerState, RegulatorStack, StepPlan,
                                   StepTelemetry, build_stack)
from repro.checkpoint import CheckpointManager, migrate_host_state
from repro.data import DataPipeline, SyntheticCorpus
from repro.distributed.fault_injection import (FaultInjectionHook,
                                               FaultInjector)
from repro.distributed.fault_tolerance import (DrainSignal, RetryPolicy,
                                               StepWatchdog)
from repro.launch import steps as steps_lib
from repro.models import model_zoo


@dataclass
class TrainResult:
    steps: int = 0
    tokens: int = 0
    diverged: bool = False
    drained: bool = False
    wall_time_s: float = 0.0
    loss_history: List[float] = field(default_factory=list)
    loss_ratios: List[float] = field(default_factory=list)
    lr_history: List[float] = field(default_factory=list)
    seqlen_history: List[int] = field(default_factory=list)
    batch_history: List[int] = field(default_factory=list)
    var_max_history: List[float] = field(default_factory=list)
    var_l1_history: List[float] = field(default_factory=list)
    grad_norm_history: List[float] = field(default_factory=list)
    val_ppl_history: List[Tuple[int, float]] = field(default_factory=list)
    tracker_summary: Dict[str, float] = field(default_factory=dict)
    watchdog_summary: Dict[str, float] = field(default_factory=dict)
    n_compiles: int = 0
    restored_from_step: Optional[int] = None
    # divergence-aware recovery accounting (core.recovery)
    rollbacks: int = 0
    recovery_events: List[str] = field(default_factory=list)
    faults_fired: List[str] = field(default_factory=list)
    # gradient-direction early warnings (repro.gns.precursor)
    precursor_events: List[str] = field(default_factory=list)


# ---------------------------------------------------------------------------
# hooks
# ---------------------------------------------------------------------------

class TrainerHook:
    """Cross-cutting trainer concern.  ``on_step_start`` runs before the
    plan is made (and may call ``trainer.request_drain()``);
    ``on_step_end`` runs after the regulators observed the completed step.
    When ``trainer.stopping`` is set (divergence with stop_on_nan), interval
    work (eval/checkpoint) should be skipped."""

    def on_run_start(self, tr: "Trainer") -> None:
        pass

    def on_step_start(self, tr: "Trainer") -> None:
        pass

    def on_step_end(self, tr: "Trainer", tele: StepTelemetry, plan: StepPlan,
                    metrics: Dict[str, float]) -> None:
        pass

    def on_run_end(self, tr: "Trainer") -> None:
        """Normal-completion epilogue (summaries, final checkpoint)."""

    def close(self) -> None:
        """Resource cleanup only — also runs when the loop exits via an
        exception (on_run_end does not: saving checkpoints or summaries
        during unwind would record a state no real preemption could)."""


class DrainHook(TrainerHook):
    """Preemption-safe exit: checkpoint at the next step boundary."""

    def __init__(self, drain: Optional[DrainSignal]):
        self.drain = drain

    def on_step_start(self, tr: "Trainer") -> None:
        if self.drain is not None and self.drain.should_drain:
            tr.request_drain()

    def close(self) -> None:
        # restore whatever handlers preceded this trainer — installed
        # handlers used to leak across Trainer instances and tests
        if self.drain is not None:
            self.drain.uninstall()


class WatchdogHook(TrainerHook):
    def on_step_start(self, tr: "Trainer") -> None:
        tr.watchdog.start()

    def on_step_end(self, tr, tele, plan, metrics) -> None:
        tr.watchdog.stop()

    def on_run_end(self, tr: "Trainer") -> None:
        tr.result.watchdog_summary = tr.watchdog.summary()


class TelemetryHook(TrainerHook):
    """Records the per-step histories and drives the user callback."""

    def __init__(self, callback: Optional[Callable[[int, Dict[str, float]],
                                                   None]] = None):
        self.callback = callback

    def on_step_end(self, tr, tele, plan, metrics) -> None:
        res = tr.result
        res.loss_history.append(tele.loss)
        res.loss_ratios.append(tele.loss_ratio)
        res.lr_history.append(plan.lr)
        res.seqlen_history.append(plan.seq_len)
        res.batch_history.append(plan.batch_size)
        res.var_max_history.append(tele.var_max)
        res.var_l1_history.append(tele.var_l1)
        res.grad_norm_history.append(tele.grad_norm)
        if self.callback is not None:
            self.callback(tele.step, {k: float(v) for k, v in metrics.items()})

    def on_run_end(self, tr: "Trainer") -> None:
        tr.result.tracker_summary = tr.tracker.summary()


class MetricsJsonlHook(TrainerHook):
    """Appends one JSON row per step (StepPlan + StepTelemetry) to a file.

    The ROADMAP's "surface Trainer hooks in the CLI" follow-on: a
    deployment-grade telemetry tap (``--metrics-jsonl PATH``) that records
    exactly what the regulator stack planned and observed, without touching
    the loop.  Rows are flushed per step so a crashed/drained run keeps its
    telemetry up to the last completed step.
    """

    def __init__(self, path: str):
        self.path = path
        self._fh = None
        self._wrote_labels = False

    def on_run_start(self, tr: "Trainer") -> None:
        self._fh = open(self.path, "a", buffering=1)

    def on_step_end(self, tr, tele, plan, metrics) -> None:
        import json
        row = {
            "step": tele.step, "tokens_seen": tele.tokens_seen,
            "loss": tele.loss, "loss_ratio": tele.loss_ratio,
            "grad_norm": tele.grad_norm, "var_max": tele.var_max,
            "var_l1": tele.var_l1,
            "plan": {"seq_len": plan.seq_len, "batch_size": plan.batch_size,
                     "lr": plan.lr,
                     "grad_clip_scale": plan.grad_clip_scale},
        }
        # optional scalar channels: written only when the step emitted
        # them (finite), so pre-PR-9 row shapes are unchanged
        for k in ("grad_norm_clipped", "gns_small_sq", "gns_big_sq",
                  "gns_b_small", "gns_b_big"):
            v = getattr(tele, k)
            if math.isfinite(v):
                row[k] = v
        if tele.per_leaf is not None:
            # per-leaf vectors in leaf_labels order; the labels themselves
            # are written once (first per-leaf row), not per step
            row["per_leaf"] = telemetry_lib.per_leaf_to_host(tele.per_leaf)
            if not self._wrote_labels:
                row["leaf_labels"] = list(tele.leaf_labels)
                self._wrote_labels = True
        self._fh.write(json.dumps(row) + "\n")

    def on_run_end(self, tr: "Trainer") -> None:
        self.close()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class EvalHook(TrainerHook):
    """Full-length validation every ``eval_interval`` steps."""

    def __init__(self, eval_batch: int = 8, quiet: bool = True):
        self.eval_batch = eval_batch
        self.quiet = quiet

    def on_step_end(self, tr, tele, plan, metrics) -> None:
        interval = tr.tc.eval_interval
        if tr.stopping or not interval or tr.step % interval != 0:
            return
        ev = tr.pipeline.eval_batch(tr.step // interval, self.eval_batch)
        ppl = float(np.exp(min(float(tr.eval_fn(tr.state["params"], ev)),
                               30.0)))
        tr.result.val_ppl_history.append((tr.step, ppl))
        if not self.quiet:
            print(f"step {tr.step} tokens {tr.tokens_seen} "
                  f"loss {tele.loss:.4f} val_ppl {ppl:.2f} "
                  f"seqlen {plan.seq_len} batch {plan.batch_size} "
                  f"lr {plan.lr:.2e}", flush=True)


class CheckpointHook(TrainerHook):
    """Periodic + final checkpointing (the drain path saves on its own)."""

    def on_step_end(self, tr, tele, plan, metrics) -> None:
        if tr.stopping or tr.ckpt is None or not tr.tc.checkpoint_interval:
            return
        if tr.step % tr.tc.checkpoint_interval == 0:
            tr.save_checkpoint()

    def on_run_end(self, tr: "Trainer") -> None:
        if tr.ckpt is not None and not tr.result.drained:
            tr.save_checkpoint()


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

class Trainer:
    """Host-side training control plane around the regulator stack.

    Owns model/optimizer state, the data pipeline, the regulator stack, the
    loss-ratio tracker and the checkpoint manager; everything else (eval,
    checkpoint cadence, drain, watchdog, telemetry) is a hook.
    """

    def __init__(self, tc: TrainConfig, *, dp_size: int = 1,
                 eval_batch: int = 8, stop_on_nan: bool = True,
                 drain: Optional[DrainSignal] = None,
                 callback: Optional[Callable[[int, Dict[str, float]],
                                             None]] = None,
                 fail_at_step: Optional[int] = None, quiet: bool = True,
                 hooks: Optional[List[TrainerHook]] = None,
                 recovery: Optional[RecoveryConfig] = None,
                 fault_injector: Optional[FaultInjector] = None):
        """`hooks` are appended after the default hook set (drain, watchdog,
        telemetry, eval, checkpoint).  ``recovery`` enables the in-process
        divergence rollback controller (core.recovery); ``fault_injector``
        arms deterministic fault injection for this run
        (distributed.fault_injection)."""
        self.tc = tc
        self.dp_size = max(dp_size, 1)
        self.stop_on_nan = stop_on_nan
        self.fail_at_step = fail_at_step
        cfg = tc.model
        self.model = model_zoo.build_model(cfg, dtype=jnp.float32,
                                           remat=tc.remat)
        rng = jax.random.PRNGKey(tc.seed)
        self.state = steps_lib.init_train_state(rng, cfg, tc.optimizer)
        # leaf labels for per-parameter telemetry / per-layer blame: fixed
        # for the run (tree structure never changes), computed once
        self.leaf_labels = telemetry_lib.param_labels(self.state["params"])

        corpus = SyntheticCorpus(vocab_size=cfg.vocab_size,
                                 seq_len=tc.seq_len, seed=tc.seed)
        self.pipeline = DataPipeline(corpus, tc.global_batch, model_cfg=cfg)
        self.stack: RegulatorStack = build_stack(
            tc, dp_size=self.dp_size,
            warmup_steps_hint=tc.optimizer.warmup_steps,
            prefix_tokens=cfg.prefix_tokens)
        self.tracker = LossRatioTracker()
        self.watchdog = StepWatchdog()
        self.ckpt = (CheckpointManager(tc.checkpoint_dir, tc.keep_checkpoints)
                     if tc.checkpoint_dir else None)

        self.step_fn = jax.jit(steps_lib.make_train_step(self.model,
                                                         tc.optimizer,
                                                         gns=tc.gns),
                               donate_argnums=(0,))
        self.eval_fn = jax.jit(lambda p, b: self.model.loss(p, b)[1]["loss"])

        self.result = TrainResult()
        self.step = 0
        self.tokens_seen = 0
        self.stopping = False
        self._drain_requested = False
        self._last = StepTelemetry()
        self._seen_shapes = set()
        # set by the fault injector (grad_spike) for the next step only
        self.fault_injector = fault_injector
        self._pending_grad_fault: Optional[Tuple[float, str]] = None

        # divergence-aware recovery: the intervention regulator joins the
        # stack (so its state checkpoints through ControllerState) and the
        # rollback controller rides the hook list
        self.recovery: Optional[RollbackController] = None
        self._recovery_reg: Optional[RecoveryRegulator] = None
        self._ring_dir = ""
        if recovery is not None:
            ladder = (self.stack["seqlen"].curriculum.ladder
                      if "seqlen" in self.stack else (tc.seq_len,))
            self._recovery_reg = RecoveryRegulator(ladder, recovery)
            self.stack.regulators.append(self._recovery_reg)
            self.recovery = RollbackController(recovery)
            self._ring_dir = recovery.ring_dir or (
                os.path.join(tc.checkpoint_dir, "ring")
                if tc.checkpoint_dir else "")

        # `hooks` extends the defaults (it does not replace them — drain/
        # callback/eval would silently stop working otherwise)
        self.hooks: List[TrainerHook] = [
            DrainHook(drain),
            WatchdogHook(),
            TelemetryHook(callback),
            EvalHook(eval_batch=eval_batch, quiet=quiet),
            CheckpointHook(),
        ]
        if self.recovery is not None:
            self.hooks.append(RecoveryHook(self.recovery))
        if fault_injector is not None:
            self.hooks.append(FaultInjectionHook(fault_injector))
        # GNS precursor: direction-sketch early warning, wired into the
        # rollback controller (proactive snapshot + LR cool-down) when
        # recovery is on; pure telemetry otherwise
        if tc.gns.enabled and tc.gns.precursor_window > 0:
            from repro.gns.precursor import GradientPrecursor, PrecursorHook
            self.hooks.append(PrecursorHook(
                GradientPrecursor(tc.gns), controller=self.recovery,
                cool=(tc.gns.precursor_cooldown_factor,
                      tc.gns.precursor_cooldown_steps)))
        self.hooks += list(hooks or [])

    # -- control signals -----------------------------------------------------
    def request_drain(self) -> None:
        self._drain_requested = True

    # -- unified controller state (checkpoint payload) -----------------------
    def controller_state(self) -> ControllerState:
        return self.stack.controller_state(self.step, self.tokens_seen,
                                           self.tracker.state_dict())

    def load_controller_state(self, cs: ControllerState) -> None:
        self.step = cs.step
        self.tokens_seen = cs.tokens_seen
        if cs.tracker:
            self.tracker.load_state_dict(cs.tracker)
        self.stack.load_controller_state(cs)

    def save_checkpoint(self) -> None:
        if self.ckpt is None:
            return
        # the controller dict is the single source of truth for host state
        # (step/tokens_seen live inside it; the manifest's own "step" field
        # covers human inspection)
        self.ckpt.save(self.step, self.state,
                       {"controller": self.controller_state().to_host()})

    def resume(self) -> Optional[int]:
        """Restore the latest checkpoint, if any.  Returns its step."""
        if self.ckpt is None:
            return None
        like = steps_lib.abstract_train_state(self.tc.model,
                                              self.tc.optimizer)
        got_step, got_state, host = self.ckpt.restore_latest(like)
        if got_step is None:
            return None
        self.state = got_state
        host = migrate_host_state(host)
        self.load_controller_state(ControllerState.from_host(
            host["controller"]))
        self.result.restored_from_step = got_step
        # a drained run spilled its in-run rollback ring next to the
        # checkpoint — refill it so recovery resumes with the same restore
        # points it had when the preemption landed
        if self.recovery is not None and self._ring_dir \
                and os.path.isdir(self._ring_dir):
            self.recovery.ring.load(self._ring_dir, like)
        return got_step

    # -- one training step ---------------------------------------------------
    def run_step(self) -> Tuple[StepTelemetry, StepPlan, Dict[str, Any]]:
        tele = dataclasses.replace(self._last, step=self.step,
                                   tokens_seen=self.tokens_seen)
        plan = self.stack.plan(tele)
        # the recovery regulator's data offset skips past a data window the
        # rollback controller blamed for a divergence
        offset = (self._recovery_reg.data_offset
                  if self._recovery_reg is not None else 0)
        batch = self.pipeline.batch_at(self.step + offset)
        batch, tokens_step = self.stack.apply(batch, plan)

        shape_key = tuple(sorted((k, v.shape) for k, v in batch.items()))
        if shape_key not in self._seen_shapes:
            self._seen_shapes.add(shape_key)
            self.result.n_compiles += 1

        # grad_spike fault: a one-step (n_leaves,) multiplier on the raw
        # per-leaf gradients (None on clean steps keeps the common trace)
        grad_scale = None
        if self._pending_grad_fault is not None \
                and self.fault_injector is not None:
            factor, substr = self._pending_grad_fault
            self._pending_grad_fault = None
            grad_scale = self.fault_injector.grad_scale_vector(
                self.leaf_labels, self.step, factor, substr)
        # optional runtime vectors: only passed when active, so the common
        # trace (no fault, no per-leaf backoff) stays byte-identical
        extra: Dict[str, Any] = {}
        if grad_scale is not None:
            extra["grad_scale"] = grad_scale
        if self._recovery_reg is not None \
                and self._recovery_reg.leaf_lr_scales:
            extra["leaf_lr"] = self._recovery_reg.leaf_lr_vector(
                self.leaf_labels)
        self.state, metrics = self.step_fn(
            self.state, batch, np.float32(plan.lr),
            np.float32(plan.grad_clip_scale), **extra)
        # per-leaf vectors (telemetry_level == "per_leaf") ride StepTelemetry,
        # not the scalar metrics dict the hooks float()
        metrics, per_leaf = telemetry_lib.split_metrics(metrics)
        loss = float(metrics["loss"])
        ratio = (self.tracker.update(loss) if math.isfinite(loss)
                 else float("inf"))
        nan = float("nan")
        post = dataclasses.replace(
            tele, loss=loss, loss_ratio=ratio,
            grad_norm=float(metrics["grad_norm"]),
            grad_norm_clipped=float(metrics.get("grad_norm_clipped", nan)),
            var_max=float(metrics["var_max"]),
            var_l1=float(metrics["var_l1"]),
            gns_small_sq=float(metrics.get("gns_small_sq", nan)),
            gns_big_sq=float(metrics.get("gns_big_sq", nan)),
            gns_b_small=float(metrics.get("gns_b_small", nan)),
            gns_b_big=float(metrics.get("gns_b_big", nan)),
            per_leaf=per_leaf,
            leaf_labels=self.leaf_labels if per_leaf is not None else ())
        self.stack.observe(post, tokens_step)
        self.step += 1
        self.tokens_seen += tokens_step
        self._last = post
        return post, plan, metrics

    # -- the loop -------------------------------------------------------------
    def run(self, max_steps: Optional[int] = None) -> TrainResult:
        opt_cfg = self.tc.optimizer
        total_steps = opt_cfg.total_steps or 10**9
        total_tokens = opt_cfg.total_tokens or 10**18
        if max_steps is not None:
            total_steps = min(total_steps, self.step + max_steps)

        t_start = time.time()
        for h in self.hooks:
            h.on_run_start(self)
        try:
            while self.step < total_steps and self.tokens_seen < total_tokens:
                for h in self.hooks:
                    h.on_step_start(self)
                if self._drain_requested:
                    self.save_checkpoint()
                    # spill the in-run rollback ring next to the checkpoint:
                    # the restore points survive the preemption (resume()
                    # refills the ring on --recover)
                    if self.recovery is not None and self._ring_dir:
                        self.recovery.ring.save(self._ring_dir)
                    self.result.drained = True
                    break
                if (self.fail_at_step is not None
                        and self.step == self.fail_at_step):
                    raise RuntimeError(f"injected failure at step {self.step}")

                tele, plan, metrics = self.run_step()

                if not math.isfinite(tele.loss):
                    self.result.diverged = True
                    self.stopping = self.stop_on_nan
                for h in self.hooks:
                    h.on_step_end(self, tele, plan, metrics)
                if self.stopping:
                    break
        except BaseException:
            # crash path: resource cleanup only — no checkpoints/summaries
            # during unwind (a real preemption couldn't write them either,
            # and self.state may hold donated buffers)
            for h in self.hooks:
                h.close()
            raise
        for h in self.hooks:
            h.on_run_end(self)
        for h in self.hooks:
            h.close()
        self.result.steps = self.step
        self.result.tokens = self.tokens_seen
        self.result.wall_time_s = time.time() - t_start
        return self.result


def train(tc: TrainConfig,
          max_steps: Optional[int] = None,
          eval_batch: int = 8,
          resume: bool = False,
          stop_on_nan: bool = True,
          drain: Optional[DrainSignal] = None,
          callback: Optional[Callable[[int, Dict[str, float]], None]] = None,
          fail_at_step: Optional[int] = None,
          quiet: bool = True,
          dp_size: int = 1,
          hooks: Optional[List[TrainerHook]] = None,
          recovery: Optional[RecoveryConfig] = None,
          fault_injector: Optional[FaultInjector] = None) -> TrainResult:
    """Run the training loop on the local device(s). Returns full telemetry.

    Thin wrapper over :class:`Trainer` so existing entry points keep
    working.  `fail_at_step` injects a crash (fault-tolerance tests/drills);
    `fault_injector` injects the richer step-indexed fault matrix and
    `recovery` turns on divergence rollback.
    """
    trainer = Trainer(tc, dp_size=dp_size, eval_batch=eval_batch,
                      stop_on_nan=stop_on_nan, drain=drain, callback=callback,
                      fail_at_step=fail_at_step, quiet=quiet, hooks=hooks,
                      recovery=recovery, fault_injector=fault_injector)
    if resume:
        trainer.resume()
    return trainer.run(max_steps=max_steps)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def build_config(args) -> TrainConfig:
    spec = get_arch(args.arch)
    cfg = reduce_cfg(spec.model) if args.reduced else spec.model
    if args.vocab:
        cfg = cfg.replace(vocab_size=args.vocab)
    slw = SLWConfig(
        enabled=args.slw, pacing=args.pacing, start_seq_len=args.start_seq,
        duration_steps=args.duration, round_multiple=args.round_multiple,
        mode=args.slw_mode, max_buckets=args.max_buckets)
    opt = OptimizerConfig(
        lr=args.lr, min_lr=args.min_lr, warmup_steps=args.warmup,
        warmup_tokens=args.warmup * args.batch * args.seq,
        total_steps=args.steps,
        total_tokens=args.tokens or args.steps * args.batch * args.seq,
        schedule=args.schedule, grad_clip=args.clip,
        optimizer=args.optimizer, decay_mask=args.decay_mask,
        agc_clip=args.agc,
        telemetry_level=("per_leaf" if args.per_leaf_telemetry
                         else "scalar"))
    bw = BatchWarmupConfig(enabled=args.batch_warmup,
                           start_batch=max(args.batch // 8, 1),
                           warmup_tokens=(args.tokens or args.steps
                                          * args.batch * args.seq) // 20)
    gns = GNSConfig(enabled=args.gns or args.gns_batch,
                    shards=args.gns_shards,
                    precursor_window=args.gns_precursor_window,
                    headroom=args.gns_headroom)
    tc = TrainConfig(model=cfg, optimizer=opt, slw=slw, batch_warmup=bw,
                     gns=gns,
                     seq_len=args.seq, global_batch=args.batch,
                     seed=args.seed, remat=args.remat,
                     eval_interval=args.eval_interval,
                     checkpoint_interval=args.ckpt_interval,
                     checkpoint_dir=args.ckpt_dir)
    # adaptive regulators opt in via the explicit stack: the auto-derived
    # schedules first, the telemetry-driven ones after (order matters — the
    # LR throttle multiplies the scheduled LR).
    extra = []
    if args.grad_noise_batch:
        extra.append(RegulatorSpec(kind="grad_noise_batch"))
    if args.gns_batch:
        extra.append(RegulatorSpec(kind="critical_batch"))
    if args.var_lr_throttle:
        extra.append(RegulatorSpec(kind="var_lr_throttle"))
    if extra:
        from repro.core.regulators import auto_specs
        tc = dataclasses.replace(tc,
                                 regulators=auto_specs(tc) + tuple(extra))
    return tc


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="gpt2-117m")
    p.add_argument("--reduced", action="store_true",
                   help="reduced same-family config (CPU-trainable)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--tokens", type=int, default=0)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--vocab", type=int, default=0)
    p.add_argument("--lr", type=float, default=6e-4)
    p.add_argument("--min-lr", type=float, default=1e-5)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--clip", type=float, default=1.0)
    p.add_argument("--schedule", default="token_cosine",
                   choices=["token_cosine", "step_cosine", "constant"])
    p.add_argument("--optimizer", default="adamw",
                   choices=["adamw", "sm3", "shampoo"],
                   help="inner optimizer of the gradient-transform chain")
    p.add_argument("--decay-mask", default="all", choices=["all", "std"],
                   help="'std' exempts 1-D/scalar leaves (norm gains, "
                        "biases) from weight decay; 'all' is the legacy "
                        "decay-everything behavior")
    p.add_argument("--agc", type=float, default=0.0,
                   help="adaptive gradient clipping threshold (per-leaf "
                        "grad/param norm ratio; 0 disables)")
    p.add_argument("--per-leaf-telemetry", action="store_true",
                   help="per-parameter-group telemetry vectors (var_max/"
                        "grad/update/param norms per labeled leaf) — feeds "
                        "per-layer blame in regulators and recovery")
    p.add_argument("--slw", action="store_true")
    p.add_argument("--pacing", default="linear",
                   choices=["linear", "root", "two_stage", "variance_gated",
                            "constant"])
    p.add_argument("--start-seq", type=int, default=8)
    p.add_argument("--duration", type=int, default=0)
    p.add_argument("--round-multiple", type=int, default=8)
    p.add_argument("--max-buckets", type=int, default=16)
    p.add_argument("--slw-mode", default="truncate",
                   choices=["truncate", "repack"])
    p.add_argument("--batch-warmup", action="store_true",
                   help="composes with --slw (the paper's joint recipe)")
    p.add_argument("--grad-noise-batch", action="store_true",
                   help="adaptive batch sizing from grad-norm noise")
    p.add_argument("--gns", action="store_true",
                   help="gradient-noise-scale measurement: per-shard grad "
                        "norms inside the jitted step -> unbiased B_noise "
                        "estimate + direction-sketch spike precursor "
                        "(repro.gns)")
    p.add_argument("--gns-shards", type=int, default=4,
                   help="emulated data-parallel shards for the GNS pair "
                        "(largest divisor of the realized batch is used)")
    p.add_argument("--gns-batch", action="store_true",
                   help="B_noise-measured batch warmup (critical_batch "
                        "regulator; implies --gns)")
    p.add_argument("--gns-precursor-window", type=int, default=12,
                   help="direction-sketch ring length for the spike "
                        "precursor (0 disables the precursor)")
    p.add_argument("--gns-headroom", type=float, default=2.0,
                   help="grow the batch while B_noise > headroom * batch")
    p.add_argument("--var-lr-throttle", action="store_true",
                   help="LR backoff while Adam variance-max spikes")
    p.add_argument("--dp-size", type=int, default=0,
                   help="data-parallel size for batch quantization "
                        "(0 = jax.device_count())")
    p.add_argument("--remat", default="none",
                   choices=["none", "full", "dots"])
    p.add_argument("--seed", type=int, default=1234)
    p.add_argument("--eval-interval", type=int, default=50)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-interval", type=int, default=100)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--metrics-jsonl", default="",
                   help="append per-step StepPlan/StepTelemetry rows to "
                        "this JSONL file (telemetry TrainerHook)")
    p.add_argument("--recover", action="store_true",
                   help="divergence-aware recovery: detect NaN/spike/"
                        "variance excursions, roll back to an in-run "
                        "snapshot, intervene (LR backoff -> seq clamp -> "
                        "data skip)")
    p.add_argument("--max-rollbacks", type=int, default=3,
                   help="in-process rollback budget before hard failure")
    p.add_argument("--inject-faults", default="",
                   help="deterministic fault matrix, e.g. "
                        "'nan_grad@12,spike@20:8.0,crash@30:post_tmp,"
                        "stall@8:0.25' (kind@step[:arg], comma-separated)")
    p.add_argument("--inject-seed", type=int, default=0,
                   help="seed for fault placement (which leaf/byte)")
    args = p.parse_args(argv)

    tc = build_config(args)
    drain = DrainSignal()
    dp = args.dp_size or jax.device_count()
    hooks = ([MetricsJsonlHook(args.metrics_jsonl)]
             if args.metrics_jsonl else None)
    recovery = (RecoveryConfig(policy=RetryPolicy(
        max_retries=args.max_rollbacks)) if args.recover else None)
    injector = (FaultInjector.from_cli(args.inject_faults,
                                       seed=args.inject_seed)
                if args.inject_faults else None)
    res = train(tc, resume=args.resume, drain=drain, quiet=False, dp_size=dp,
                hooks=hooks, recovery=recovery, fault_injector=injector)
    print(f"\ndone: steps={res.steps} tokens={res.tokens} "
          f"diverged={res.diverged} compiles={res.n_compiles}")
    print("stability:", res.tracker_summary)
    print("watchdog:", res.watchdog_summary)
    if recovery is not None or injector is not None:
        print(f"recovery: rollbacks={res.rollbacks} "
              f"events={res.recovery_events} faults={res.faults_fired}")
    return 0 if not res.diverged else 1


if __name__ == "__main__":
    raise SystemExit(main())
