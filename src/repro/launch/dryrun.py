import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh ((16,16) "data","model" or (2,16,16)
     "pod","data","model"),
  2. resolves the sharding contract (param/opt/batch/cache NamedShardings)
     from the logical-axis rules,
  3. ``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``
     and ``.compile()`` — no real allocation anywhere,
  4. records memory_analysis / cost_analysis / the collective schedule
     (parsed from the compiled HLO) as a JSON record for the roofline.

Failures here (sharding mismatch, OOM-scale temps, unsupported collective)
are bugs in the system — the CI gate for "would this run on the real mesh".

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single multi --out experiments/dryrun --skip-existing
"""
# (no `from __future__ import annotations` here: the XLA_FLAGS lines must be
# the first statements in the module, which rules out __future__ imports.)
import argparse
import json
import time
import traceback
from typing import Dict, List, Optional

import numpy as np


def _lower_step(cfg, shape, mesh, rules, remat: str, block_kv: int,
                unroll_layers: bool = False):
    """Build the step fn + sharding contract for a cell and lower it.
    Returns the jax Lowered object."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import OptimizerConfig
    from repro.launch import steps as steps_lib
    from repro.models import model_zoo

    model = model_zoo.build_model(cfg, dtype=jnp.bfloat16, remat=remat,
                                  block_kv=block_kv)
    model.unroll_layers = unroll_layers
    with mesh:
        if shape.kind == "train":
            step_fn = steps_lib.make_train_step(model, OptimizerConfig(),
                                                rules)
            state = steps_lib.abstract_train_state(cfg)
            state_sh = steps_lib.train_state_shardings(rules, cfg)
            batch = model_zoo.train_batch_specs(cfg, shape.global_batch,
                                                shape.seq_len)
            batch_sh = steps_lib.batch_shardings(rules, cfg, batch)
            lr = jax.ShapeDtypeStruct((), jnp.float32)
            return jax.jit(
                step_fn,
                in_shardings=(state_sh, batch_sh, None),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state, batch, lr)
        if shape.kind == "prefill":
            step_fn = steps_lib.make_prefill_step(model, rules)
            params = model_zoo.abstract_params(cfg)
            p_sh = steps_lib.train_state_shardings(rules, cfg)["params"]
            batch = model_zoo.prefill_batch_specs(cfg, shape.global_batch,
                                                  shape.seq_len)
            batch_sh = steps_lib.batch_shardings(rules, cfg, batch)
            cache_sh = steps_lib.cache_shardings(rules, model,
                                                 shape.global_batch,
                                                 shape.seq_len)
            return jax.jit(
                step_fn,
                in_shardings=(p_sh, batch_sh),
                out_shardings=(NamedSharding(mesh, P()), cache_sh),
            ).lower(params, batch)
        # decode
        step_fn = steps_lib.make_serve_step(model, rules)
        params = model_zoo.abstract_params(cfg)
        p_sh = steps_lib.train_state_shardings(rules, cfg)["params"]
        cache = model.cache_shapes(shape.global_batch, shape.seq_len)
        cache_sh = steps_lib.cache_shardings(rules, model,
                                             shape.global_batch,
                                             shape.seq_len)
        tokens = model_zoo.decode_token_specs(shape.global_batch)
        tok_sh = steps_lib.batch_shardings(
            rules, cfg, {"tokens": tokens})["tokens"]
        return jax.jit(
            step_fn,
            in_shardings=(p_sh, cache_sh, tok_sh),
            out_shardings=(NamedSharding(mesh, P()), cache_sh),
            donate_argnums=(1,),
        ).lower(params, cache, tokens)


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             rule_set: str = "fsdp", remat: str = "full",
             block_kv: int = 512, seq_shard: str = "auto",
             moe_dispatch: str = "") -> Dict:
    """Lower+compile one cell; returns the JSON record."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.distributed.sharding import ShardingRules
    from repro.launch.mesh import make_production_mesh
    from repro.models import model_zoo
    from repro.roofline import analysis as roofline

    spec = get_arch(arch_name)
    shape = spec.shape(shape_name)
    cfg = spec.model
    if moe_dispatch:
        cfg = cfg.replace(moe_dispatch=moe_dispatch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    if seq_shard == "auto":
        seq_sharded, seq_axis = shape.name == "long_500k", "data"
    else:
        seq_sharded, seq_axis = seq_shard != "none", seq_shard if seq_shard != "none" else "data"
    rules = ShardingRules.make(mesh, rule_set, seq_sharded_cache=seq_sharded,
                               seq_shard_axis=seq_axis)
    record: Dict = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "chips": chips, "rule_set": rule_set,
        "remat": remat, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch, "seq_shard": seq_shard,
        "moe_dispatch": moe_dispatch or cfg.moe_dispatch,
    }

    t0 = time.time()
    lowered = _lower_step(cfg, shape, mesh, rules, remat, block_kv)
    record["lower_s"] = time.time() - t0
    t1 = time.time()
    with mesh:
        compiled = lowered.compile()
    record["compile_s"] = time.time() - t1

    # --- analysis artifacts -------------------------------------------------
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    record["cost"] = {k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float)) and k in
                      ("flops", "bytes accessed", "transcendentals",
                       "utilization")}
    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            "per_device_bytes": float(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)),
            "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
            "generated_code_bytes": float(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # noqa: BLE001
        record["memory"] = {"error": str(e)}

    hlo = compiled.as_text()
    record["collectives"] = roofline.parse_collectives(hlo, chips)
    record["hlo_lines"] = hlo.count("\n")

    record["params_total"] = model_zoo.param_count(cfg)
    record["params_active"] = model_zoo.active_param_count(cfg)
    record["model_flops"] = roofline.model_flops(
        cfg, shape.kind, shape.global_batch, shape.seq_len,
        record["params_active"])
    record["sharding_fallbacks"] = rules.fallbacks
    return record


def _collective_wire_bytes(compiled, chips: int) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (post-SPMD shapes)."""
    from repro.roofline import analysis as roofline
    from repro.roofline import hw
    out: Dict[str, float] = {}
    for c in roofline.parse_collectives(compiled.as_text(), chips):
        w = hw.wire_bytes(c["kind"], c["result_bytes"], c["group"])
        out[c["kind"]] = out.get(c["kind"], 0.0) + w
    return out


def measure_cell(arch_name: str, shape_name: str, mesh_kind: str = "single",
                 rule_set: str = "fsdp", remat: str = "full",
                 block_kv: int = 512, seq_shard: str = "auto",
                 moe_dispatch: str = "") -> Dict:
    """Roofline measurement for one cell.

    XLA's cost_analysis counts while-loop bodies once, so the full-config
    compile (run_cell) cannot give per-step FLOPs/collective bytes directly.
    This combines:
      * exact global FLOPs / estimated HBM bytes from a scan-aware jaxpr
        analysis of the very step function the dry-run lowers, and
      * per-layer collective wire bytes measured on *unrolled* reduced-depth
        compiles (2 and 4 layers; hybrid uses three (n_layers, attn_every)
        points to separate the Mamba and shared-attention marginals),
        linearly extrapolated to the full depth.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.configs.base import OptimizerConfig
    from repro.distributed.sharding import ShardingRules
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh
    from repro.models import model_zoo
    from repro.roofline import analysis as roofline
    from repro.roofline.jaxpr_cost import analyze_fn

    spec = get_arch(arch_name)
    shape = spec.shape(shape_name)
    cfg = spec.model
    if moe_dispatch:
        cfg = cfg.replace(moe_dispatch=moe_dispatch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    if seq_shard == "auto":
        seq_sharded, seq_axis = shape.name == "long_500k", "data"
    else:
        seq_sharded, seq_axis = seq_shard != "none", seq_shard if seq_shard != "none" else "data"
    record: Dict = {"arch": arch_name, "shape": shape_name,
                    "mesh": mesh_kind, "kind": shape.kind, "chips": chips,
                    "rule_set": rule_set, "remat": remat,
                    "seq_shard": seq_shard,
                    "moe_dispatch": moe_dispatch or cfg.moe_dispatch}

    # --- 1. exact global flops/bytes from the traced jaxpr -----------------
    model = model_zoo.build_model(cfg, dtype=jnp.bfloat16, remat=remat,
                                  block_kv=block_kv)
    t0 = time.time()
    if shape.kind == "train":
        fn = steps_lib.make_train_step(model, OptimizerConfig(), None)
        state = steps_lib.abstract_train_state(cfg)
        batch = model_zoo.train_batch_specs(cfg, shape.global_batch,
                                            shape.seq_len)
        cost = analyze_fn(fn, state, batch,
                          jax.ShapeDtypeStruct((), jnp.float32))
    elif shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(model, None)
        batch = model_zoo.prefill_batch_specs(cfg, shape.global_batch,
                                              shape.seq_len)
        cost = analyze_fn(fn, model_zoo.abstract_params(cfg), batch)
    else:
        fn = steps_lib.make_serve_step(model, None)
        cache = model.cache_shapes(shape.global_batch, shape.seq_len)
        tokens = model_zoo.decode_token_specs(shape.global_batch)
        cost = analyze_fn(fn, model_zoo.abstract_params(cfg), cache, tokens)
    record["jaxpr_flops_global"] = cost.flops
    record["jaxpr_bytes_global"] = cost.bytes
    record["jaxpr_flops_by_prim"] = {
        k: v for k, v in sorted(cost.by_prim.items(),
                                key=lambda kv: -kv[1])[:8]}
    record["trace_s"] = time.time() - t0

    # --- 2. collective wire bytes via unrolled-depth extrapolation ---------
    rules_points = []
    if cfg.family == "hybrid":
        points = [{"n_layers": 2, "attn_every": 2},
                  {"n_layers": 4, "attn_every": 2},
                  {"n_layers": 2, "attn_every": 1}]
    else:
        points = [{"n_layers": 2}, {"n_layers": 4}]
    measures = []
    t1 = time.time()
    for pt in points:
        cfg_small = cfg.replace(**pt)
        rules = ShardingRules.make(mesh, rule_set,
                                   seq_sharded_cache=seq_sharded,
                                   seq_shard_axis=seq_axis)
        lowered = _lower_step(cfg_small, shape, mesh, rules, remat, block_kv,
                              unroll_layers=True)
        with mesh:
            compiled = lowered.compile()
        measures.append(_collective_wire_bytes(compiled, chips))
        rules_points.append(pt)
    record["collective_points"] = [
        {"point": p, "wire_bytes": m} for p, m in zip(rules_points, measures)]
    record["collective_compile_s"] = time.time() - t1

    kinds = sorted({k for m in measures for k in m})
    extrap: Dict[str, float] = {}
    if cfg.family == "hybrid":
        g_full = cfg.n_layers // cfg.attn_every  # attn applications
        for k in kinds:
            m1 = measures[0].get(k, 0.0)  # C + 2x + 1y
            m2 = measures[1].get(k, 0.0)  # C + 4x + 2y
            m3 = measures[2].get(k, 0.0)  # C + 2x + 2y
            y = m3 - m1
            x = (m2 - m1 - y) / 2.0
            c0 = m1 - 2 * x - y
            extrap[k] = max(c0 + cfg.n_layers * x + g_full * y, 0.0)
    else:
        for k in kinds:
            m1, m2 = measures[0].get(k, 0.0), measures[1].get(k, 0.0)
            marg = (m2 - m1) / 2.0
            c0 = m1 - 2 * marg
            extrap[k] = max(c0 + cfg.n_layers * marg, 0.0)
    record["collective_wire_bytes_per_device"] = extrap
    record["collective_wire_total"] = sum(extrap.values())

    record["params_total"] = model_zoo.param_count(cfg)
    record["params_active"] = model_zoo.active_param_count(cfg)
    record["model_flops"] = roofline.model_flops(
        cfg, shape.kind, shape.global_batch, shape.seq_len,
        record["params_active"])
    return record


def cell_path(out_dir: str, arch: str, shape: str, mesh_kind: str,
              tag: str = "") -> str:
    suffix = f".{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--arch", nargs="+", default=["all"])
    parser.add_argument("--shape", nargs="+", default=["all"])
    parser.add_argument("--mesh", nargs="+", default=["single", "multi"],
                        choices=["single", "multi"])
    parser.add_argument("--rules", default="fsdp",
                        choices=["fsdp", "baseline", "fsdp_pure", "serve_tp"])
    parser.add_argument("--remat", default="full",
                        choices=["full", "dots", "none"])
    parser.add_argument("--block-kv", type=int, default=512)
    parser.add_argument("--out", default="experiments/dryrun")
    parser.add_argument("--tag", default="",
                        help="suffix for perf-iteration variants")
    parser.add_argument("--skip-existing", action="store_true")
    parser.add_argument("--moe-dispatch", default="",
                        choices=["", "global", "row_local"])
    parser.add_argument("--seq-shard", default="auto",
                        choices=["auto", "none", "data", "model"],
                        help="KV-cache sequence-axis sharding (auto: data "
                        "for long_500k only)")
    parser.add_argument("--measure", action="store_true",
                        help="roofline measurement mode (jaxpr flops + "
                        "unrolled-depth collective extrapolation); writes "
                        "<cell>.measure[.tag].json")
    args = parser.parse_args(argv)

    from repro.configs import ASSIGNED, get_arch

    archs = (list(ASSIGNED) if args.arch == ["all"] else args.arch)
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch_name in archs:
        spec = get_arch(arch_name)
        shapes = ([s.name for s in spec.runnable_shapes()]
                  if args.shape == ["all"] else args.shape)
        for shape_name in shapes:
            if shape_name not in [s.name for s in spec.runnable_shapes()]:
                print(f"SKIP {arch_name} x {shape_name} (documented skip)")
                continue
            for mesh_kind in args.mesh:
                tag = (("measure." if args.measure else "") + args.tag
                       ).rstrip(".")
                path = cell_path(args.out, arch_name, shape_name, mesh_kind,
                                 tag)
                if args.skip_existing and os.path.exists(path):
                    print(f"CACHED {path}")
                    continue
                label = f"{arch_name} x {shape_name} x {mesh_kind}"
                print(f"RUN {label} ...", flush=True)
                try:
                    if args.measure:
                        rec = measure_cell(arch_name, shape_name, mesh_kind,
                                           rule_set=args.rules,
                                           remat=args.remat,
                                           block_kv=args.block_kv,
                                           seq_shard=args.seq_shard,
                                           moe_dispatch=args.moe_dispatch)
                        with open(path, "w") as f:
                            json.dump(rec, f, indent=1)
                        print(f"OK  {label}: jaxpr_flops="
                              f"{rec['jaxpr_flops_global']:.3e} "
                              f"coll/dev={rec['collective_wire_total']:.3e}B",
                              flush=True)
                    else:
                        rec = run_cell(arch_name, shape_name, mesh_kind,
                                       rule_set=args.rules, remat=args.remat,
                                       block_kv=args.block_kv,
                                       seq_shard=args.seq_shard,
                                       moe_dispatch=args.moe_dispatch)
                        with open(path, "w") as f:
                            json.dump(rec, f, indent=1)
                        print(f"OK  {label}: compile={rec['compile_s']:.1f}s "
                              f"flops/dev={rec['cost'].get('flops', 0):.3e} "
                              f"hlo_lines={rec['hlo_lines']}", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((label, str(e)))
                    traceback.print_exc()
                    print(f"FAIL {label}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for label, err in failures:
            print(f"  {label}: {err[:200]}")
        return 1
    print("\nall cells compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
