"""Step builders: train / prefill / decode (serve) steps + their shardings.

One place defines (a) the jitted step functions and (b) the full sharding
contract (state / batch / cache NamedShardings) so the dry-run, the trainer
and the tests all lower the same computation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.distributed.sharding import ShardingRules, use_rules
from repro.models import model_zoo
from repro.optim import adam


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(model, opt_cfg: OptimizerConfig,
                    rules: Optional[ShardingRules] = None):
    # `clip_scale` is a runtime scalar so regulators (e.g. the variance LR
    # throttle) can tighten the clip per step without recompiling; callers
    # that never pass it get the config constant.
    def train_step(state, batch, lr, clip_scale=1.0):
        with use_rules(rules):
            def loss_fn(p):
                return model.loss(p, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"])
            grads, gnorm = adam.clip_by_global_norm(
                grads, opt_cfg.grad_clip * clip_scale)
            new_params, new_opt, telemetry = adam.adamw_update(
                state["params"], grads, state["opt"], lr, opt_cfg)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out = {**metrics, **telemetry, "grad_norm": gnorm, "lr": lr}
        return new_state, out

    return train_step


def make_prefill_step(model, rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model, rules: Optional[ShardingRules] = None):
    def serve_step(params, cache, tokens):
        with use_rules(rules):
            return model.decode(params, cache, tokens)
    return serve_step


# ---------------------------------------------------------------------------
# abstract state + sharding trees
# ---------------------------------------------------------------------------

def abstract_train_state(cfg: ModelConfig) -> Dict[str, Any]:
    params = model_zoo.abstract_params(cfg)
    return {"params": params, "opt": adam.abstract_opt_state(params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def init_train_state(rng, cfg: ModelConfig) -> Dict[str, Any]:
    params = model_zoo.init_params(rng, cfg)
    return {"params": params, "opt": adam.init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def _shard_tree(rules: ShardingRules, axes_tree, shape_tree, kind: str):
    is_axes_leaf = lambda x: x is None or (
        isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                     for a in x))

    def one(axes, sds):
        spec = (rules.param_spec(axes, sds.shape) if kind == "param"
                else rules.act_spec(axes, sds.shape))
        return NamedSharding(rules.mesh, spec)

    return jax.tree_util.tree_map(one, axes_tree, shape_tree,
                                  is_leaf=is_axes_leaf)


def train_state_shardings(rules: ShardingRules, cfg: ModelConfig):
    axes = model_zoo.param_axes(cfg)
    shapes = model_zoo.abstract_params(cfg)
    p_sh = _shard_tree(rules, axes, shapes, "param")
    replicated = NamedSharding(rules.mesh, P())
    return {"params": p_sh,
            "opt": {"m": p_sh, "v": p_sh, "count": replicated},
            "step": replicated}


def batch_shardings(rules: ShardingRules, cfg: ModelConfig, specs):
    axes = model_zoo.batch_logical_axes(cfg)
    axes = {k: v for k, v in axes.items() if k in specs}
    return _shard_tree(rules, axes, specs, "act")


def cache_shardings(rules: ShardingRules, model, batch_size: int,
                    seq_len: int):
    axes = model.cache_axes()
    shapes = model.cache_shapes(batch_size, seq_len)
    return _shard_tree(rules, axes, shapes, "act")
