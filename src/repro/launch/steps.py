"""Step builders: train / prefill / decode (serve) steps + their shardings.

One place defines (a) the jitted step functions and (b) the full sharding
contract (state / batch / cache NamedShardings) so the dry-run, the trainer
and the tests all lower the same computation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import GNSConfig, ModelConfig, OptimizerConfig
from repro.distributed.sharding import ShardingRules, use_rules
from repro.models import model_zoo
from repro.optim import transforms as optim_tx


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def _gns_shard_count(gns: GNSConfig, batch_rows: int) -> int:
    """Realized emulated-replica count: the largest divisor of the step's
    batch that is <= the configured shard count (1 = GNS pair unavailable
    for this batch shape — e.g. a batch of one row)."""
    for k in range(min(gns.shards, batch_rows), 1, -1):
        if batch_rows % k == 0:
            return k
    return 1


def make_train_step(model, opt_cfg: OptimizerConfig,
                    rules: Optional[ShardingRules] = None,
                    optimizer: Optional[optim_tx.GradientTransform] = None,
                    gns: Optional[GNSConfig] = None):
    # `clip_scale` is a runtime scalar so regulators (e.g. the variance LR
    # throttle) can tighten the clip per step without recompiling; callers
    # that never pass it get the config constant.  `grad_scale`, when not
    # None, is a (n_leaves,) runtime vector multiplied onto the raw
    # per-leaf gradients pre-clip — the fault injector's hook for targeting
    # one block's gradients.  `leaf_lr`, when not None, is a (n_leaves,)
    # runtime vector carried to the chain as hyper["leaf_lr_scale"] — the
    # recovery controller's per-layer LR backoff surface.  Both default to
    # None so the common trace is byte-identical to the legacy step.
    #
    # `gns` (when enabled) adds the gradient-noise-scale measurement: the
    # batch is viewed as k emulated data-parallel shards and the per-shard
    # gradients are computed with a vmapped value_and_grad — the full-batch
    # gradient is their (token-weighted) mean, exactly what a psum over
    # real dp replicas would produce, so the small/big squared-norm pair
    # the estimator needs comes from what each shard already holds.  The
    # disabled path does not touch the trace at all.
    tx = optimizer if optimizer is not None else \
        optim_tx.build_optimizer(opt_cfg)
    gns_cfg = gns if (gns is not None and gns.enabled) else None
    if gns_cfg is not None and gns_cfg.precursor_window > 0:
        sketch_key = jax.random.PRNGKey(gns_cfg.sketch_seed)
        sketch_dim = max(gns_cfg.precursor_dim, 1)

        def _sketch(i, g):
            """(d,) random-sign bucket sketch of one leaf's gradient: an
            unbiased inner-product sketch (E[<s_t,s_u>] = <g_t,g_u>) with
            fixed per-leaf signs, O(n) compute / O(d) output."""
            flat = g.astype(jnp.float32).reshape(-1)
            m = -(-flat.shape[0] // sketch_dim)  # ceil(n / d)
            flat = jnp.pad(flat, (0, m * sketch_dim - flat.shape[0]))
            signs = jax.random.rademacher(
                jax.random.fold_in(sketch_key, i),
                (m * sketch_dim,), jnp.float32)
            return jnp.sum((flat * signs).reshape(sketch_dim, m), axis=1)

    def _scaled_leaves(tree, grad_scale):
        leaves, td = jax.tree_util.tree_flatten(tree)
        if grad_scale is not None:
            leaves = [g * grad_scale[i].astype(g.dtype)
                      for i, g in enumerate(leaves)]
        return leaves, td

    def train_step(state, batch, lr, clip_scale=1.0, grad_scale=None,
                   leaf_lr=None):
        with use_rules(rules):
            def loss_fn(p, b):
                return model.loss(p, b)

            gns_tel = {}
            rows = jax.tree_util.tree_leaves(batch)[0].shape[0]
            k = _gns_shard_count(gns_cfg, rows) if gns_cfg is not None else 1
            if k >= 2:
                # k emulated dp shards: contiguous row groups, per-shard
                # value_and_grad under vmap; the full-batch gradient is
                # the token-weighted shard mean (loss is a token-mean, so
                # this equals the single-pass gradient up to fp rounding)
                sharded = jax.tree_util.tree_map(
                    lambda v: v.reshape((k, v.shape[0] // k) + v.shape[1:]),
                    batch)
                (losses, metrics_k), grads_k = jax.vmap(
                    lambda b: jax.value_and_grad(loss_fn, has_aux=True)(
                        state["params"], b))(sharded)
                tokens_k = metrics_k.get("tokens")
                w = (tokens_k.astype(jnp.float32)
                     / jnp.maximum(jnp.sum(tokens_k), 1.0)
                     if tokens_k is not None
                     else jnp.full((k,), 1.0 / k, jnp.float32))
                metrics = {
                    name: (jnp.sum(v, axis=0) if name == "tokens"
                           else jnp.tensordot(w, v.astype(jnp.float32),
                                              axes=1))
                    for name, v in metrics_k.items()}
                # per-shard leaves carry the grad_spike fault scale too, so
                # the measurement sees the same gradients the chain does
                shard_leaves, td = _scaled_leaves(grads_k, grad_scale)
                full_leaves = [
                    jnp.tensordot(w.astype(g.dtype), g, axes=1)
                    for g in shard_leaves]
                grads = jax.tree_util.tree_unflatten(td, full_leaves)
                sq = lambda g: jnp.square(g.astype(jnp.float32))
                leaf_small = jnp.stack([
                    jnp.mean(jnp.sum(sq(g),
                                     axis=tuple(range(1, g.ndim))))
                    for g in shard_leaves])
                leaf_big = jnp.stack([jnp.sum(sq(g)) for g in full_leaves])
                gns_tel = {
                    "gns_small_sq": jnp.sum(leaf_small),
                    "gns_big_sq": jnp.sum(leaf_big),
                    "gns_b_small": jnp.float32(rows // k),
                    "gns_b_big": jnp.float32(rows),
                    "leaf_gns_small_sq": leaf_small,
                    "leaf_gns_big_sq": leaf_big,
                }
                if gns_cfg.precursor_window > 0:
                    gns_tel["leaf_gns_sketch"] = jnp.stack([
                        _sketch(i, g) for i, g in enumerate(full_leaves)])
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], batch)
                if grad_scale is not None:
                    leaves, td = _scaled_leaves(grads, grad_scale)
                    grads = jax.tree_util.tree_unflatten(td, leaves)
            hyper = {"lr": lr, "clip_scale": clip_scale}
            if leaf_lr is not None:
                hyper["leaf_lr_scale"] = leaf_lr
            updates, new_opt, telemetry = tx.update(
                grads, state["opt"], state["params"], hyper)
            new_params = optim_tx.apply_updates(state["params"], updates)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out = {**metrics, **telemetry, **gns_tel, "lr": lr}
        return new_state, out

    return train_step


def make_prefill_step(model, rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model, rules: Optional[ShardingRules] = None):
    def serve_step(params, cache, tokens):
        with use_rules(rules):
            return model.decode(params, cache, tokens)
    return serve_step


# ---------------------------------------------------------------------------
# abstract state + sharding trees
# ---------------------------------------------------------------------------

def abstract_train_state(cfg: ModelConfig,
                         opt_cfg: Optional[OptimizerConfig] = None
                         ) -> Dict[str, Any]:
    """Shape tree of the train state.  ``opt_cfg`` selects the optimizer
    chain whose state rides under ``"opt"`` (default chain when omitted —
    the chain-format AdamW every legacy call site means)."""
    params = model_zoo.abstract_params(cfg)
    tx = optim_tx.build_optimizer(opt_cfg or OptimizerConfig())
    return {"params": params,
            "opt": optim_tx.abstract_chain_state(tx, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def init_train_state(rng, cfg: ModelConfig,
                     opt_cfg: Optional[OptimizerConfig] = None
                     ) -> Dict[str, Any]:
    params = model_zoo.init_params(rng, cfg)
    tx = optim_tx.build_optimizer(opt_cfg or OptimizerConfig())
    return {"params": params, "opt": tx.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _shard_tree(rules: ShardingRules, axes_tree, shape_tree, kind: str):
    is_axes_leaf = lambda x: x is None or (
        isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                     for a in x))

    def one(axes, sds):
        spec = (rules.param_spec(axes, sds.shape) if kind == "param"
                else rules.act_spec(axes, sds.shape))
        return NamedSharding(rules.mesh, spec)

    return jax.tree_util.tree_map(one, axes_tree, shape_tree,
                                  is_leaf=is_axes_leaf)


def train_state_shardings(rules: ShardingRules, cfg: ModelConfig,
                          opt_cfg: Optional[OptimizerConfig] = None):
    axes = model_zoo.param_axes(cfg)
    shapes = model_zoo.abstract_params(cfg)
    p_sh = _shard_tree(rules, axes, shapes, "param")
    replicated = NamedSharding(rules.mesh, P())
    return {"params": p_sh,
            "opt": _opt_state_shardings(cfg, opt_cfg, shapes, p_sh,
                                        replicated),
            "step": replicated}


def _opt_state_shardings(cfg: ModelConfig,
                         opt_cfg: Optional[OptimizerConfig],
                         params_abs, p_sh, replicated):
    """Shardings for the optimizer-chain state: any ``m``/``v`` subtree
    that mirrors the param pytree (Adam/SM3 momenta, nested or not) takes
    the param shardings leaf for leaf; everything else (counts, SM3
    accumulators, Shampoo Kronecker statistics) is replicated."""
    tx = optim_tx.build_optimizer(opt_cfg or OptimizerConfig())
    abs_opt = optim_tx.abstract_chain_state(tx, params_abs)
    # param sharding looked up by the tree-path suffix after an m/v marker
    p_by_path = {tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path): sh
                 for path, sh in
                 jax.tree_util.tree_flatten_with_path(p_sh)[0]}

    def one(path, _sds):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        for i, k in enumerate(keys):
            if k in ("m", "v") and tuple(keys[i + 1:]) in p_by_path:
                return p_by_path[tuple(keys[i + 1:])]
        return replicated

    flat, treedef = jax.tree_util.tree_flatten_with_path(abs_opt)
    return jax.tree_util.tree_unflatten(
        treedef, [one(path, sds) for path, sds in flat])


def batch_shardings(rules: ShardingRules, cfg: ModelConfig, specs):
    axes = model_zoo.batch_logical_axes(cfg)
    axes = {k: v for k, v in axes.items() if k in specs}
    return _shard_tree(rules, axes, specs, "act")


def cache_shardings(rules: ShardingRules, model, batch_size: int,
                    seq_len: int):
    axes = model.cache_axes()
    shapes = model.cache_shapes(batch_size, seq_len)
    return _shard_tree(rules, axes, shapes, "act")
