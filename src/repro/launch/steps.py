"""Step builders: train / prefill / decode (serve) steps + their shardings.

One place defines (a) the jitted step functions and (b) the full sharding
contract (state / batch / cache NamedShardings) so the dry-run, the trainer
and the tests all lower the same computation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.distributed.sharding import ShardingRules, use_rules
from repro.models import model_zoo
from repro.optim import transforms as optim_tx


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(model, opt_cfg: OptimizerConfig,
                    rules: Optional[ShardingRules] = None,
                    optimizer: Optional[optim_tx.GradientTransform] = None):
    # `clip_scale` is a runtime scalar so regulators (e.g. the variance LR
    # throttle) can tighten the clip per step without recompiling; callers
    # that never pass it get the config constant.  `grad_scale`, when not
    # None, is a (n_leaves,) runtime vector multiplied onto the raw
    # per-leaf gradients pre-clip — the fault injector's hook for targeting
    # one block's gradients (and a future per-leaf runtime control surface).
    tx = optimizer if optimizer is not None else \
        optim_tx.build_optimizer(opt_cfg)

    def train_step(state, batch, lr, clip_scale=1.0, grad_scale=None):
        with use_rules(rules):
            def loss_fn(p):
                return model.loss(p, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"])
            if grad_scale is not None:
                leaves, td = jax.tree_util.tree_flatten(grads)
                leaves = [g * grad_scale[i].astype(g.dtype)
                          for i, g in enumerate(leaves)]
                grads = jax.tree_util.tree_unflatten(td, leaves)
            updates, new_opt, telemetry = tx.update(
                grads, state["opt"], state["params"],
                {"lr": lr, "clip_scale": clip_scale})
            new_params = optim_tx.apply_updates(state["params"], updates)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out = {**metrics, **telemetry, "lr": lr}
        return new_state, out

    return train_step


def make_prefill_step(model, rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model, rules: Optional[ShardingRules] = None):
    def serve_step(params, cache, tokens):
        with use_rules(rules):
            return model.decode(params, cache, tokens)
    return serve_step


# ---------------------------------------------------------------------------
# abstract state + sharding trees
# ---------------------------------------------------------------------------

def abstract_train_state(cfg: ModelConfig,
                         opt_cfg: Optional[OptimizerConfig] = None
                         ) -> Dict[str, Any]:
    """Shape tree of the train state.  ``opt_cfg`` selects the optimizer
    chain whose state rides under ``"opt"`` (default chain when omitted —
    the chain-format AdamW every legacy call site means)."""
    params = model_zoo.abstract_params(cfg)
    tx = optim_tx.build_optimizer(opt_cfg or OptimizerConfig())
    return {"params": params,
            "opt": optim_tx.abstract_chain_state(tx, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def init_train_state(rng, cfg: ModelConfig,
                     opt_cfg: Optional[OptimizerConfig] = None
                     ) -> Dict[str, Any]:
    params = model_zoo.init_params(rng, cfg)
    tx = optim_tx.build_optimizer(opt_cfg or OptimizerConfig())
    return {"params": params, "opt": tx.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _shard_tree(rules: ShardingRules, axes_tree, shape_tree, kind: str):
    is_axes_leaf = lambda x: x is None or (
        isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                     for a in x))

    def one(axes, sds):
        spec = (rules.param_spec(axes, sds.shape) if kind == "param"
                else rules.act_spec(axes, sds.shape))
        return NamedSharding(rules.mesh, spec)

    return jax.tree_util.tree_map(one, axes_tree, shape_tree,
                                  is_leaf=is_axes_leaf)


def train_state_shardings(rules: ShardingRules, cfg: ModelConfig,
                          opt_cfg: Optional[OptimizerConfig] = None):
    axes = model_zoo.param_axes(cfg)
    shapes = model_zoo.abstract_params(cfg)
    p_sh = _shard_tree(rules, axes, shapes, "param")
    replicated = NamedSharding(rules.mesh, P())
    return {"params": p_sh,
            "opt": _opt_state_shardings(cfg, opt_cfg, shapes, p_sh,
                                        replicated),
            "step": replicated}


def _opt_state_shardings(cfg: ModelConfig,
                         opt_cfg: Optional[OptimizerConfig],
                         params_abs, p_sh, replicated):
    """Shardings for the optimizer-chain state: any ``m``/``v`` subtree
    that mirrors the param pytree (Adam/SM3 momenta, nested or not) takes
    the param shardings leaf for leaf; everything else (counts, SM3
    accumulators, Shampoo Kronecker statistics) is replicated."""
    tx = optim_tx.build_optimizer(opt_cfg or OptimizerConfig())
    abs_opt = optim_tx.abstract_chain_state(tx, params_abs)
    # param sharding looked up by the tree-path suffix after an m/v marker
    p_by_path = {tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path): sh
                 for path, sh in
                 jax.tree_util.tree_flatten_with_path(p_sh)[0]}

    def one(path, _sds):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        for i, k in enumerate(keys):
            if k in ("m", "v") and tuple(keys[i + 1:]) in p_by_path:
                return p_by_path[tuple(keys[i + 1:])]
        return replicated

    flat, treedef = jax.tree_util.tree_flatten_with_path(abs_opt)
    return jax.tree_util.tree_unflatten(
        treedef, [one(path, sds) for path, sds in flat])


def batch_shardings(rules: ShardingRules, cfg: ModelConfig, specs):
    axes = model_zoo.batch_logical_axes(cfg)
    axes = {k: v for k, v in axes.items() if k in specs}
    return _shard_tree(rules, axes, specs, "act")


def cache_shardings(rules: ShardingRules, model, batch_size: int,
                    seq_len: int):
    axes = model.cache_axes()
    shapes = model.cache_shapes(batch_size, seq_len)
    return _shard_tree(rules, axes, shapes, "act")
