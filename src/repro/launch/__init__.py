# launch layer: mesh / dryrun / train / serve.
# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and must
# only ever be imported as the very first thing in a fresh process.
from repro.launch import mesh, steps  # noqa: F401
