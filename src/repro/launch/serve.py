"""Batched serving driver: prefill + greedy decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced as reduce_cfg
from repro.data import SyntheticCorpus
from repro.models import model_zoo


def serve(arch: str, use_reduced: bool, batch: int, prompt_len: int,
          gen_tokens: int, cache_len: int = 0, seed: int = 0,
          quiet: bool = False):
    spec = get_arch(arch)
    cfg = reduce_cfg(spec.model) if use_reduced else spec.model
    model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
    params = model_zoo.init_params(jax.random.PRNGKey(seed), cfg)
    cache_len = cache_len or prompt_len + gen_tokens

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                             seed=seed)
    prompts = corpus.batch(0, batch)["tokens"]  # (B, prompt_len)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(lambda p, c, t: model.decode(p, c, t))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    logits.block_until_ready()
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t1 = time.time()
    for _ in range(gen_tokens):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t1

    gen = np.stack(out_tokens, axis=1)
    if not quiet:
        print(f"arch={cfg.name} batch={batch} prompt={prompt_len} "
              f"gen={gen_tokens}")
        print(f"prefill: {t_prefill*1e3:.1f} ms "
              f"({batch*prompt_len/max(t_prefill,1e-9):.0f} tok/s)")
        print(f"decode:  {t_decode*1e3:.1f} ms total, "
              f"{batch*gen_tokens/max(t_decode,1e-9):.0f} tok/s")
        print("sample:", gen[0][:16].tolist())
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "decode_tok_s": batch * gen_tokens / max(t_decode, 1e-9),
            "generated": gen}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    serve(args.arch, args.reduced, args.batch, args.prompt_len, args.gen,
          seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
