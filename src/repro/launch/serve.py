"""Serving CLI: continuous-batching engine (default) or the legacy
static-batch greedy path (``--legacy``).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 8 --batch 4 --prompt-len 64 --gen 32

``--batch`` keeps its historical meaning on both paths: the decode batch
width (engine slot count / legacy static batch).  The engine path admits
``--requests`` ragged requests through the prompt bucket ladder and
backfills slots as generations finish; the legacy path is kept verbatim as
the parity oracle (tests) and the static-batch baseline (bench_serve).

``--replicas N`` (or ``--disaggregate``) serves through the Router over N
replicas — each with ``--batch`` slots — under ``--policy`` admission;
``--disaggregate`` splits every serving unit into a prefill-role +
decode-role replica pair.  ``--metrics-jsonl PATH`` streams one JSONL row
per fused decode step (per replica) plus a final summary row, readable
back with ``core.telemetry.read_metrics_jsonl``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced as reduce_cfg
from repro.data import SyntheticCorpus
from repro.models import model_zoo
from repro.serve import (InferenceEngine, Request, Router, SamplingParams,
                         SchedulerConfig, make_replicas)
from repro.serve.policies import POLICIES
from repro.serve.router import ROUTES


class _JsonlWriter:
    """Append-one-row-per-call JSONL sink for Replica.on_step_metrics."""

    def __init__(self, path: str):
        self._f = open(path, "w")

    def __call__(self, row: dict) -> None:
        self._f.write(json.dumps(row) + "\n")

    def close(self) -> None:
        self._f.flush()
        self._f.close()


def serve(arch: str, use_reduced: bool, batch: int, prompt_len: int,
          gen_tokens: int, cache_len: int = 0, seed: int = 0,
          quiet: bool = False):
    """Legacy static-batch greedy decode (the engine's parity oracle)."""
    spec = get_arch(arch)
    cfg = reduce_cfg(spec.model) if use_reduced else spec.model
    model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
    params = model_zoo.init_params(jax.random.PRNGKey(seed), cfg)
    cache_len = cache_len or prompt_len + gen_tokens

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                             seed=seed)
    prompts = corpus.batch(0, batch)["tokens"]  # (B, prompt_len)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(lambda p, c, t: model.decode(p, c, t))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    logits.block_until_ready()
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t1 = time.time()
    for _ in range(gen_tokens):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t1

    gen = np.stack(out_tokens, axis=1)
    if not quiet:
        print(f"arch={cfg.name} batch={batch} prompt={prompt_len} "
              f"gen={gen_tokens}")
        print(f"prefill: {t_prefill*1e3:.1f} ms "
              f"({batch*prompt_len/max(t_prefill,1e-9):.0f} tok/s)")
        print(f"decode:  {t_decode*1e3:.1f} ms total, "
              f"{batch*gen_tokens/max(t_decode,1e-9):.0f} tok/s")
        print("sample:", gen[0][:16].tolist())
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "decode_tok_s": batch * gen_tokens / max(t_decode, 1e-9),
            "generated": gen}


def make_requests(cfg, n_requests: int, prompt_len: int, gen_tokens: int,
                  seed: int = 0, ragged: bool = True,
                  sampling: SamplingParams = SamplingParams()):
    """Synthetic workload: ``n_requests`` prompts; when ``ragged``, prompt
    and generation lengths vary per request (the continuous-batching case —
    the paper's length heterogeneity at serving time)."""
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                             seed=seed)
    prompts = np.asarray(corpus.batch(0, n_requests)["tokens"])
    reqs = []
    for i in range(n_requests):
        plen = prompt_len
        mt = gen_tokens
        if ragged:
            plen = max(4, prompt_len - (i % 4) * max(prompt_len // 6, 1))
            mt = max(1, gen_tokens - (i % 3) * max(gen_tokens // 4, 1))
        reqs.append(Request(uid=i, tokens=tuple(int(t) for t in
                                                prompts[i, :plen]),
                            max_tokens=mt, sampling=sampling))
    return reqs


def serve_engine(arch: str, use_reduced: bool, n_slots: int, prompt_len: int,
                 gen_tokens: int, n_requests: int = 0, cache_len: int = 0,
                 seed: int = 0, ragged: bool = True,
                 sampling: SamplingParams = SamplingParams(),
                 sched: SchedulerConfig = None, prefill_batch: int = 1,
                 decode_backend: str = "", paged: bool = False,
                 page_size: int = 64, n_pages: int = 0,
                 policy: str = "fcfs", metrics_jsonl: str = "",
                 quiet: bool = False):
    """Continuous-batching serve: the thin driver over InferenceEngine."""
    spec = get_arch(arch)
    cfg = reduce_cfg(spec.model) if use_reduced else spec.model
    n_requests = n_requests or n_slots
    cache_len = cache_len or prompt_len + gen_tokens
    sched = sched or SchedulerConfig(
        n_slots=n_slots, cache_len=cache_len,
        min_prompt_bucket=min(16, max(prompt_len // 4, 1)),
        round_multiple=max(prompt_len // 4, 8),
        prefill_batch=prefill_batch, paged=paged,
        page_size=page_size, n_pages=n_pages, policy=policy)
    engine = InferenceEngine.from_arch(arch, use_reduced=use_reduced,
                                       seed=seed, cfg=sched,
                                       decode_backend=decode_backend or None)
    writer = _JsonlWriter(metrics_jsonl) if metrics_jsonl else None
    if writer is not None:
        engine.on_step_metrics = writer
    reqs = make_requests(cfg, n_requests, prompt_len, gen_tokens, seed=seed,
                         ragged=ragged, sampling=sampling)
    t0 = time.time()
    results = engine.run(reqs)
    wall = time.time() - t0
    s = engine.stats
    if writer is not None:
        writer({"summary": True, "wall_s": wall,
                "generated_tokens": s.generated_tokens,
                "decode_steps": s.decode_steps,
                "slot_errors": s.slot_errors, "shed": s.shed})
        writer.close()
    if not quiet:
        print(f"arch={cfg.name} slots={n_slots} requests={n_requests} "
              f"buckets={engine.scheduler.ladder}")
        if sched.paged:
            from repro.serve import cache_nbytes
            print(f"paged:   {sched.resolved_n_pages} pages x "
                  f"{sched.page_size} tokens "
                  f"({sched.resolved_n_pages * sched.page_size} pool tokens "
                  f"vs {n_slots * sched.cache_len} dense; "
                  f"cache {cache_nbytes(engine.cache)/1e6:.2f} MB)")
        print(f"prefill: {s.prefill_s*1e3:.1f} ms ({s.prefill_tok_s:.0f} "
              f"tok/s over {s.prefill_tokens} prompt tokens)")
        print(f"decode:  {s.decode_s*1e3:.1f} ms, {s.decode_tok_s:.0f} tok/s "
              f"({s.generated_tokens} tokens, {s.decode_steps} fused steps)")
        print(f"latency: p50={s.latency_percentile(50)*1e3:.1f} ms "
              f"p95={s.latency_percentile(95)*1e3:.1f} ms per token")
        print("sample:", results[0].tokens[:16])
    return {"wall_s": wall, "prefill_s": s.prefill_s, "decode_s": s.decode_s,
            "prefill_tok_s": s.prefill_tok_s, "decode_tok_s": s.decode_tok_s,
            "p50_s": s.latency_percentile(50),
            "p95_s": s.latency_percentile(95),
            "results": results, "stats": s}


def serve_router(arch: str, use_reduced: bool, n_slots: int, prompt_len: int,
                 gen_tokens: int, n_requests: int = 0, cache_len: int = 0,
                 seed: int = 0, ragged: bool = True,
                 sampling: SamplingParams = SamplingParams(),
                 replicas: int = 2, policy: str = "fcfs",
                 route: str = "least-loaded", disaggregate: bool = False,
                 prefill_batch: int = 1, paged: bool = False,
                 page_size: int = 64, n_pages: int = 0,
                 metrics_jsonl: str = "", quiet: bool = False):
    """Routed serve: N replicas (each ``n_slots`` wide) behind the Router."""
    spec = get_arch(arch)
    cfg = reduce_cfg(spec.model) if use_reduced else spec.model
    n_requests = n_requests or replicas * n_slots
    cache_len = cache_len or prompt_len + gen_tokens
    sched = SchedulerConfig(
        n_slots=n_slots, cache_len=cache_len,
        min_prompt_bucket=min(16, max(prompt_len // 4, 1)),
        round_multiple=max(prompt_len // 4, 8),
        prefill_batch=prefill_batch, paged=paged,
        page_size=page_size, n_pages=n_pages, policy=policy)
    model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
    params = model_zoo.init_params(jax.random.PRNGKey(seed), cfg)
    router = Router(make_replicas(model, params, sched, replicas,
                                  disaggregate=disaggregate), route=route)
    writer = _JsonlWriter(metrics_jsonl) if metrics_jsonl else None
    if writer is not None:
        for rep in router.replicas:
            rep.on_step_metrics = writer
    reqs = make_requests(cfg, n_requests, prompt_len, gen_tokens, seed=seed,
                         ragged=ragged, sampling=sampling)
    t0 = time.time()
    results = router.run(reqs)
    wall = time.time() - t0
    summary = router.summary()
    if writer is not None:
        writer(dict(summary, summary=True, wall_s=wall))
        writer.close()
    if not quiet:
        agg = summary["aggregate"]
        print(f"arch={cfg.name} replicas={replicas} slots={n_slots}/replica "
              f"policy={policy} route={route} "
              f"disaggregate={disaggregate} requests={n_requests}")
        print(f"routed={summary['routed']} spilled={summary['spilled']} "
              f"shed={summary['shed']}")
        print(f"prefill: {agg['prefill_s']*1e3:.1f} ms   "
              f"decode: {agg['decode_s']*1e3:.1f} ms, "
              f"{agg['generated_tokens']} tokens, "
              f"{agg['decode_steps']} fused steps, "
              f"slot_errors={agg['slot_errors']}")
        for name, row in summary["replicas"].items():
            print(f"  {name}: admitted={row['admitted']} "
                  f"{row['decode_tok_s']:.0f} tok/s "
                  f"p95={row['p95_step_s']*1e3:.1f} ms")
        print("sample:", results[0].tokens[:16])
    return {"wall_s": wall, "results": results, "summary": summary,
            "router": router}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4,
                   help="decode width: engine slot count / legacy batch")
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--cache-len", type=int, default=0,
                   help="per-slot cache capacity (0 = prompt+gen)")
    p.add_argument("--legacy", action="store_true",
                   help="static-batch greedy path instead of the engine")
    p.add_argument("--requests", type=int, default=0,
                   help="engine: number of requests (0 = --batch)")
    p.add_argument("--uniform", action="store_true",
                   help="engine: identical prompt/gen lengths per request")
    p.add_argument("--prefill-batch", type=int, default=1,
                   help="engine: admit up to k same-bucket requests as one "
                        "(k, bucket) prefill call")
    p.add_argument("--decode-backend", default="",
                   choices=["", "reference", "kernel", "kernel_interpret"],
                   help="engine: override ModelConfig.decode_backend "
                        "(default: the arch preset's value)")
    p.add_argument("--paged", action="store_true",
                   help="engine: paged KV pool + per-slot page tables "
                        "instead of dense (n_slots, cache_len) rows")
    p.add_argument("--page-size", type=int, default=64,
                   help="engine: tokens per KV page (with --paged)")
    p.add_argument("--n-pages", type=int, default=0,
                   help="engine: KV pool size in pages (0 = dense-"
                        "equivalent n_slots * ceil(cache_len/page_size))")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--top-k", type=int, default=0)
    p.add_argument("--top-p", type=float, default=1.0)
    p.add_argument("--replicas", type=int, default=1,
                   help="serve through the Router over N replicas "
                        "(each --batch slots wide)")
    p.add_argument("--policy", default="fcfs", choices=list(POLICIES),
                   help="admission policy (serve/policies.py)")
    p.add_argument("--route", default="least-loaded", choices=list(ROUTES),
                   help="router replica selection")
    p.add_argument("--disaggregate", action="store_true",
                   help="split each serving unit into a prefill-role + "
                        "decode-role replica pair")
    p.add_argument("--metrics-jsonl", default="",
                   help="stream one JSONL metrics row per fused decode "
                        "step (+ a summary row) to this path")
    args = p.parse_args(argv)

    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, seed=args.seed)
    if args.legacy:
        serve(args.arch, args.reduced, args.batch, args.prompt_len, args.gen,
              cache_len=args.cache_len, seed=args.seed)
    elif args.replicas > 1 or args.disaggregate:
        serve_router(args.arch, args.reduced, args.batch, args.prompt_len,
                     args.gen, n_requests=args.requests,
                     cache_len=args.cache_len, seed=args.seed,
                     ragged=not args.uniform, sampling=sp,
                     replicas=args.replicas, policy=args.policy,
                     route=args.route, disaggregate=args.disaggregate,
                     prefill_batch=args.prefill_batch, paged=args.paged,
                     page_size=args.page_size, n_pages=args.n_pages,
                     metrics_jsonl=args.metrics_jsonl)
    else:
        serve_engine(args.arch, args.reduced, args.batch, args.prompt_len,
                     args.gen, n_requests=args.requests,
                     cache_len=args.cache_len, seed=args.seed,
                     ragged=not args.uniform, sampling=sp,
                     prefill_batch=args.prefill_batch,
                     decode_backend=args.decode_backend, paged=args.paged,
                     page_size=args.page_size, n_pages=args.n_pages,
                     policy=args.policy, metrics_jsonl=args.metrics_jsonl)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
