"""Production mesh builders.

Importing this module never touches jax device state — meshes are built
inside functions only (the dry-run sets XLA_FLAGS before any jax init).

Axis semantics:
  pod   — data parallelism across pods (gradient all-reduce over DCI)
  data  — FSDP within a pod (params/optimizer reduce-scattered over ICI)
  model — tensor/expert parallelism within a pod
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     devices=jax.devices()[: int(np.prod(shape))])


def make_host_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Small mesh over however many (possibly fake) local devices exist —
    used by the mini-mesh integration tests."""
    import jax
    n = int(np.prod(shape))
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
