"""Composable optimizer chain: optax-shaped gradient transforms, no optax.

The monolithic ``adamw_update`` becomes a chain of
:class:`GradientTransform`\\ s — ``init(params) -> state`` /
``update(updates, state, params, hyper) -> (updates, state, telemetry)``
pairs — so clipping, preconditioning, weight decay, per-leaf LR scaling and
telemetry collection compose instead of forking the train step.  ``hyper``
carries the runtime scalars (``lr``, ``clip_scale``) so regulators keep
retuning steps without recompiles.

Sign convention: the chain produces the quantity *subtracted* from the
params (:func:`apply_updates` does ``p - u``), matching the legacy
``p - lr * step``.  The default chain —

    clip_global_norm -> scale_by_adam -> add_decayed_weights -> scale_by_lr

— reproduces the legacy AdamW trajectory *numerically exactly* (params,
opt state, and scalar telemetry), which is pinned by
``tests/test_optim_chain.py``; everything else (SM3, Shampoo-grafted,
adaptive gradient clipping, per-leaf LR scales, per-leaf telemetry) is
opt-in through :class:`~repro.configs.base.OptimizerConfig` and
assembled by :func:`build_optimizer`.

Chain state is a dict keyed by transform name (``{"adam": {"m", "v",
"count"}, ...}``) so checkpoints stay path-addressable; stateless
transforms contribute an empty dict (zero checkpoint leaves), and
``repro.checkpoint`` restores legacy ``{"m","v","count"}`` payloads into
the ``adam`` slot via key aliasing (see ``checkpoint.restore``).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.stability import momentum_stats, variance_stats
from repro.core.telemetry import leaf_norms, leaf_var_max, param_labels

Hyper = Dict[str, jax.Array]
Telemetry = Dict[str, jax.Array]


class GradientTransform(NamedTuple):
    """One chain link.  ``update`` must be jit-traceable."""

    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Hyper], Tuple[Any, Any, Telemetry]]


def chain(*transforms: GradientTransform) -> GradientTransform:
    """Compose transforms left to right; state is keyed by transform name."""
    names = [t.name for t in transforms]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate transform names in chain: {names}")

    def init(params):
        return {t.name: t.init(params) for t in transforms}

    def update(updates, state, params, hyper):
        new_state, telemetry = {}, {}
        for t in transforms:
            updates, st, tel = t.update(updates, state[t.name], params, hyper)
            new_state[t.name] = st
            telemetry.update(tel)
        return updates, new_state, telemetry

    return GradientTransform("chain", init, update)


def apply_updates(params: Any, updates: Any) -> Any:
    """``p - u`` in fp32, cast back to the param dtype (legacy semantics)."""
    return jax.tree_util.tree_map(
        lambda p, u: (p - u).astype(p.dtype), params, updates)


def abstract_chain_state(tx: GradientTransform, params_shapes: Any) -> Any:
    """ShapeDtypeStruct tree of the chain state (checkpoint ``like`` trees,
    sharding derivation) without materializing arrays."""
    return jax.eval_shape(tx.init, params_shapes)


def _zeros_like_tree(t: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)


# ---------------------------------------------------------------------------
# clipping
# ---------------------------------------------------------------------------

def clip_global_norm(max_norm: float, per_leaf_telemetry: bool = False
                     ) -> GradientTransform:
    """Cast to fp32, measure the global norm, clip to ``max_norm *
    hyper["clip_scale"]``.  ``max_norm <= 0`` measures without clipping
    (so ``grad_norm`` telemetry survives an AGC-only configuration).

    Telemetry contract: ``grad_norm`` is the RAW pre-clip norm (measured
    on the incoming gradients, before any scaling) — the noise/variance
    signal regulators act on.  ``grad_norm_clipped`` is the post-clip
    norm (``gnorm * scale``); under sustained clipping it saturates at
    the limit, which is exactly why nothing downstream may regulate on
    it (see ``GradNoiseBatchRegulator``)."""

    def update(updates, state, params, hyper):
        leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
                  for x in jax.tree_util.tree_leaves(updates)]
        gnorm = jnp.sqrt(sum(leaves))
        if max_norm > 0:
            limit = max_norm * hyper["clip_scale"]
            scale = jnp.minimum(1.0, limit / jnp.maximum(gnorm, 1e-12))
        else:
            scale = jnp.float32(1.0)
        out = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), updates)
        tel: Telemetry = {"grad_norm": gnorm,
                          "grad_norm_clipped": gnorm * scale}
        if per_leaf_telemetry:
            tel["leaf_grad_norm"] = jnp.sqrt(jnp.stack(leaves))
        return out, state, tel

    return GradientTransform("clip", lambda params: {}, update)


def adaptive_grad_clip(clipping: float, eps: float = 1e-3
                       ) -> GradientTransform:
    """AGC (Brock et al.): per-leaf clip of the grad-norm/param-norm ratio.
    Composes after (or replaces, with ``grad_clip=0``) the global clip."""

    def update(updates, state, params, hyper):
        def one(g, p):
            pn = jnp.sqrt(jnp.sum(jnp.square(p.astype(jnp.float32))))
            gn = jnp.sqrt(jnp.sum(jnp.square(g)))
            limit = clipping * jnp.maximum(pn, eps)
            return g * jnp.minimum(1.0, limit / jnp.maximum(gn, 1e-6))

        return (jax.tree_util.tree_map(one, updates, params), state, {})

    return GradientTransform("agc", lambda params: {}, update)


# ---------------------------------------------------------------------------
# preconditioners
# ---------------------------------------------------------------------------

def scale_by_adam(cfg: OptimizerConfig, per_leaf_telemetry: bool = False
                  ) -> GradientTransform:
    """The legacy Adam core, bit-for-bit: m/v EMAs, bias correction,
    ``mhat / (sqrt(vhat) + eps)``, plus the paper's variance telemetry."""
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps

    def init(params):
        return {"m": _zeros_like_tree(params), "v": _zeros_like_tree(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(updates, state, params, hyper):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** cf
        bc2 = 1.0 - b2 ** cf
        new_m = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1.0 - b1) * g, state["m"], updates)
        new_v = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1.0 - b2) * jnp.square(g),
            state["v"], updates)
        out = jax.tree_util.tree_map(
            lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + eps), new_m, new_v)
        tel = {**variance_stats(new_v), **momentum_stats(new_m)}
        if per_leaf_telemetry:
            tel["leaf_var_max"] = leaf_var_max(new_v)
        return out, {"m": new_m, "v": new_v, "count": count}, tel

    return GradientTransform("adam", init, update)


def scale_by_sm3(cfg: OptimizerConfig, per_leaf_telemetry: bool = False
                 ) -> GradientTransform:
    """SM3 (Anil et al.): per-dimension min/max accumulators instead of a
    full second-moment tree — O(sum of dims) memory per leaf instead of
    O(prod of dims) — with optional heavy-ball momentum on the
    preconditioned update.  The variance telemetry reduces the *estimated*
    second moment (the min-broadcast of the accumulators), so regulators
    see the same ``var_max`` series shape as Adam."""
    b1, eps = cfg.sm3_momentum, cfg.eps

    def leaf_accs(x):
        if x.ndim == 0:
            return (jnp.zeros((), jnp.float32),)
        return tuple(
            jnp.zeros(tuple(d if i == axis else 1
                            for i, d in enumerate(x.shape)), jnp.float32)
            for axis in range(x.ndim))

    def init(params):
        leaves = jax.tree_util.tree_leaves(params)
        st = {"acc": tuple(leaf_accs(x) for x in leaves)}
        if b1 > 0:
            st["m"] = _zeros_like_tree(params)
        return st

    def update(updates, state, params, hyper):
        leaves, treedef = jax.tree_util.tree_flatten(updates)
        new_accs: List[Tuple[jax.Array, ...]] = []
        nus: List[jax.Array] = []
        outs: List[jax.Array] = []
        for g, accs in zip(leaves, state["acc"]):
            est = accs[0]
            for a in accs[1:]:
                est = jnp.minimum(est, a)
            nu = est + jnp.square(g)
            if g.ndim == 0:
                new_accs.append((nu,))
            else:
                new_accs.append(tuple(
                    jnp.max(nu, axis=tuple(i for i in range(g.ndim)
                                           if i != axis), keepdims=True)
                    for axis in range(g.ndim)))
            nus.append(nu)
            outs.append(g / (jnp.sqrt(nu) + eps))
        out = jax.tree_util.tree_unflatten(treedef, outs)
        new_state = {"acc": tuple(new_accs)}
        tel = variance_stats(nus)
        if b1 > 0:
            new_m = jax.tree_util.tree_map(
                lambda m, u: b1 * m + (1.0 - b1) * u, state["m"], out)
            new_state["m"] = new_m
            out = new_m
            tel.update(momentum_stats(new_m))
        if per_leaf_telemetry:
            tel["leaf_var_max"] = leaf_var_max(nus)
        return out, new_state, tel

    return GradientTransform("sm3", init, update)


def _inv_pth_root(s: jax.Array, p: float, eps: float) -> jax.Array:
    """Symmetric inverse p-th root via eigendecomposition (fp32; batched
    over leading dims)."""
    n = s.shape[-1]
    w, v = jnp.linalg.eigh(s + eps * jnp.eye(n, dtype=s.dtype))
    w = jnp.maximum(w, eps) ** (-1.0 / p)
    return jnp.einsum("...ij,...j,...kj->...ik", v, w, v)


def scale_by_shampoo(cfg: OptimizerConfig, per_leaf_telemetry: bool = False
                     ) -> GradientTransform:
    """Shampoo-style block-diagonal preconditioning grafted onto the Adam
    update magnitude.

    Each eligible leaf (ndim >= 2, last two dims <= ``shampoo_block_size``)
    is viewed as a stack of (rows, cols) blocks over its leading dims — one
    block per scan-stacked layer slice, i.e. genuinely block-diagonal —
    with decayed L/R Kronecker statistics and inverse-4th-root
    preconditioners recomputed every ``shampoo_interval`` steps.  The
    preconditioned direction is rescaled per block to the norm of the Adam
    update (grafting), so the step-size trajectory stays on the well-tuned
    Adam scale while the *direction* gains curvature information.
    Ineligible leaves fall back to the plain Adam update.
    """
    adam = scale_by_adam(cfg, per_leaf_telemetry=per_leaf_telemetry)
    beta, eps = cfg.beta2, cfg.shampoo_eps
    block, interval = cfg.shampoo_block_size, max(cfg.shampoo_interval, 1)

    def eligible(x) -> bool:
        return x.ndim >= 2 and x.shape[-2] <= block and x.shape[-1] <= block

    def leaf_stats(x):
        if not eligible(x):
            return None
        lead = math.prod(x.shape[:-2]) if x.ndim > 2 else 1
        r, c = x.shape[-2], x.shape[-1]
        eye_r = jnp.broadcast_to(jnp.eye(r, dtype=jnp.float32), (lead, r, r))
        eye_c = jnp.broadcast_to(jnp.eye(c, dtype=jnp.float32), (lead, c, c))
        return {"l": jnp.zeros((lead, r, r), jnp.float32),
                "r": jnp.zeros((lead, c, c), jnp.float32),
                "pl": eye_r, "pr": eye_c}

    def init(params):
        leaves = jax.tree_util.tree_leaves(params)
        return {"adam": adam.init(params),
                "stats": tuple(leaf_stats(x) for x in leaves)}

    def update(updates, state, params, hyper):
        adam_u, adam_state, tel = adam.update(updates, state["adam"],
                                              params, hyper)
        count = adam_state["count"]
        recompute = (count - 1) % interval == 0
        g_leaves, treedef = jax.tree_util.tree_flatten(updates)
        a_leaves = jax.tree_util.tree_leaves(adam_u)
        new_stats, outs = [], []
        for g, au, st in zip(g_leaves, a_leaves, state["stats"]):
            if st is None:
                new_stats.append(None)
                outs.append(au)
                continue
            shape = g.shape
            gb = g.reshape((-1,) + shape[-2:])
            l_new = beta * st["l"] + (1.0 - beta) * jnp.einsum(
                "bij,bkj->bik", gb, gb)
            r_new = beta * st["r"] + (1.0 - beta) * jnp.einsum(
                "bji,bjk->bik", gb, gb)
            pl = jax.lax.cond(recompute,
                              lambda ln=l_new: _inv_pth_root(ln, 4.0, eps),
                              lambda pl=st["pl"]: pl)
            pr = jax.lax.cond(recompute,
                              lambda rn=r_new: _inv_pth_root(rn, 4.0, eps),
                              lambda pr=st["pr"]: pr)
            precond = jnp.einsum("bij,bjk,bkl->bil", pl,
                                 gb.astype(jnp.float32), pr)
            ab = au.reshape((-1,) + shape[-2:])
            a_norm = jnp.sqrt(jnp.sum(jnp.square(ab), axis=(-2, -1),
                                      keepdims=True))
            p_norm = jnp.sqrt(jnp.sum(jnp.square(precond), axis=(-2, -1),
                                      keepdims=True))
            grafted = precond * (a_norm / jnp.maximum(p_norm, 1e-16))
            new_stats.append({"l": l_new, "r": r_new, "pl": pl, "pr": pr})
            outs.append(grafted.reshape(shape))
        out = jax.tree_util.tree_unflatten(treedef, outs)
        # preconditioner staleness: steps since the last eigh refresh.
        # The recompute flag keys off the shared Adam count, so every
        # block refreshes on the same cadence and one scalar covers all
        # of them (bench_optim surfaces it per arm).
        tel = dict(tel, shampoo_staleness=((count - 1) % interval)
                   .astype(jnp.float32))
        return out, {"adam": adam_state, "stats": tuple(new_stats)}, tel

    return GradientTransform("shampoo", init, update)


# ---------------------------------------------------------------------------
# decay / scaling / telemetry tails
# ---------------------------------------------------------------------------

def decay_mask_tree(params: Any, mode: str) -> Any:
    """Which leaves get weight decay.  ``all`` is the legacy behavior
    (every leaf, biases and norm scales included); ``std`` is the standard
    mask — only matrices decay, 1-D/scalar leaves (biases, norm gains) do
    not.  The model zoo stacks per-layer leaves on a leading scan axis
    under the top-level ``layers`` key, so a stacked bias arrives as
    ``(L, d)``: the mask strips that axis before counting effective dims,
    and a leaf is a matrix when >= 2 of the remaining dims have size > 1."""
    if mode == "all":
        return jax.tree_util.tree_map(lambda p: True, params)
    if mode != "std":
        raise ValueError(f"unknown decay_mask {mode!r} (all | std)")

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    def is_matrix(path, p) -> bool:
        shape = p.shape
        if path and "layers" in str(getattr(path[0], "key", path[0])):
            shape = shape[1:]  # scan-stacked: drop the layer axis
        return len([d for d in shape if d > 1]) >= 2

    return jax.tree_util.tree_unflatten(
        treedef, [is_matrix(path, p) for path, p in flat])


def add_decayed_weights(weight_decay: float, mask_mode: str = "all"
                        ) -> GradientTransform:
    """``u + weight_decay * p`` on masked leaves (decoupled decay, applied
    before the LR scale — exactly where the legacy fused update put it)."""

    def update(updates, state, params, hyper):
        if weight_decay == 0.0:
            return updates, state, {}
        mask = decay_mask_tree(params, mask_mode)
        out = jax.tree_util.tree_map(
            lambda u, p, m: u + weight_decay * p if m else u,
            updates, params, mask)
        return out, state, {}

    return GradientTransform("decay", lambda params: {}, update)


def scale_per_leaf(lr_scales: Tuple[Tuple[str, float], ...]
                   ) -> GradientTransform:
    """Per-leaf LR scaling: each ``(pattern, factor)`` multiplies the
    update of every leaf whose label contains ``pattern`` (factors
    compose multiplicatively when several patterns match)."""

    def update(updates, state, params, hyper):
        labels = param_labels(updates)
        leaves, treedef = jax.tree_util.tree_flatten(updates)
        out = []
        for label, u in zip(labels, leaves):
            factor = 1.0
            for pattern, f in lr_scales:
                if pattern in label:
                    factor *= f
            out.append(u * factor if factor != 1.0 else u)
        return jax.tree_util.tree_unflatten(treedef, out), state, {}

    return GradientTransform("leaf_lr", lambda params: {}, update)


def per_leaf_update_telemetry() -> GradientTransform:
    """Final-update / param norms per leaf (placed after decay, before the
    LR scale, so the vector is the step *direction* magnitude)."""

    def update(updates, state, params, hyper):
        return updates, state, {"leaf_update_norm": leaf_norms(updates),
                                "leaf_param_norm": leaf_norms(params)}

    return GradientTransform("leaf_tel", lambda params: {}, update)


def scale_by_lr() -> GradientTransform:
    """Final LR scale.  ``hyper["leaf_lr_scale"]`` — optional, a
    ``(n_leaves,)`` runtime vector in ``tree_leaves`` order — additionally
    multiplies each leaf's update: the recovery controller's per-layer LR
    backoff surface.  Key *presence* is a trace-time (Python) check, so
    callers that never pass it keep the legacy single-scalar trace
    byte-identical."""

    def update(updates, state, params, hyper):
        lr = hyper["lr"]
        scales = hyper.get("leaf_lr_scale")
        if scales is None:
            return (jax.tree_util.tree_map(lambda u: lr * u, updates),
                    state, {})
        leaves, treedef = jax.tree_util.tree_flatten(updates)
        out = [lr * scales[i].astype(u.dtype) * u
               for i, u in enumerate(leaves)]
        return jax.tree_util.tree_unflatten(treedef, out), state, {}

    return GradientTransform("lr", lambda params: {}, update)


# ---------------------------------------------------------------------------
# config -> chain
# ---------------------------------------------------------------------------

def build_optimizer(cfg: OptimizerConfig) -> GradientTransform:
    """Assemble the chain an :class:`OptimizerConfig` describes.  With
    default fields this is exactly the legacy AdamW path."""
    per_leaf = cfg.telemetry_level == "per_leaf"
    ts: List[GradientTransform] = [
        clip_global_norm(cfg.grad_clip, per_leaf_telemetry=per_leaf)]
    if cfg.agc_clip > 0:
        ts.append(adaptive_grad_clip(cfg.agc_clip, cfg.agc_eps))
    if cfg.optimizer == "adamw":
        ts.append(scale_by_adam(cfg, per_leaf_telemetry=per_leaf))
    elif cfg.optimizer == "sm3":
        ts.append(scale_by_sm3(cfg, per_leaf_telemetry=per_leaf))
    elif cfg.optimizer == "shampoo":
        ts.append(scale_by_shampoo(cfg, per_leaf_telemetry=per_leaf))
    else:
        raise ValueError(f"unknown optimizer {cfg.optimizer!r} "
                         f"(adamw | sm3 | shampoo)")
    ts.append(add_decayed_weights(cfg.weight_decay, cfg.decay_mask))
    if cfg.lr_scales:
        ts.append(scale_per_leaf(cfg.lr_scales))
    if per_leaf:
        ts.append(per_leaf_update_telemetry())
    ts.append(scale_by_lr())
    return chain(*ts)


def migrate_opt_state(opt: Dict[str, Any]) -> Dict[str, Any]:
    """Upgrade a legacy in-memory ``{"m","v","count"}`` opt state into the
    default-chain format (``{"clip": {}, "adam": {...}, ...}``).  Already-
    migrated states pass through unchanged."""
    if "m" in opt and "v" in opt and "count" in opt:
        return {"clip": {}, "adam": {"m": opt["m"], "v": opt["v"],
                                     "count": opt["count"]},
                "decay": {}, "lr": {}}
    return opt
