from repro.optim.adam import (  # noqa: F401
    abstract_opt_state,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
)
from repro.optim.compression import (  # noqa: F401
    compressed_allreduce,
    ef_compress_tree,
    init_error_state,
)
from repro.optim.schedule import lr_at  # noqa: F401
