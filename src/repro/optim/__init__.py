from repro.optim.adam import (  # noqa: F401
    abstract_opt_state,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
)
from repro.optim.transforms import (  # noqa: F401
    GradientTransform,
    abstract_chain_state,
    adaptive_grad_clip,
    add_decayed_weights,
    apply_updates,
    build_optimizer,
    chain,
    clip_global_norm,
    decay_mask_tree,
    migrate_opt_state,
    scale_by_adam,
    scale_by_lr,
    scale_by_shampoo,
    scale_by_sm3,
    scale_per_leaf,
)
from repro.optim.compression import (  # noqa: F401
    compressed_allreduce,
    ef_compress_tree,
    init_error_state,
)
from repro.optim.schedule import lr_at  # noqa: F401
