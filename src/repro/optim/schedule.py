"""LR schedules — step-wise (baseline GPT-2) and token-wise (paper A.2).

The paper's key fix for fair SLW comparison: because warmup steps carry fewer
tokens, step-wise cosine decays *faster in token space* for SLW than for the
baseline; switching the decay to run over **tokens** makes the schedules
coincide.  Schedules here are host-side pure functions of exact Python-int
counters (no float32 token-count truncation at 157B tokens); the resulting
scalar is fed into the jitted step as an argument.
"""
from __future__ import annotations

import math

from repro.configs.base import OptimizerConfig


def _cosine(frac: float, lr: float, min_lr: float) -> float:
    frac = min(max(frac, 0.0), 1.0)
    return min_lr + 0.5 * (lr - min_lr) * (1.0 + math.cos(math.pi * frac))


def lr_at(cfg: OptimizerConfig, step: int, tokens_seen: int) -> float:
    """LR for the step about to run, given exact host-side counters."""
    if cfg.schedule == "constant":
        return cfg.lr
    if cfg.schedule == "step_cosine":
        warm = max(cfg.warmup_steps, 1)
        if step < warm:
            return cfg.lr * (step + 1) / warm
        total = max(cfg.total_steps - warm, 1)
        return _cosine((step - warm) / total, cfg.lr, cfg.min_lr)
    if cfg.schedule == "token_cosine":
        warm = max(cfg.warmup_tokens, 1)
        if tokens_seen < warm:
            return cfg.lr * min((tokens_seen + 1) / warm, 1.0)
        total = max(cfg.total_tokens - warm, 1)
        return _cosine((tokens_seen - warm) / total, cfg.lr, cfg.min_lr)
    raise ValueError(f"unknown schedule {cfg.schedule!r}")
