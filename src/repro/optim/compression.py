"""1-bit-Adam-style compressed data-parallel gradient sync (error feedback).

The paper cites 1-bit Adam/LAMB as the "communication" arm of the efficiency
problem it attacks from the data side; at multi-pod scale both compose: SLW
shrinks tokens/step early, compression shrinks the cross-pod (DCI) gradient
all-reduce bytes ~16x always.

Scheme (Tang et al., 1-bit Adam): after a warmup phase of exact all-reduce,
communicate ``sign(g + e) * mean(|g + e|)`` and keep the quantization residue
``e`` locally (error feedback).  Implemented as a shard_map around the
gradient psum so the collective really moves sign bits (+ one scalar per
tensor) — this is the piece XLA cannot do for us.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import shard_map


def compress(t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """sign + per-tensor l1 scale. Returns (int8 signs, fp32 scale)."""
    scale = jnp.mean(jnp.abs(t))
    signs = jnp.where(t >= 0, jnp.int8(1), jnp.int8(-1))
    return signs, scale


def decompress(signs: jax.Array, scale: jax.Array) -> jax.Array:
    return signs.astype(jnp.float32) * scale


def ef_compress_tree(grads: Any, error: Any) -> Tuple[Any, Any, Any]:
    """Error-feedback compression over a pytree.
    Returns (compressed {signs, scales}, decompressed local view, new error)."""
    corrected = jax.tree_util.tree_map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error)
    sig_scale = jax.tree_util.tree_map(compress, corrected)
    signs = jax.tree_util.tree_map(lambda ss: ss[0], sig_scale,
                                   is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree_util.tree_map(lambda ss: ss[1], sig_scale,
                                    is_leaf=lambda x: isinstance(x, tuple))
    decomp = jax.tree_util.tree_map(decompress, signs, scales)
    new_error = jax.tree_util.tree_map(lambda c, d: c - d, corrected, decomp)
    return {"signs": signs, "scales": scales}, decomp, new_error


def init_error_state(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)


def compressed_allreduce(mesh: Mesh, axis: str):
    """Returns fn(grads, error) -> (mean_grads, new_error) that all-reduces
    sign-compressed gradients over `axis` with error feedback.

    grads enter as per-shard (already averaged over the local batch); the
    result approximates the exact mean over the axis.  Bytes on the wire:
    1 byte/element (int8 sign) + 4 bytes/tensor, vs 4 bytes/element exact.
    """
    n = mesh.shape[axis]

    def sync(grads, error):
        comp, _decomp, new_error = ef_compress_tree(grads, error)
        # all-reduce the int8 signs (sum of signs in int32 to avoid overflow)
        summed = jax.tree_util.tree_map(
            lambda s: jax.lax.psum(s.astype(jnp.int32), axis), comp["signs"])
        scales = jax.tree_util.tree_map(
            lambda sc: jax.lax.psum(sc, axis) / n, comp["scales"])
        mean = jax.tree_util.tree_map(
            lambda s, sc: s.astype(jnp.float32) * sc / n, summed, scales)
        return mean, new_error

    def wrapper(grads, error):
        specs = jax.tree_util.tree_map(lambda _: P(), grads)
        err_specs = jax.tree_util.tree_map(lambda _: P(), error)
        return shard_map(sync, mesh=mesh,
                         in_specs=(specs, err_specs),
                         out_specs=(specs, err_specs),
                         check_vma=False)(grads, error)

    return wrapper
