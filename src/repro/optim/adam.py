"""AdamW with fp32 master state, global-norm clipping, and the paper's
variance telemetry exposed from inside the jitted step.

The optimizer is a plain pytree transform (no optax dependency): state =
{"m": tree, "v": tree, "count": int32}.  ``v`` is exactly the Adam variance
state whose l1 norm / max element the paper's Section 3 analysis tracks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.stability import momentum_stats, variance_stats


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def abstract_opt_state(params_shapes: Any) -> Dict[str, Any]:
    sds = lambda t: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    return {"m": sds(params_shapes), "v": sds(params_shapes),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def adamw_update(params: Any, grads: Any, opt_state: Dict[str, Any],
                 lr: jax.Array, cfg: OptimizerConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. grads must already be fp32 (post-clip). Returns
    (new_params, new_opt_state, telemetry)."""
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    new_m = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1.0 - b1) * g, opt_state["m"], grads)
    new_v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1.0 - b2) * jnp.square(g),
        opt_state["v"], grads)

    def upd(p, m, v, decay):
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps) \
            + (cfg.weight_decay * p if decay else 0.0)
        return (p - lr * step).astype(p.dtype)

    from repro.optim.transforms import decay_mask_tree
    mask = decay_mask_tree(params, cfg.decay_mask)
    new_params = jax.tree_util.tree_map(upd, params, new_m, new_v, mask)
    telemetry = {**variance_stats(new_v), **momentum_stats(new_m)}
    return new_params, {"m": new_m, "v": new_v, "count": count}, telemetry
