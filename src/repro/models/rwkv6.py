"""RWKV-6 (Finch): attention-free mixer with data-dependent per-channel decay.

The WKV recurrence is computed in a chunked matmul form (`wkv6_reference`,
oracle for ``repro/kernels/rwkv6``): within a chunk the pairwise per-channel
decay tensor is materialized directly (safe exponents: decays <= 1 appear as
exp of non-positive numbers only), and across chunks the (H, D, D) state is
carried by a scan.  Decode state is O(1) per layer — this is why rwkv6-7b
runs the long_500k cell.  ``wkv6_mix`` dispatches between this oracle and
the differentiable Pallas kernel per ``ModelConfig.rwkv_backend``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import resolve_backend
from repro.kernels.rwkv6.ops import wkv6
from repro.models.layers import (
    ParamDef, advance_pos, apply_norm, cast, cross_entropy_loss, layer_norm,
    maybe_checkpoint, maybe_scan, norm_def, round_up, stack_defs)
from repro.models.transformer import _logits, embed_inputs

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core WKV6 math (oracle for kernels/rwkv6)
# ---------------------------------------------------------------------------

def wkv6_reference(r: jax.Array, k: jax.Array, v: jax.Array,
                   log_w: jax.Array, u: jax.Array, chunk: int,
                   init_state: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV-6.

    r, k, v: (B, S, H, D); log_w: (B, S, H, D) (<= 0, data-dependent decay);
    u: (H, D) bonus for the current token.
    Recurrence per head:  out_t = r_t . (S_{t-1} + u*k_t (x) v_t)
                          S_t   = diag(w_t) S_{t-1} + k_t (x) v_t
    Returns (out (B,S,H,D), final_state (B,H,D,D)).
    """
    b, s, h, d = r.shape
    chunk = min(chunk, s)
    if s % chunk:  # pad with log_w=0 / k=0 steps: state-safe
        pad = chunk - s % chunk
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        out, state = wkv6_reference(
            jnp.pad(r, padw), jnp.pad(k, padw), jnp.pad(v, padw),
            jnp.pad(log_w, padw), u, chunk, init_state)
        return out[:, :s], state
    nc = s // chunk
    f32 = jnp.float32

    rc = jnp.moveaxis(r.reshape(b, nc, chunk, h, d), 1, 0).astype(f32)
    kc = jnp.moveaxis(k.reshape(b, nc, chunk, h, d), 1, 0).astype(f32)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, h, d), 1, 0).astype(f32)
    lw = jnp.moveaxis(log_w.reshape(b, nc, chunk, h, d), 1, 0).astype(f32)

    state0 = (jnp.zeros((b, h, d, d), f32) if init_state is None
              else init_state.astype(f32))
    idx = jnp.arange(chunk)
    strict = idx[:, None] > idx[None, :]  # j < i (diag handled by u-bonus)
    uf = u.astype(f32)

    def step(state, inp):
        rq, kq, vq, lq = inp  # (B,Q,H,D)
        cum = jnp.cumsum(lq, axis=1)  # inclusive (B,Q,H,D)
        cum_in = cum - lq  # exclusive: decay applied after step j is w_{j+1}..
        # intra-chunk, strictly causal: exponent cum_in[i] - cum[j] <= 0 for j<i
        gap = cum_in[:, :, None] - cum[:, None, :, :]  # (B,Q,Q,H,D)
        gap = jnp.where(strict[None, :, :, None, None], gap, NEG_INF)
        att = jnp.einsum("bihd,bijhd,bjhd->bijh", rq, jnp.exp(gap), kq)
        y = jnp.einsum("bijh,bjhd->bihd", att, vq)
        # current-token bonus
        bonus = jnp.einsum("bihd,hd,bihd->bih", rq, uf, kq)
        y = y + bonus[..., None] * vq
        # carried state: r_i . diag(exp(cum_in_i)) S_prev
        y = y + jnp.einsum("bihd,bihd,bhde->bihe", rq, jnp.exp(cum_in), state)
        # state update: S = diag(exp(cum_last)) S + sum_j exp(cum_last-cum_j) k_j (x) v_j
        decay_to_end = jnp.exp(cum[:, -1][:, None] - cum)  # (B,Q,H,D) <= 1
        state = (jnp.exp(cum[:, -1])[..., None] * state
                 + jnp.einsum("bjhd,bjhd,bjhe->bhde", kq, decay_to_end, vq))
        return state, y

    final_state, ys = jax.lax.scan(step, state0, (rc, kc, vc, lw))
    out = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, d)
    return out.astype(r.dtype), final_state


def wkv6_mix(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
             u: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Backend dispatch for the WKV scan at the model layout.

    r/k/v/log_w (B,S,H,D), u (H,D); returns (y (B,S,H,D), final_state
    (B,H,D,D)).  ``cfg.rwkv_backend`` selects the differentiable Pallas
    kernel ("kernel": compiled, TPU only, reference fallback elsewhere;
    "kernel_interpret": forced interpret mode for CPU validation) or the
    jnp oracle ("reference") — train and prefill both route through it.
    """
    use_kernel, interpret = resolve_backend(cfg.rwkv_backend, "rwkv_backend")
    if use_kernel:
        tr = lambda t: t.transpose(0, 2, 1, 3)  # (B,S,H,D) <-> (B,H,S,D)
        y, state = wkv6(tr(r), tr(k), tr(v), tr(log_w), u,
                        chunk=cfg.rwkv_chunk, interpret=interpret)
        return tr(y), state
    return wkv6_reference(r, k, v, log_w, u, cfg.rwkv_chunk)


def wkv6_decode_step(state: jax.Array, r: jax.Array, k: jax.Array,
                     v: jax.Array, log_w: jax.Array, u: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """One-token WKV. state (B,H,D,D); r/k/v/log_w (B,H,D)."""
    f32 = jnp.float32
    rf, kf, vf = r.astype(f32), k.astype(f32), v.astype(f32)
    bonus = jnp.einsum("bhd,hd,bhd->bh", rf, u.astype(f32), kf)
    y = jnp.einsum("bhd,bhde->bhe", rf, state) + bonus[..., None] * vf
    state = (jnp.exp(log_w.astype(f32))[..., None] * state
             + jnp.einsum("bhd,bhe->bhde", kf, vf))
    return y.astype(r.dtype), state


# ---------------------------------------------------------------------------
# the RWKV-6 block
# ---------------------------------------------------------------------------

def rwkv6_def(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    rank = cfg.rwkv_lora_rank
    s = 1.0 / math.sqrt(d)
    return {
        "ln1": norm_def(d, "layernorm"),
        "mix": ParamDef((5, d), (None, "embed"), "zeros"),  # r,k,v,w,g lerp
        "w_base": ParamDef((d,), ("rwkv_inner",), "zeros"),
        "w_lora_a": ParamDef((d, rank), ("embed", None), "normal", s),
        "w_lora_b": ParamDef((rank, d), (None, "rwkv_inner"), "zeros"),
        "wr": ParamDef((d, d), ("embed", "rwkv_inner"), "normal", s),
        "wk": ParamDef((d, d), ("embed", "rwkv_inner"), "normal", s),
        "wv": ParamDef((d, d), ("embed", "rwkv_inner"), "normal", s),
        "wg": ParamDef((d, d), ("embed", "rwkv_inner"), "normal", s),
        "u": ParamDef((h, hd), ("rwkv_heads", None), "normal", 0.5),
        "ln_x": norm_def(d, "layernorm", ("rwkv_inner",)),
        "wo": ParamDef((d, d), ("rwkv_inner", "embed"), "normal", s),
        "ln2": norm_def(d, "layernorm"),
        "mix_c": ParamDef((2, d), (None, "embed"), "zeros"),  # channel-mix k,r
        "wck": ParamDef((d, cfg.d_ff), ("embed", "mlp"), "normal", s),
        "wcv": ParamDef((cfg.d_ff, d), ("mlp", "embed"), "normal",
                        1.0 / math.sqrt(cfg.d_ff)),
        "wcr": ParamDef((d, d), ("embed", "rwkv_inner"), "normal", s),
    }


def _time_mix_inputs(lp, xn: jax.Array, x_prev: jax.Array, cfg: ModelConfig):
    """Token-shift lerp + projections. xn (B,S,D) normalized; x_prev same
    shape, shifted by one (previous token's normalized x)."""
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    mix = lp["mix"].astype(xn.dtype)  # (5, D)
    delta = x_prev - xn
    xr, xk, xv, xw, xg = (xn + mix[i][None, None, :] * delta for i in range(5))
    shp = xn.shape[:-1] + (h, hd)
    r = (xr @ lp["wr"].astype(xn.dtype)).reshape(shp)
    k = (xk @ lp["wk"].astype(xn.dtype)).reshape(shp)
    v = (xv @ lp["wv"].astype(xn.dtype)).reshape(shp)
    g = jax.nn.silu(xg @ lp["wg"].astype(xn.dtype))
    w_raw = (lp["w_base"].astype(jnp.float32)
             + jnp.tanh(xw.astype(jnp.float32) @ lp["w_lora_a"].astype(jnp.float32))
             @ lp["w_lora_b"].astype(jnp.float32))
    log_w = -jnp.exp(jnp.clip(w_raw, -8.0, 4.0))  # <= 0, data-dependent
    return r, k, v, g, log_w.reshape(xn.shape[:-1] + (h, hd))


def _group_norm_heads(y: jax.Array, scale, bias, h: int, eps: float):
    """Per-head LayerNorm (GroupNorm with H groups) over (..., H*Dh)."""
    b, s, _ = y.shape
    yh = y.reshape(b, s, h, -1).astype(jnp.float32)
    mu = yh.mean(axis=-1, keepdims=True)
    var = yh.var(axis=-1, keepdims=True)
    yn = ((yh - mu) * jax.lax.rsqrt(var + eps)).reshape(b, s, -1)
    return (yn * scale.astype(jnp.float32) + bias.astype(jnp.float32))


def rwkv6_time_mix(lp, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    h = d // cfg.rwkv_head_dim
    xn = layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
    x_prev = jnp.concatenate([jnp.zeros_like(xn[:, :1]), xn[:, :-1]], axis=1)
    r, k, v, g, log_w = _time_mix_inputs(lp, xn, x_prev, cfg)
    y, _state = wkv6_mix(r, k, v, log_w, lp["u"], cfg)
    y = _group_norm_heads(y.reshape(b, s, d), lp["ln_x"]["scale"],
                          lp["ln_x"]["bias"], h, cfg.norm_eps)
    y = (y.astype(x.dtype) * g) @ lp["wo"].astype(x.dtype)
    return x + y


def rwkv6_channel_mix(lp, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xn = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
    x_prev = jnp.concatenate([jnp.zeros_like(xn[:, :1]), xn[:, :-1]], axis=1)
    mix = lp["mix_c"].astype(xn.dtype)
    delta = x_prev - xn
    xk = xn + mix[0][None, None, :] * delta
    xr = xn + mix[1][None, None, :] * delta
    kk = jnp.square(jax.nn.relu(xk @ lp["wck"].astype(xn.dtype)))
    out = (kk @ lp["wcv"].astype(xn.dtype)) * jax.nn.sigmoid(
        xr @ lp["wcr"].astype(xn.dtype))
    return x + out


def rwkv6_block(lp, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rwkv6_time_mix(lp, x, cfg)
    x = rwkv6_channel_mix(lp, x, cfg)
    return constrain(x, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def rwkv6_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    pv = round_up(cfg.vocab_size, 128)
    return {
        "embed": ParamDef((pv, d), ("vocab", "embed"), "embed", 0.02),
        "ln_in": norm_def(d, "layernorm"),
        "layers": stack_defs(cfg.n_layers, rwkv6_def(cfg)),
        "final_norm": norm_def(d, "layernorm"),
        "lm_head": ParamDef((d, pv), ("embed", "vocab"), "normal",
                            1.0 / math.sqrt(d)),
    }


@dataclass
class RWKV6LM:
    cfg: ModelConfig
    dtype: Any = jnp.bfloat16
    remat: str = "full"
    block_kv: int = 512  # unused (attention-free); kept for interface parity
    unroll_layers: bool = False

    def _run(self, params, x):
        cfg = self.cfg
        block = maybe_checkpoint(
            lambda h, lp: rwkv6_block(lp, h, cfg), self.remat)

        def body(carry, lp):
            return block(carry, lp), None

        x, _ = maybe_scan(body, x, params["layers"], self.unroll_layers)
        return x

    def loss(self, params, batch):
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, _ = embed_inputs(params, batch, cfg, self.dtype)
        x = layer_norm(x, params["ln_in"]["scale"], params["ln_in"]["bias"],
                       cfg.norm_eps)
        x = constrain(x, ("batch", "seq", "embed"))
        x = self._run(params, x)
        logits = _logits(params, x, cfg)
        loss, denom = cross_entropy_loss(
            logits, batch["labels"], batch.get("loss_mask"), cfg.vocab_size)
        return loss, {"loss": loss, "tokens": denom}

    # -- serving ------------------------------------------------------------
    # cache per layer: wkv state (B,H,D,D) + token-shift buffers (B, D) x2
    def cache_shapes(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        d = cfg.d_model
        h = d // cfg.rwkv_head_dim
        f32 = jnp.float32
        L = cfg.n_layers
        return {
            "wkv": jax.ShapeDtypeStruct((L, batch_size, h, cfg.rwkv_head_dim,
                                         cfg.rwkv_head_dim), f32),
            "shift_t": jax.ShapeDtypeStruct((L, batch_size, d), self.dtype),
            "shift_c": jax.ShapeDtypeStruct((L, batch_size, d), self.dtype),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_axes(self):
        return {
            "wkv": ("layers", "batch", "rwkv_heads", None, None),
            "shift_t": ("layers", "batch", "embed"),
            "shift_c": ("layers", "batch", "embed"),
            "pos": (),
        }

    def _decode_layer(self, lp, x, cache_layer, cfg: ModelConfig):
        """x (B, D) single token; cache_layer leaves without layer dim."""
        b, d = x.shape
        h = d // cfg.rwkv_head_dim
        xn = layer_norm(x[:, None, :], lp["ln1"]["scale"], lp["ln1"]["bias"],
                        cfg.norm_eps)[:, 0]
        x_prev = cache_layer["shift_t"].astype(xn.dtype)
        r, k, v, g, log_w = _time_mix_inputs(
            lp, xn[:, None, :], x_prev[:, None, :], cfg)
        y, state = wkv6_decode_step(
            cache_layer["wkv"], r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], lp["u"])
        y = _group_norm_heads(y.reshape(b, 1, d), lp["ln_x"]["scale"],
                              lp["ln_x"]["bias"], h, cfg.norm_eps)
        y = (y.astype(x.dtype) * g)[:, 0] @ lp["wo"].astype(x.dtype)
        x = x + y
        # channel mix
        xn2 = layer_norm(x[:, None, :], lp["ln2"]["scale"], lp["ln2"]["bias"],
                         cfg.norm_eps)[:, 0]
        c_prev = cache_layer["shift_c"].astype(xn2.dtype)
        mix = lp["mix_c"].astype(xn2.dtype)
        delta = c_prev - xn2
        xk = xn2 + mix[0][None, :] * delta
        xr = xn2 + mix[1][None, :] * delta
        kk = jnp.square(jax.nn.relu(xk @ lp["wck"].astype(xn2.dtype)))
        out = (kk @ lp["wcv"].astype(xn2.dtype)) * jax.nn.sigmoid(
            xr @ lp["wcr"].astype(xn2.dtype))
        x = x + out
        new_cache = {"wkv": state, "shift_t": xn.astype(self.dtype),
                     "shift_c": xn2.astype(self.dtype)}
        return x, new_cache

    def decode(self, params, cache, tokens):
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, _ = embed_inputs(params, {"tokens": tokens}, cfg, self.dtype)
        x = layer_norm(x, params["ln_in"]["scale"], params["ln_in"]["bias"],
                       cfg.norm_eps)[:, 0]

        def body(carry, inp):
            lp, cl = inp
            x, new_cl = self._decode_layer(lp, carry, cl, cfg)
            return x, new_cl

        layer_cache = {k: cache[k] for k in ("wkv", "shift_t", "shift_c")}
        x, new_cache = maybe_scan(body, x, (params["layers"], layer_cache),
                                  self.unroll_layers)
        logits = _logits(params, x[:, None, :], cfg)[:, 0]
        active = cache.get("active")
        new_cache["pos"] = advance_pos(cache["pos"], tokens.shape[1], active)
        if active is not None:
            new_cache["active"] = active
        return logits, new_cache

    def prefill(self, params, batch, cache_len: Optional[int] = None):
        """Prefill = full forward computing final states per layer."""
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, _ = embed_inputs(params, batch, cfg, self.dtype)
        x = layer_norm(x, params["ln_in"]["scale"], params["ln_in"]["bias"],
                       cfg.norm_eps)
        s = x.shape[1]

        def body(carry, lp):
            h = carry
            b, _, d = h.shape
            nh = d // cfg.rwkv_head_dim
            xn = layer_norm(h, lp["ln1"]["scale"], lp["ln1"]["bias"],
                            cfg.norm_eps)
            x_prev = jnp.concatenate([jnp.zeros_like(xn[:, :1]), xn[:, :-1]],
                                     axis=1)
            r, k, v, g, log_w = _time_mix_inputs(lp, xn, x_prev, cfg)
            y, state = wkv6_mix(r, k, v, log_w, lp["u"], cfg)
            y = _group_norm_heads(y.reshape(b, s, d), lp["ln_x"]["scale"],
                                  lp["ln_x"]["bias"], nh, cfg.norm_eps)
            h = h + (y.astype(h.dtype) * g) @ lp["wo"].astype(h.dtype)
            shift_t = xn[:, -1]
            xn2 = layer_norm(h, lp["ln2"]["scale"], lp["ln2"]["bias"],
                             cfg.norm_eps)
            h = rwkv6_channel_mix(lp, h, cfg)
            shift_c = xn2[:, -1]
            return h, {"wkv": state, "shift_t": shift_t.astype(self.dtype),
                       "shift_c": shift_c.astype(self.dtype)}

        x, cache = maybe_scan(body, x, params["layers"], self.unroll_layers)
        logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
        cache["pos"] = jnp.asarray(s, jnp.int32)
        return logits, cache
