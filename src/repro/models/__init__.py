from repro.models.model_zoo import (  # noqa: F401
    abstract_params,
    active_param_count,
    batch_logical_axes,
    build_model,
    decode_token_specs,
    init_params,
    make_train_batch,
    model_defs,
    param_axes,
    param_count,
    prefill_batch_specs,
    train_batch_specs,
)
