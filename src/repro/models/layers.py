"""Parameter definitions + common layers (functional, pytree-of-dicts style).

A model is declared as a nested dict of :class:`ParamDef` leaves.  The same
declaration tree yields (a) materialized fp32 parameters, (b) abstract
ShapeDtypeStructs for the dry-run, and (c) logical-axis PartitionSpecs for the
distribution layer — guaranteed structurally consistent because they all come
from one tree.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamDef(NamedTuple):
    """Declaration of one parameter leaf."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = never sharded)
    init: str = "normal"  # normal | zeros | ones | embed | uniform_conv
    scale: float = 1.0  # stddev for "normal"/"embed"

    def initializer(self, rng: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, jnp.float32)
        if self.init == "ones":
            return jnp.ones(self.shape, jnp.float32)
        if self.init in ("normal", "embed"):
            return self.scale * jax.random.truncated_normal(
                rng, -3.0, 3.0, self.shape, jnp.float32)
        if self.init == "uniform_conv":  # conv1d default: U(-1/sqrt(k), 1/sqrt(k))
            lim = self.scale
            return jax.random.uniform(rng, self.shape, jnp.float32, -lim, lim)
        raise ValueError(f"unknown init {self.init!r}")


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(rng: jax.Array, defs: Any) -> Any:
    """Materialize a ParamDef tree into fp32 arrays (path-deterministic rngs)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    rngs = jax.random.split(rng, len(leaves))
    out = [d.initializer(r) for d, r in zip(leaves, rngs)]
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs: Any) -> Any:
    """ShapeDtypeStructs for the dry-run — no allocation."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32), defs, is_leaf=_is_def)


def logical_axes(defs: Any) -> Any:
    """Tree of logical-axis tuples, parallel to the params tree."""
    return jax.tree_util.tree_map(lambda d: d.axes, defs, is_leaf=_is_def)


def param_count(defs: Any) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree_util.tree_leaves(defs, is_leaf=_is_def))


def stack_defs(n: int, defs: Any, axis_name: str = "layers") -> Any:
    """Stack a per-layer ParamDef tree for scan-over-layers (leading dim n)."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.scale),
        defs, is_leaf=_is_def)


def maybe_scan(body, init, xs, unroll: bool = False):
    """lax.scan, or a Python-unrolled equivalent (roofline measurement mode:
    XLA cost analysis counts while-loop bodies once, so per-layer collective
    bytes are measured on small unrolled depths and extrapolated)."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda t: t[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *ys)
    return carry, stacked


def maybe_checkpoint(fn, remat: str):
    """Activation-checkpointing policy for the layer-scan body."""
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_no_batch_dims)
    raise ValueError(f"unknown remat policy {remat!r}")


# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------

def cast(tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(params: Dict[str, jax.Array], x: jax.Array, kind: str,
               eps: float) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"], eps)
    return layer_norm(x, params["scale"], params["bias"], eps)


def norm_def(d: int, kind: str, axes: Tuple[Optional[str], ...] = ("embed",)):
    if kind == "rmsnorm":
        return {"scale": ParamDef((d,), axes, "ones")}
    return {"scale": ParamDef((d,), axes, "ones"),
            "bias": ParamDef((d,), axes, "zeros")}


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S). Rotate-half convention."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2) broadcasting over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_def(d_model: int, d_ff: int, kind: str) -> Dict[str, ParamDef]:
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    if kind == "swiglu":
        return {
            "w_gate": ParamDef((d_model, d_ff), ("embed", "mlp"), "normal", s_in),
            "w_up": ParamDef((d_model, d_ff), ("embed", "mlp"), "normal", s_in),
            "w_down": ParamDef((d_ff, d_model), ("mlp", "embed"), "normal", s_out),
        }
    return {
        "w_up": ParamDef((d_model, d_ff), ("embed", "mlp"), "normal", s_in),
        "b_up": ParamDef((d_ff,), ("mlp",), "zeros"),
        "w_down": ParamDef((d_ff, d_model), ("mlp", "embed"), "normal", s_out),
        "b_down": ParamDef((d_model,), ("embed",), "zeros"),
    }


def mlp_apply(params: Dict[str, jax.Array], x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        gate = jax.nn.silu(x @ params["w_gate"])
        return (gate * (x @ params["w_up"])) @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_up"] + params["b_up"])
    return h @ params["w_down"] + params["b_down"]


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def advance_pos(pos: jax.Array, n: int, active=None, limit=None) -> jax.Array:
    """Advance decode position(s) by ``n`` generated tokens.

    Per-slot serving rules (both are slot-lifecycle guards — an idle slot's
    position used to grow without bound, one step per fused decode, until
    its cache writes walked past the row):

    * ``active`` (per-slot bool mask): inactive (free/evicted) slots stay
      frozen at their current position instead of drifting.
    * ``limit`` (cache capacity): positions saturate at ``limit`` rather
      than growing past it — the matching cache writes are dropped, not
      clamped onto the last row (see ``decode_attention``).

    With both ``None`` this is the legacy scalar path: ``pos + n`` exactly
    (decode-replay depends on exact arithmetic)."""
    new = pos + n
    if limit is not None:
        new = jnp.minimum(new, limit)
    if active is not None:
        new = jnp.where(active, new, pos)
    return new


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array], vocab_size: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Mean masked next-token loss. Handles padded vocab (logits wider than
    vocab_size get -inf)."""
    logits = logits.astype(jnp.float32)
    padded = logits.shape[-1]
    if padded != vocab_size:
        iota = jnp.arange(padded)
        logits = jnp.where(iota[None, None, :] < vocab_size, logits, -1e9)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / total
    return loss, total
