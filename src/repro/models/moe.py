"""Fine-grained MoE (DeepSeekMoE / Moonlight style): shared + routed experts.

Top-k token-choice routing with a capacity buffer.  Dispatch is sort-free:
the position of each (token, expert) assignment inside its expert's capacity
buffer is a cumulative count over a one-hot matrix — static shapes, scatter +
gather, TPU/XLA-SPMD friendly.  Experts are sharded over the ``model`` mesh
axis (EP); the scatter/gather across the token-sharded <-> expert-sharded
boundary lowers to all-to-all-style collectives under SPMD.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models.layers import (
    ParamDef, advance_pos, apply_norm, cast, cross_entropy_loss,
    maybe_checkpoint, maybe_scan, mlp_def, mlp_apply, norm_def, round_up,
    stack_defs)
from repro.models.transformer import DenseLM, _logits, embed_inputs


def moe_ffn_def(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    defs: Dict[str, Any] = {
        "router": ParamDef((d, e), ("embed", "experts"), "normal", s_in),
        "w_gate": ParamDef((e, d, f), ("experts", "embed", "mlp"), "normal", s_in),
        "w_up": ParamDef((e, d, f), ("experts", "embed", "mlp"), "normal", s_in),
        "w_down": ParamDef((e, f, d), ("experts", "mlp", "embed"), "normal", s_out),
    }
    if cfg.n_shared_experts:
        defs["shared"] = mlp_def(d, cfg.d_ff * cfg.n_shared_experts, "swiglu")
    return defs


def capacity(tokens: int, cfg: ModelConfig) -> int:
    return max(1, int(math.ceil(
        tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)))


def moe_ffn(params, x: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) -> (B, S, D), aux losses."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(t, cfg)
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renorm

    # position of each assignment within its expert (sort-free ranking)
    flat_e = expert_idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < c
    dest = jnp.where(keep, flat_e * c + pos, e * c)  # E*c = drop slot

    src = jnp.arange(t * k) // k  # token index per assignment
    gathered = jnp.take(xt, src, axis=0)  # (T*k, D)
    buf = jnp.zeros((e * c + 1, d), x.dtype).at[dest].set(gathered)
    buf = buf[:e * c].reshape(e, c, d)
    buf = constrain(buf, ("experts", None, None))

    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    y_buf = jnp.einsum("ecf,efd->ecd", h, wd)  # (E, C, D)
    y_buf = constrain(y_buf, ("experts", None, None))

    y_flat = jnp.concatenate(
        [y_buf.reshape(e * c, d), jnp.zeros((1, d), x.dtype)], axis=0)
    y_assign = jnp.take(y_flat, dest, axis=0)  # (T*k, D); drops read zeros
    w = (gate.reshape(-1).astype(x.dtype) * keep.astype(x.dtype))
    y = (y_assign * w[:, None]).reshape(t, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        y = y + mlp_apply(params["shared"], xt, "swiglu")

    # aux losses (Switch-style load balance + router z-loss)
    me = probs.mean(axis=0)  # mean router prob per expert
    ce_frac = (onehot.sum(axis=0).astype(jnp.float32) / (t * k))
    aux = {
        "load_balance": e * jnp.sum(me * ce_frac),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "dropped_frac": 1.0 - keep.astype(jnp.float32).mean(),
    }
    return y.reshape(b, s, d), aux


def moe_ffn_rowlocal(params, x: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Row-local (hierarchical GShard-style) dispatch — the §Perf fix.

    The global-cumsum dispatch above ranks (token, expert) assignments over
    the *global* token axis, which under SPMD forces every device to see
    every token (~95 GiB/layer of all-gather on the 256-chip mesh — see
    EXPERIMENTS.md §Perf).  Here ranking + capacity are computed per batch
    row, so all dispatch arithmetic is local to the row's data shard and the
    only cross-device movement is the unavoidable token hop from the
    batch-sharded buffer to the expert-sharded einsum (all-to-all-sized).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(s, cfg)  # per-row capacity

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)  # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(b, s * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (B, S*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1,
                              flat_e[..., None], axis=2)[..., 0]  # (B, S*k)
    keep = pos < c
    dest = jnp.where(keep, flat_e * c + pos, e * c)

    # Dispatch = int32 inverse-slot scatter + batched gather.  Scattering the
    # *vectors* here (first attempt — see EXPERIMENTS.md §Perf, refuted) is
    # not SPMD-partitionable: XLA replicates the updates and masks+all-reduces
    # the sharded output (~180 GiB/layer).  Scattering only the slot->token
    # int32 map moves KBs, and the vector movement becomes a batch-aligned
    # take_along_axis that partitions cleanly.
    rows = jnp.arange(b)[:, None]
    inv = jnp.full((b, e * c + 1), s * k, jnp.int32)
    inv = inv.at[rows, dest].set(
        jnp.broadcast_to(jnp.arange(s * k, dtype=jnp.int32), (b, s * k)))
    inv = inv[:, :e * c]  # (B, E*C): assignment index occupying each slot
    slot_valid = inv < s * k
    tok = jnp.minimum(inv // k, s - 1)  # token index per slot
    buf = jnp.take_along_axis(x, tok[..., None], axis=1)  # (B, E*C, D)
    buf = buf * slot_valid[..., None].astype(x.dtype)
    buf = buf.reshape(b, e, c, d)
    buf = constrain(buf, ("batch", "experts", None, None))

    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg))
    h = h * jnp.einsum("becd,edf->becf", buf, wu)
    y_buf = jnp.einsum("becf,efd->becd", h, wd)
    y_buf = constrain(y_buf, ("batch", "experts", None, None))

    y_flat = jnp.concatenate(
        [y_buf.reshape(b, e * c, d), jnp.zeros((b, 1, d), x.dtype)], axis=1)
    y_assign = jnp.take_along_axis(y_flat, dest[..., None], axis=1)
    w = (gate.reshape(b, s * k).astype(x.dtype) * keep.astype(x.dtype))
    y = (y_assign * w[..., None]).reshape(b, s, k, d).sum(axis=2)

    if cfg.n_shared_experts:
        y = y + mlp_apply(params["shared"], x, "swiglu")

    me = probs.reshape(-1, e).mean(axis=0)
    ce_frac = onehot.sum(axis=(0, 1)).astype(jnp.float32) / (b * s * k)
    aux = {
        "load_balance": e * jnp.sum(me * ce_frac),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "dropped_frac": 1.0 - keep.astype(jnp.float32).mean(),
    }
    return y, aux


def apply_moe_ffn(params, x, cfg: ModelConfig):
    if cfg.moe_dispatch == "row_local":
        return moe_ffn_rowlocal(params, x, cfg)
    return moe_ffn(params, x, cfg)


def moe_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    pv = round_up(cfg.vocab_size, 128)
    layer = {
        "ln1": norm_def(d, cfg.norm),
        "attn": attn_mod.attention_def(cfg),
        "ln2": norm_def(d, cfg.norm),
        "moe": moe_ffn_def(cfg),
    }
    defs: Dict[str, Any] = {
        "embed": ParamDef((pv, d), ("vocab", "embed"), "embed", 0.02),
        "layers": stack_defs(cfg.n_layers, layer),
        "final_norm": norm_def(d, cfg.norm),
        "lm_head": ParamDef((d, pv), ("embed", "vocab"), "normal",
                            1.0 / math.sqrt(d)),
    }
    return defs


@dataclass
class MoELM(DenseLM):
    """MoE decoder — reuses the dense attention/serving skeleton, swaps the
    FFN for shared+routed experts and adds router aux losses."""

    def _moe_block(self, collect_kv: bool):
        cfg = self.cfg

        def fn(x, lp, positions):
            h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
            a, kv = attn_mod.full_attention(lp["attn"], h, cfg, positions,
                                            block_kv=self.block_kv)
            x = x + a
            h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
            y, aux = apply_moe_ffn(lp["moe"], h, cfg)
            x = x + y
            x = constrain(x, ("batch", "seq", "embed"))
            if collect_kv:
                return x, (kv, aux)
            return x, aux
        return fn

    def loss(self, params, batch):
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, positions = embed_inputs(params, batch, cfg, self.dtype)
        x = constrain(x, ("batch", "seq", "embed"))
        block = maybe_checkpoint(self._moe_block(collect_kv=False), self.remat)

        def body(carry, lp):
            return block(carry, lp, positions)

        x, aux = maybe_scan(body, x, params["layers"], self.unroll_layers)
        logits = _logits(params, x, cfg)
        if cfg.frontend == "vision_patches":
            logits = logits[:, batch["patch_embeds"].shape[1]:, :]
        loss, denom = cross_entropy_loss(
            logits, batch["labels"], batch.get("loss_mask"), cfg.vocab_size)
        lb = aux["load_balance"].mean()
        rz = aux["router_z"].mean()
        total = loss + cfg.router_aux_coef * lb + cfg.router_z_coef * rz
        return total, {"loss": loss, "tokens": denom, "load_balance": lb,
                       "router_z": rz,
                       "dropped_frac": aux["dropped_frac"].mean()}

    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, positions = embed_inputs(params, batch, cfg, self.dtype)
        s = x.shape[1]
        cache_len = cache_len or s
        block = self._moe_block(collect_kv=True)

        def body(carry, lp):
            y, (kv, _aux) = block(carry, lp, positions)
            return y, kv

        x, (ks, vs) = maybe_scan(body, x, params["layers"], self.unroll_layers)
        logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
        pad = cache_len - s
        if pad:
            zeros = jnp.zeros(
                (ks.shape[0], ks.shape[1], pad) + ks.shape[3:], ks.dtype)
            ks = jnp.concatenate([ks, zeros], axis=2)
            vs = jnp.concatenate([vs, zeros], axis=2)
        cache = {"k": ks.astype(self.dtype), "v": vs.astype(self.dtype),
                 "pos": jnp.asarray(s, jnp.int32)}
        return logits, cache

    def decode(self, params, cache, tokens):
        cfg = self.cfg
        params = cast(params, self.dtype)
        pos = cache["pos"]
        active = cache.get("active")
        page_table = cache.get("page_table")
        x, _ = embed_inputs(params, {"tokens": tokens}, cfg, self.dtype,
                            start_pos=pos)

        def body(carry, inp):
            x = carry
            lp, ck, cv = inp
            h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
            a, ck, cv = attn_mod.decode_attention(lp["attn"], h, cfg, ck, cv,
                                                  pos, active=active,
                                                  page_table=page_table)
            x = x + a
            h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
            y, _aux = apply_moe_ffn(lp["moe"], h, cfg)
            x = x + y
            return x, (ck, cv)

        x, (ks, vs) = maybe_scan(
            body, x, (params["layers"], cache["k"], cache["v"]),
            self.unroll_layers)
        logits = _logits(params, x, cfg)[:, 0]
        if page_table is not None:
            cap = page_table.shape[1] * cache["k"].shape[2]
        else:
            cap = cache["k"].shape[2]
        new_pos = advance_pos(pos, tokens.shape[1], active,
                              limit=cap if pos.ndim else None)
        out = {"k": ks, "v": vs, "pos": new_pos}
        if active is not None:
            out["active"] = active
        if page_table is not None:
            out["page_table"] = page_table
        return logits, out
