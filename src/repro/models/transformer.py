"""Dense decoder-only LM (llama/GPT-style) with scan-over-layers.

Covers: smollm-360m, phi3-mini-3.8b, qwen3-32b (qk_norm), qwen2-1.5b
(qkv_bias), musicgen-large (audio_frames frontend stub, learned pos, GELU),
llava-next-mistral-7b (vision_patches prefix stub), and the paper's GPT-2 /
GPT-3 replicas (learned pos, LayerNorm, GELU).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models.layers import (
    ParamDef, advance_pos, apply_norm, cast, cross_entropy_loss,
    maybe_checkpoint, maybe_scan, mlp_def, mlp_apply, norm_def, round_up,
    stack_defs)


def dense_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    pv = round_up(cfg.vocab_size, 128)
    layer = {
        "ln1": norm_def(d, cfg.norm),
        "attn": attn_mod.attention_def(cfg),
        "ln2": norm_def(d, cfg.norm),
        "mlp": mlp_def(d, cfg.d_ff, cfg.mlp),
    }
    defs: Dict[str, Any] = {
        "embed": ParamDef((pv, d), ("vocab", "embed"), "embed", 0.02),
        "layers": stack_defs(cfg.n_layers, layer),
        "final_norm": norm_def(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, pv), ("embed", "vocab"), "normal",
                                   1.0 / math.sqrt(d))
    if cfg.pos_emb == "learned":
        defs["pos_embed"] = ParamDef((cfg.max_seq_len, d), ("pos", "embed"),
                                     "embed", 0.02)
    return defs


def embed_inputs(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
                 dtype, start_pos=0) -> Tuple[jax.Array, jax.Array]:
    """Token/frontend embedding. Returns (x, positions).

    ``start_pos`` is a scalar (legacy whole-batch decode) or a per-row (B,)
    vector (continuous batching), yielding positions (S,) or (B, S).
    """
    if cfg.frontend == "audio_frames" and "frames" in batch:
        x = batch["frames"].astype(dtype)  # stubbed EnCodec frame embeddings
    else:
        # cast the table *before* the take: the convert runs shard-local, so
        # the SPMD gather of the rows moves bf16, not f32 (halves that
        # all-gather — see EXPERIMENTS.md §Perf)
        x = jnp.take(params["embed"].astype(dtype), batch["tokens"], axis=0)
        if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
            x = jnp.concatenate([batch["patch_embeds"].astype(dtype), x], axis=1)
    s = x.shape[1]
    start = jnp.asarray(start_pos)
    positions = (start[:, None] + jnp.arange(s) if start.ndim
                 else start + jnp.arange(s))
    if cfg.pos_emb == "learned":
        x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(dtype)
    return x, positions


def _logits(params, x, cfg: ModelConfig):
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))


def _block(cfg: ModelConfig, block_kv: int):
    def fn(x, lp, positions):
        h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
        a, kv = attn_mod.full_attention(lp["attn"], h, cfg, positions,
                                        block_kv=block_kv)
        x = x + a
        h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, cfg.mlp)
        x = constrain(x, ("batch", "seq", "embed"))
        return x, kv
    return fn


@dataclass
class DenseLM:
    cfg: ModelConfig
    dtype: Any = jnp.bfloat16
    remat: str = "full"
    block_kv: int = 512
    # roofline measurement mode: unroll the layer scan (see layers.maybe_scan)
    unroll_layers: bool = False

    # -- training ----------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, positions = embed_inputs(params, batch, cfg, self.dtype)
        x = constrain(x, ("batch", "seq", "embed"))
        block = maybe_checkpoint(self._block_nokv(), self.remat)

        def body(carry, lp):
            return block(carry, lp, positions), None

        x, _ = maybe_scan(body, x, params["layers"], self.unroll_layers)
        logits = _logits(params, x, cfg)
        if cfg.frontend == "vision_patches":
            logits = logits[:, batch["patch_embeds"].shape[1]:, :]
        loss, denom = cross_entropy_loss(
            logits, batch["labels"], batch.get("loss_mask"), cfg.vocab_size)
        return loss, {"loss": loss, "tokens": denom}

    def _block_nokv(self):
        inner = _block(self.cfg, self.block_kv)

        def fn(x, lp, positions):
            y, _ = inner(x, lp, positions)
            return y
        return fn

    # -- serving -----------------------------------------------------------
    def prefill(self, params, batch, cache_len: Optional[int] = None):
        """Full forward; returns (last-position logits, KV cache)."""
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, positions = embed_inputs(params, batch, cfg, self.dtype)
        s = x.shape[1]
        cache_len = cache_len or s
        block = _block(cfg, self.block_kv)

        def body(carry, lp):
            y, kv = block(carry, lp, positions)
            return y, kv

        x, (ks, vs) = maybe_scan(body, x, params["layers"], self.unroll_layers)
        logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
        pad = cache_len - s
        if pad:
            zeros = jnp.zeros(
                (ks.shape[0], ks.shape[1], pad) + ks.shape[3:], ks.dtype)
            ks = jnp.concatenate([ks, zeros], axis=2)
            vs = jnp.concatenate([vs, zeros], axis=2)
        cache = {"k": ks.astype(self.dtype), "v": vs.astype(self.dtype),
                 "pos": jnp.asarray(s, jnp.int32)}
        return logits, cache

    def decode(self, params, cache, tokens):
        """One decode step: tokens (B, 1) against the cache. Returns
        (logits (B, V), new cache).

        Slot caches may carry two optional leaves the legacy scalar-pos
        cache lacks: ``active`` (per-slot occupancy — inactive slots freeze
        their position and drop cache writes) and ``page_table`` (the KV
        leaves are shared paged pools — see serve/paging.py); both pass
        through unchanged."""
        cfg = self.cfg
        params = cast(params, self.dtype)
        pos = cache["pos"]
        active = cache.get("active")
        page_table = cache.get("page_table")
        x, _ = embed_inputs(params, {"tokens": tokens}, cfg, self.dtype,
                            start_pos=pos)

        def body(carry, inp):
            x = carry
            lp, ck, cv = inp
            h = apply_norm(lp["ln1"], x, cfg.norm, cfg.norm_eps)
            a, ck, cv = attn_mod.decode_attention(lp["attn"], h, cfg, ck, cv,
                                                  pos, active=active,
                                                  page_table=page_table)
            x = x + a
            h = apply_norm(lp["ln2"], x, cfg.norm, cfg.norm_eps)
            x = x + mlp_apply(lp["mlp"], h, cfg.mlp)
            return x, (ck, cv)

        x, (ks, vs) = maybe_scan(
            body, x, (params["layers"], cache["k"], cache["v"]),
            self.unroll_layers)
        logits = _logits(params, x, cfg)[:, 0]
        if page_table is not None:
            cap = page_table.shape[1] * cache["k"].shape[2]  # pages * page_sz
        else:
            cap = cache["k"].shape[2]  # dense per-slot row length
        new_pos = advance_pos(pos, tokens.shape[1], active,
                              limit=cap if pos.ndim else None)
        out = {"k": ks, "v": vs, "pos": new_pos}
        if active is not None:
            out["active"] = active
        if page_table is not None:
            out["page_table"] = page_table
        return logits, out

    # -- specs ---------------------------------------------------------------
    def cache_shapes(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        kv = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, seq_len, kvh, hd), self.dtype)
        return {"k": kv, "v": kv,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_axes(self):
        kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
        return {"k": kv, "v": kv, "pos": ()}
