"""GQA attention: flash/blockwise train-prefill backends + cached decode.

Two train/prefill backends, selected by ``ModelConfig.attn_backend``:

* ``blockwise`` — jnp online-softmax scan over KV blocks.  Peak memory is
  O(S * block) instead of O(S^2) and it is fully differentiable through
  XLA; it doubles as the pure-jnp oracle for the kernel below.
* ``flash`` — the Pallas flash-attention kernel
  (``repro.kernels.flash_attention``), now differentiable end-to-end via
  ``jax.custom_vjp`` (fused forward emitting logsumexp residuals + three
  backward kernels), so ``jax.value_and_grad`` in the train step runs the
  kernel in both directions.  On non-TPU backends "flash" falls back to
  blockwise; "flash_interpret" forces the kernel in interpret mode (tests).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import on_tpu, resolve_backend
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_decode.ops import flash_decode, flash_decode_paged
from repro.kernels.flash_decode.ref import gather_pages
from repro.models.layers import ParamDef, apply_rope, rms_norm

NEG_INF = -1e30


def attention_def(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = 1.0 / math.sqrt(d)
    s_o = 1.0 / math.sqrt(h * hd)
    defs = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim"), "normal", s),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim"), "normal", s),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim"), "normal", s),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed"), "normal", s_o),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("heads", "head_dim"), "zeros")
        defs["bk"] = ParamDef((kv, hd), ("kv_heads", "head_dim"), "zeros")
        defs["bv"] = ParamDef((kv, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), ("head_dim",), "ones")
        defs["k_norm"] = ParamDef((hd,), ("head_dim",), "ones")
    return defs


def _project_qkv(params, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, block_kv: int = 512,
                        q_offset: int = 0) -> jax.Array:
    """Online-softmax attention scanning over KV blocks.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H = KV * G.
    Memory high-water is O(Sq * block_kv) per head instead of O(Sq * Sk).
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    # odd bucket/remainder lengths (e.g. sk=544 against the default 512)
    # used to trip the divisibility assert.  Prefer the largest divisor of
    # sk within (block_kv/2, block_kv] — an exact scan with bounded waste;
    # when none exists (e.g. prime sk) pad the tail and mask the dead keys
    # rather than degenerating toward block_kv=1 (trace-time, sk is static)
    block_kv = min(block_kv, sk)
    block_kv = next((c for c in range(block_kv, block_kv // 2, -1)
                     if sk % c == 0), block_kv)
    pad = (-sk) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = (sk + pad) // block_kv

    qg = q.reshape(b, sq, kvh, g, d) * scale
    kb = k.reshape(b, n_blocks, block_kv, kvh, d)
    vb = v.reshape(b, n_blocks, block_kv, kvh, d)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        kc, vc, blk = inp  # (B, blk, KV, D), (B, blk, KV, D), ()
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, kc).astype(jnp.float32)
        k_pos = blk * block_kv + jnp.arange(block_kv)
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]  # (Sq, blk)
            if pad:
                mask &= (k_pos < sk)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        elif pad:
            s = jnp.where((k_pos < sk)[None, None, None, None, :], s,
                          NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqj,bjkd->bkgqd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, sq, d), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)  # (n_blocks, B, blk, KV, D)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kb_t, vb_t, jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def _context(q: jax.Array, k: jax.Array, v: jax.Array, cfg: ModelConfig,
             block_kv: int) -> jax.Array:
    """Backend dispatch for the train/prefill context computation.

    ``cfg.attn_backend`` selects between the jnp blockwise scan (the oracle)
    and the differentiable Pallas flash-attention kernel.  "flash" uses the
    compiled kernel only on TPU and falls back to blockwise elsewhere, so
    full-scale presets remain lowerable/compilable on any backend (e.g. the
    CPU dry-run); "flash_interpret" forces the kernel in interpret mode —
    the CPU validation path the gradient tests and the flash train-step
    smoke test run.
    """
    backend = cfg.attn_backend
    if backend == "flash" and on_tpu():
        return flash_attention(q, k, v, causal=True)
    if backend == "flash_interpret":
        return flash_attention(q, k, v, causal=True, interpret=True)
    if backend not in ("blockwise", "flash"):
        raise ValueError(f"unknown attn_backend {backend!r}")
    return blockwise_attention(q, k, v, causal=True, block_kv=block_kv)


def full_attention(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                   positions: jax.Array, block_kv: int = 512
                   ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Causal self-attention over the whole sequence. Returns (out, (k, v)).

    Routes through ``cfg.attn_backend`` (see ``_context``): the training
    step (``jax.value_and_grad`` in launch/steps.py) and the serve prefill
    both reach the Pallas kernel — forward *and* backward — when "flash" is
    selected on TPU.
    """
    q, k, v = _project_qkv(params, x, cfg, positions)
    ctx = _context(q, k, v, cfg, block_kv)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(x.dtype))
    return out, (k, v)


def decode_attention(params: Dict[str, jax.Array], x: jax.Array,
                     cfg: ModelConfig, cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, active: Optional[jax.Array] = None,
                     page_table: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a KV cache at position `pos`.

    ``pos`` is a scalar (whole batch at one position — the legacy static
    path) or a per-row (B,) vector (continuous batching: every slot decodes
    at its own depth).  Returns (out, new_cache_k, new_cache_v).

    Cache layouts:

    * dense (``page_table=None``): ``cache_k``/``cache_v`` are
      ``(B, S_max, KV, D)`` per-slot rows.
    * paged (``page_table`` = ``(B, max_pages)`` int32, ``-1`` = unowned):
      the caches are shared ``(n_pages, page_size, KV, D)`` pools and row
      ``b``'s token ``j`` lives at ``page_table[b, j // page_size]``,
      offset ``j % page_size`` (see serve/paging.py).  Paged decode is
      per-slot single-token only (the fused engine step).

    ``active`` is the per-slot (B,) occupancy mask when given: writes for
    inactive rows are dropped, so a free/evicted slot's cache never drifts
    between an evict and the next insert.  Writes past the cache capacity
    are likewise dropped (scatter ``mode="drop"`` on an out-of-bounds
    sentinel index), not silently clamped onto the last row — under a page
    table a clamped runaway position would corrupt another slot's page.

    Mask convention — **count of valid entries**: after this step's k/v
    write, a row decoding at position ``p`` has ``p + 1`` valid cache
    entries (indices ``0..p`` inclusive of the token just written) and
    cache row ``j`` attends iff ``j < p + 1``.  This is the same convention
    ``distributed.collectives.flash_decode_sharded`` and the flash-decode
    kernel use (``lengths`` = counts), pinned by the parity tests in
    tests/test_flash_decode.py.

    ``cfg.decode_backend`` selects the context computation: "reference"
    (jnp masked softmax over the full cache — the oracle; paged caches are
    gathered through the page table first), "kernel" (the Pallas split-KV
    flash-decode kernel on TPU — the page-table-walking variant for paged
    caches — reference elsewhere) or "kernel_interpret" (kernel in
    interpret mode — CPU validation).  The kernel serves the single-token
    step; multi-token calls stay on the reference path.
    """
    b, s_q, h, = x.shape[0], x.shape[1], cfg.n_heads
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    if per_slot:
        positions = pos[:, None] + jnp.arange(s_q)[None, :]  # (B, s_q)
    else:
        positions = pos + jnp.arange(s_q)[None, :]  # (1, s_q) broadcast
    q, k, v = _project_qkv(params, x, cfg, positions)

    if page_table is not None:
        if not per_slot or s_q != 1:
            raise ValueError("paged decode is per-slot single-token only "
                             f"(got pos ndim {pos.ndim}, s_q {s_q})")
        n_pages, page_size = cache_k.shape[0], cache_k.shape[1]
        max_pages = page_table.shape[1]
        pid = page_table[jnp.arange(b),
                         jnp.minimum(pos // page_size, max_pages - 1)]
        ok = (pid >= 0) & (pos // page_size < max_pages)
        if active is not None:
            ok = ok & active
        # flatten the pool and scatter at page*page_size + offset; rows
        # that may not write (inactive, unowned page, past capacity) get
        # the one-past-the-end sentinel and are dropped
        flat_idx = jnp.where(ok, pid * page_size + pos % page_size,
                             n_pages * page_size)

        def _pool_write(c, upd):
            fc = c.reshape((n_pages * page_size,) + c.shape[2:])
            fc = fc.at[flat_idx].set(upd.astype(c.dtype), mode="drop")
            return fc.reshape(c.shape)
        cache_k = _pool_write(cache_k, k[:, 0])
        cache_v = _pool_write(cache_v, v[:, 0])
    elif per_slot:
        s_max = cache_k.shape[1]
        if s_q == 1:
            ok = pos < s_max
            if active is not None:
                ok = ok & active
            idx = jnp.where(ok, pos, s_max)  # OOB sentinel -> dropped
            rows = jnp.arange(b)
            cache_k = cache_k.at[rows, idx].set(
                k[:, 0].astype(cache_k.dtype), mode="drop")
            cache_v = cache_v.at[rows, idx].set(
                v[:, 0].astype(cache_v.dtype), mode="drop")
        else:
            # multi-token per-slot replay: row b writes its s_q tokens at
            # pos[b]..pos[b]+s_q-1 (vmapped dynamic_update_slice lowers to
            # a scatter; callers keep pos + s_q <= s_max)
            def _row_write(c, upd, p):
                return jax.lax.dynamic_update_slice_in_dim(c, upd, p, axis=0)
            cache_k = jax.vmap(_row_write)(cache_k, k.astype(cache_k.dtype),
                                           pos)
            cache_v = jax.vmap(_row_write)(cache_v, v.astype(cache_v.dtype),
                                           pos)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), pos, axis=1)

    kvh = cfg.n_kv_heads
    g = h // kvh
    d = cfg.resolved_head_dim
    use_kernel, interpret = resolve_backend(cfg.decode_backend,
                                            "decode_backend")
    if use_kernel and s_q == 1:
        # counts of valid entries per row (the token just written included)
        lengths = (pos + 1 if per_slot
                   else jnp.broadcast_to(pos + 1, (b,))).astype(jnp.int32)
        if page_table is not None:
            ctx = flash_decode_paged(q[:, 0], cache_k, cache_v, page_table,
                                     lengths, interpret=interpret)[:, None]
        else:
            ctx = flash_decode(q[:, 0], cache_k, cache_v, lengths,
                               interpret=interpret)[:, None]
    else:
        if page_table is not None:
            kc = gather_pages(cache_k, page_table)
            vc = gather_pages(cache_v, page_table)
        else:
            kc, vc = cache_k, cache_v
        s_max = kc.shape[1]
        scale = 1.0 / math.sqrt(d)
        qg = q.reshape(b, s_q, kvh, g, d) * scale
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, kc).astype(jnp.float32)
        counts = positions + 1  # (B, s_q) or (1, s_q): valid-entry counts
        if per_slot:
            valid = jnp.arange(s_max)[None, None, :] < counts[:, :, None]
            s = jnp.where(valid[:, None, None], s, NEG_INF)
        else:
            valid = jnp.arange(s_max)[None, :] < counts[0][:, None]
            s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(vc.dtype), vc)
        ctx = ctx.transpose(0, 3, 1, 2, 4).reshape(b, s_q, h, d)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(x.dtype))
    return out, cache_k, cache_v
