"""Zamba2 hybrid: Mamba-2 backbone + one *shared* attention+MLP block.

The shared block (a single set of weights) is applied after every
``attn_every`` SSM layers; each application keeps its own KV cache.  The
layer stack is therefore a two-level scan: outer over groups (closing over
the shared weights, so gradients accumulate across applications — exactly the
weight-sharing semantics of the published model), inner over the group's
Mamba layers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import mamba2
from repro.models.layers import (
    ParamDef, advance_pos, apply_norm, cast, cross_entropy_loss,
    maybe_checkpoint, maybe_scan, mlp_def, mlp_apply, norm_def, round_up,
    stack_defs)
from repro.models.transformer import _logits, embed_inputs


def zamba2_defs(cfg: ModelConfig) -> Dict[str, Any]:
    assert cfg.n_layers % cfg.attn_every == 0, (cfg.n_layers, cfg.attn_every)
    d = cfg.d_model
    pv = round_up(cfg.vocab_size, 128)
    return {
        "embed": ParamDef((pv, d), ("vocab", "embed"), "embed", 0.02),
        "mamba_layers": stack_defs(cfg.n_layers, mamba2.mamba2_def(cfg)),
        "shared": {
            "ln1": norm_def(d, cfg.norm),
            "attn": attn_mod.attention_def(cfg),
            "ln2": norm_def(d, cfg.norm),
            "mlp": mlp_def(d, cfg.d_ff, cfg.mlp),
        },
        "final_norm": norm_def(d, cfg.norm),
        "lm_head": ParamDef((d, pv), ("embed", "vocab"), "normal",
                            1.0 / math.sqrt(d)),
    }


def _group_tree(tree, n_groups: int):
    """Reshape stacked (L, ...) leaves to (G, L/G, ...)."""
    return jax.tree_util.tree_map(
        lambda t: t.reshape((n_groups, t.shape[0] // n_groups) + t.shape[1:]),
        tree)


def _shared_block(shared, x, cfg: ModelConfig, positions, block_kv: int):
    h = apply_norm(shared["ln1"], x, cfg.norm, cfg.norm_eps)
    a, kv = attn_mod.full_attention(shared["attn"], h, cfg, positions,
                                    block_kv=block_kv)
    x = x + a
    h = apply_norm(shared["ln2"], x, cfg.norm, cfg.norm_eps)
    x = x + mlp_apply(shared["mlp"], h, cfg.mlp)
    return constrain(x, ("batch", "seq", "embed")), kv


@dataclass
class Zamba2LM:
    cfg: ModelConfig
    dtype: Any = jnp.bfloat16
    remat: str = "full"
    block_kv: int = 512
    unroll_layers: bool = False

    @property
    def n_groups(self) -> int:
        return self.cfg.n_layers // self.cfg.attn_every

    # -- training ------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, positions = embed_inputs(params, batch, cfg, self.dtype)
        x = constrain(x, ("batch", "seq", "embed"))
        grouped = _group_tree(params["mamba_layers"], self.n_groups)
        mblock = maybe_checkpoint(
            lambda h, lp: mamba2.mamba2_block(lp, h, cfg), self.remat)
        sblock = maybe_checkpoint(
            lambda h: _shared_block(params["shared"], h, cfg, positions,
                                    self.block_kv)[0], self.remat)

        def outer(carry, group_params):
            def inner(c, lp):
                return mblock(c, lp), None
            h, _ = maybe_scan(inner, carry, group_params, self.unroll_layers)
            h = sblock(h)
            return h, None

        x, _ = maybe_scan(outer, x, grouped, self.unroll_layers)
        logits = _logits(params, x, cfg)
        loss, denom = cross_entropy_loss(
            logits, batch["labels"], batch.get("loss_mask"), cfg.vocab_size)
        return loss, {"loss": loss, "tokens": denom}

    # -- serving ---------------------------------------------------------------
    def cache_shapes(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        g = self.n_groups
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        mcache = mamba2.mamba2_cache_shapes(cfg, cfg.n_layers, batch_size,
                                            self.dtype)
        kv = jax.ShapeDtypeStruct((g, batch_size, seq_len, kvh, hd), self.dtype)
        return {"mamba": mcache, "attn_k": kv, "attn_v": kv,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_axes(self):
        kv = ("groups", "batch", "seq", "kv_heads", "head_dim")
        return {"mamba": mamba2.mamba2_cache_axes(), "attn_k": kv,
                "attn_v": kv, "pos": ()}

    def prefill(self, params, batch, cache_len: Optional[int] = None):
        cfg = self.cfg
        params = cast(params, self.dtype)
        x, positions = embed_inputs(params, batch, cfg, self.dtype)
        s = x.shape[1]
        cache_len = cache_len or s
        grouped = _group_tree(params["mamba_layers"], self.n_groups)

        # mamba prefill needs final states: run block capturing state
        def mamba_with_state(lp, h):
            d_inner, nh, p, n = mamba2.mamba2_dims(cfg)
            b = h.shape[0]
            hn = mamba2.rms_norm(h, lp["norm_in"]["scale"], cfg.norm_eps)
            z, x_in, b_raw, c_raw, dt_raw = mamba2._proj_inputs(lp, hn, cfg)
            x_conv = jax.nn.silu(mamba2.causal_conv1d(
                x_in, lp["conv_x"]["w"], lp["conv_x"]["b"]))
            b_conv = jax.nn.silu(mamba2.causal_conv1d(
                b_raw, lp["conv_b"]["w"], lp["conv_b"]["b"]))
            c_conv = jax.nn.silu(mamba2.causal_conv1d(
                c_raw, lp["conv_c"]["w"], lp["conv_c"]["b"]))
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                                 + lp["dt_bias"].astype(jnp.float32))
            a_coef = -jnp.exp(lp["a_log"].astype(jnp.float32))
            xh = x_conv.reshape(b, s, nh, p)
            y, state = mamba2.ssd_mix(xh, dt, a_coef, b_conv, c_conv, cfg)
            y = y + lp["d_skip"].astype(y.dtype)[None, None, :, None] * xh
            y = y.reshape(b, s, d_inner)
            y = mamba2.rms_norm(y * jax.nn.silu(z), lp["norm_gate"]["scale"],
                                cfg.norm_eps)
            out = h + y @ lp["wo"].astype(y.dtype)
            k = cfg.conv_kernel
            cache = {
                "ssm_state": state,
                "conv_x": mamba2.conv_prefill_state(x_in, k, self.dtype),
                "conv_b": mamba2.conv_prefill_state(b_raw, k, self.dtype),
                "conv_c": mamba2.conv_prefill_state(c_raw, k, self.dtype),
            }
            return out, cache

        def outer(carry, group_params):
            def inner(c, lp):
                return mamba_with_state(lp, c)
            h, mcaches = maybe_scan(
                lambda c, lp: mamba_with_state(lp, c), carry, group_params,
                self.unroll_layers)
            h, (k, v) = _shared_block(params["shared"], h, cfg, positions,
                                      self.block_kv)
            return h, (mcaches, k, v)

        x, (mcaches, ks, vs) = maybe_scan(outer, x, grouped,
                                          self.unroll_layers)
        logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
        # mcaches leaves: (G, L/G, B, ...) -> (L, B, ...)
        mcaches = jax.tree_util.tree_map(
            lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:]),
            mcaches)
        pad = cache_len - s
        if pad:
            zeros = jnp.zeros(
                (ks.shape[0], ks.shape[1], pad) + ks.shape[3:], ks.dtype)
            ks = jnp.concatenate([ks, zeros], axis=2)
            vs = jnp.concatenate([vs, zeros], axis=2)
        cache = {"mamba": mcaches, "attn_k": ks.astype(self.dtype),
                 "attn_v": vs.astype(self.dtype),
                 "pos": jnp.asarray(s, jnp.int32)}
        return logits, cache

    def decode(self, params, cache, tokens):
        cfg = self.cfg
        params = cast(params, self.dtype)
        pos = cache["pos"]
        active = cache.get("active")
        page_table = cache.get("page_table")
        x, _ = embed_inputs(params, {"tokens": tokens}, cfg, self.dtype,
                            start_pos=pos)
        grouped = _group_tree(params["mamba_layers"], self.n_groups)
        gm = _group_tree(cache["mamba"], self.n_groups)

        def outer(carry, inp):
            x = carry
            group_params, group_mcache, ck, cv = inp

            def inner(c, lp_and_cache):
                lp, mc = lp_and_cache
                y, new_mc = mamba2.mamba2_decode_block(lp, c, mc, cfg)
                return y, new_mc

            x, new_mc = maybe_scan(inner, x, (group_params, group_mcache),
                                   self.unroll_layers)
            h = apply_norm(params["shared"]["ln1"], x, cfg.norm, cfg.norm_eps)
            a, ck, cv = attn_mod.decode_attention(
                params["shared"]["attn"], h, cfg, ck, cv, pos,
                active=active, page_table=page_table)
            x = x + a
            h = apply_norm(params["shared"]["ln2"], x, cfg.norm, cfg.norm_eps)
            x = x + mlp_apply(params["shared"]["mlp"], h, cfg.mlp)
            return x, (new_mc, ck, cv)

        x, (new_mamba, ks, vs) = maybe_scan(
            outer, x, (grouped, gm, cache["attn_k"], cache["attn_v"]),
            self.unroll_layers)
        new_mamba = jax.tree_util.tree_map(
            lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:]),
            new_mamba)
        logits = _logits(params, x, cfg)[:, 0]
        if page_table is not None:
            cap = page_table.shape[1] * cache["attn_k"].shape[2]
        else:
            cap = cache["attn_k"].shape[2]
        new_pos = advance_pos(pos, tokens.shape[1], active,
                              limit=cap if pos.ndim else None)
        out = {"mamba": new_mamba, "attn_k": ks, "attn_v": vs,
               "pos": new_pos}
        if active is not None:
            out["active"] = active
        if page_table is not None:
            out["page_table"] = page_table
        return logits, out
