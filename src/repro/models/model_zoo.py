"""Uniform model API: build_model / defs / input specs for every family."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import is_axes_leaf
from repro.models import layers as L
from repro.models.moe import MoELM, moe_defs
from repro.models.rwkv6 import RWKV6LM, rwkv6_defs
from repro.models.transformer import DenseLM, dense_defs
from repro.models.zamba2 import Zamba2LM, zamba2_defs


def model_defs(cfg: ModelConfig):
    if cfg.family == "dense":
        return dense_defs(cfg)
    if cfg.family == "moe":
        return moe_defs(cfg)
    if cfg.family == "rwkv":
        return rwkv6_defs(cfg)
    if cfg.family == "hybrid":
        return zamba2_defs(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def build_model(cfg: ModelConfig, dtype=jnp.bfloat16, remat: str = "full",
                block_kv: int = 512):
    cls = {"dense": DenseLM, "moe": MoELM, "rwkv": RWKV6LM,
           "hybrid": Zamba2LM}[cfg.family]
    return cls(cfg=cfg, dtype=dtype, remat=remat, block_kv=block_kv)


def init_params(rng: jax.Array, cfg: ModelConfig):
    return L.init_params(rng, model_defs(cfg))


def abstract_params(cfg: ModelConfig):
    return L.abstract_params(model_defs(cfg))


def param_axes(cfg: ModelConfig):
    return L.logical_axes(model_defs(cfg))


def param_count(cfg: ModelConfig) -> int:
    return L.param_count(model_defs(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE: routed experts count top_k/E)."""
    total = param_count(cfg)
    if cfg.family != "moe":
        return total
    e, k = cfg.n_experts, cfg.top_k
    routed = 3 * cfg.n_layers * cfg.d_model * cfg.d_ff * e
    return total - routed + routed * k // e


# ---------------------------------------------------------------------------
# decode-state construction — the slot-addressable serving cache
# (repro.serve.state wraps these behind the DecodeState protocol)
# ---------------------------------------------------------------------------

def decode_cache_axes(model) -> Any:
    """Logical-axes tree for the slot cache: scalar bookkeeping leaves
    (``pos``) are promoted to per-slot vectors, so every leaf carries the
    "batch" (slot) axis.  Includes the per-slot ``active`` occupancy leaf
    (see ``decode_cache_specs``)."""
    def one(ax):
        return ax if "batch" in ax else ("batch",) + ax
    axes = jax.tree_util.tree_map(one, model.cache_axes(),
                                  is_leaf=is_axes_leaf)
    axes["active"] = ("batch",)
    return axes


def decode_cache_specs(model, n_slots: int, cache_len: int) -> Any:
    """ShapeDtypeStruct tree for an ``n_slots``-wide decode cache.

    Uniform across backbones: transformer/MoE KV caches, Mamba-2/RWKV-6
    recurrent states and the Zamba-2 hybrid cache all come out with the
    batch dim sized to ``n_slots`` and the scalar ``pos`` leaf promoted to
    a per-slot (n_slots,) vector (each slot decodes at its own depth).
    A per-slot (n_slots,) bool ``active`` occupancy leaf rides along:
    models freeze ``pos`` (and drop cache writes) for inactive slots, so a
    free slot's state can never drift between an evict and the next insert.
    """
    shapes = model.cache_shapes(n_slots, cache_len)
    axes = model.cache_axes()

    def one(ax, sds):
        if "batch" in ax:
            return sds
        return jax.ShapeDtypeStruct((n_slots,) + sds.shape, sds.dtype)

    specs = jax.tree_util.tree_map(one, axes, shapes, is_leaf=is_axes_leaf)
    specs["active"] = jax.ShapeDtypeStruct((n_slots,), jnp.bool_)
    return specs


def init_decode_cache(model, n_slots: int, cache_len: int) -> Any:
    """Zero-initialized slot cache (see ``decode_cache_specs``)."""
    return jax.tree_util.tree_map(
        lambda sds: jnp.zeros(sds.shape, sds.dtype),
        decode_cache_specs(model, n_slots, cache_len))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs) per shape cell — the dry-run contract
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    i32 = jnp.int32
    if cfg.frontend == "audio_frames":
        return {
            "frames": jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model), dtype),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), i32),
        }
    if cfg.frontend == "vision_patches":
        p = cfg.prefix_tokens
        s_text = seq_len - p
        return {
            "patch_embeds": jax.ShapeDtypeStruct((batch, p, cfg.d_model), dtype),
            "tokens": jax.ShapeDtypeStruct((batch, s_text), i32),
            "labels": jax.ShapeDtypeStruct((batch, s_text), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), i32),
    }


def batch_logical_axes(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.frontend == "audio_frames":
        # "tokens" present for the decode path (codebook ids)
        return {"frames": ("batch", "seq", "embed"),
                "tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.frontend == "vision_patches":
        return {"patch_embeds": ("batch", None, "embed"),
                "tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    return {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}


def prefill_batch_specs(cfg: ModelConfig, batch: int, seq_len: int,
                        dtype=jnp.bfloat16):
    specs = train_batch_specs(cfg, batch, seq_len, dtype)
    specs.pop("labels")
    return specs


def decode_token_specs(batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, 1), jnp.int32)


def make_train_batch(rng, cfg: ModelConfig, batch: int, seq_len: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Random concrete batch matching train_batch_specs (smoke tests)."""
    r1, r2, r3 = jax.random.split(rng, 3)
    specs = train_batch_specs(cfg, batch, seq_len, dtype)
    out = {}
    for k, sds in specs.items():
        if sds.dtype == jnp.int32:
            out[k] = jax.random.randint(r1, sds.shape, 0, cfg.vocab_size,
                                        jnp.int32)
        else:
            out[k] = 0.02 * jax.random.normal(r2, sds.shape, jnp.float32)
            out[k] = out[k].astype(sds.dtype)
    return out
