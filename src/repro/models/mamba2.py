"""Mamba-2 (SSD) block — chunked state-space dual formulation.

The chunked scan (`ssd_reference`) is the pure-jnp oracle for the Pallas
kernel in ``repro/kernels/ssd``.  Everything runs inside a single
``lax.scan`` over chunks so the intra-chunk quadratic tensors stay
O(B*H*Q^2) regardless of sequence length — this is what makes the 500K-token
cells tractable.  ``ssd_mix`` dispatches between this oracle and the
differentiable Pallas kernel per ``ModelConfig.ssm_backend``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import resolve_backend
from repro.kernels.ssd.ops import ssd
from repro.models.layers import ParamDef, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core SSD math (oracle for kernels/ssd)
# ---------------------------------------------------------------------------

def ssd_reference(x: jax.Array, dt: jax.Array, a_coef: jax.Array,
                  b_in: jax.Array, c_in: jax.Array, chunk: int,
                  init_state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:      (B, S, H, P)   per-head inputs
    dt:     (B, S, H)      post-softplus step sizes
    a_coef: (H,)           negative per-head decay coefficients
    b_in:   (B, S, N)      input projections (single group, shared over heads)
    c_in:   (B, S, N)      output projections
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    b, s, h, p = x.shape
    n = b_in.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:  # pad with dt=0 steps (decay exp(0)=1, zero input: state-safe)
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        y, state = ssd_reference(x, dt, a_coef, b_in, c_in, chunk, init_state)
        return y[:, :s], state
    nc = s // chunk

    log_decay = dt * a_coef  # (B, S, H), <= 0
    x_dt = (x * dt[..., None]).astype(jnp.float32)

    def to_chunks(t, extra_dims):
        return t.reshape((b, nc, chunk) + extra_dims)

    lc = to_chunks(log_decay, (h,))  # (B, nc, Q, H)
    xc = to_chunks(x_dt, (h, p))
    bc = to_chunks(b_in.astype(jnp.float32), (n,))
    cc = to_chunks(c_in.astype(jnp.float32), (n,))

    state0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]  # (Q, Q)

    def step(state, inp):
        lq, xq, bq, cq = inp  # (B,Q,H), (B,Q,H,P), (B,Q,N), (B,Q,N)
        cum = jnp.cumsum(lq, axis=1)  # (B,Q,H) inclusive
        # intra-chunk: y_i += sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) xdt_j
        scores = jnp.einsum("bin,bjn->bij", cq, bq)  # (B,Q,Q)
        gap = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H) <=0 on causal
        decay = jnp.exp(jnp.where(causal[None, :, :, None], gap, NEG_INF))
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", scores, decay, xq)
        # contribution of the carried state
        y_inter = jnp.einsum("bin,bhnp,bih->bihp", cq, state,
                             jnp.exp(cum))
        # chunk state: S_c = sum_j exp(cum_last - cum_j) B_j x xdt_j
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H) <= 1
        new_state = (jnp.exp(cum[:, -1, :])[:, :, None, None] * state
                     + jnp.einsum("bjn,bjh,bjhp->bhnp", bq, decay_to_end, xq))
        return new_state, y_intra + y_inter

    xs = (jnp.moveaxis(lc, 1, 0), jnp.moveaxis(xc, 1, 0),
          jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
    final_state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_mix(xh: jax.Array, dt: jax.Array, a_coef: jax.Array,
            b_in: jax.Array, c_in: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """Backend dispatch for the SSD scan at the model layout.

    xh (B,S,H,P), dt (B,S,H), b_in/c_in (B,S,N); returns
    (y (B,S,H,P), final_state (B,H,N,P)).  ``cfg.ssm_backend`` selects the
    differentiable Pallas kernel ("kernel": compiled, TPU only, reference
    fallback elsewhere; "kernel_interpret": forced interpret mode for CPU
    validation) or the jnp oracle ("reference") — so both the train step
    and the serve prefill run the kernel fwd+bwd when opted in.
    """
    use_kernel, interpret = resolve_backend(cfg.ssm_backend, "ssm_backend")
    if use_kernel:
        y, state = ssd(xh.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                       a_coef, b_in, c_in, chunk=cfg.ssm_chunk,
                       interpret=interpret)
        return y.transpose(0, 2, 1, 3), state
    return ssd_reference(xh, dt, a_coef, b_in, c_in, cfg.ssm_chunk)


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    a_coef: jax.Array, b_in: jax.Array, c_in: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD update. state (B,H,N,P); x (B,H,P); dt (B,H);
    b_in/c_in (B,N)."""
    decay = jnp.exp(dt * a_coef)  # (B,H)
    x_dt = (x * dt[..., None]).astype(jnp.float32)
    state = (decay[..., None, None] * state
             + jnp.einsum("bn,bhp->bhnp", b_in.astype(jnp.float32), x_dt))
    y = jnp.einsum("bn,bhnp->bhp", c_in.astype(jnp.float32), state)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# conv helpers
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,D), w (K,D), bias (D)."""
    k = w.shape[0]
    pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    # windows: sum_k w[k] * x[t - K + 1 + k]
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
    return out + bias[None, None, :].astype(x.dtype)


def conv_decode_step(conv_state: jax.Array, x_t: jax.Array, w: jax.Array,
                     bias: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """conv_state (B, K-1, D); x_t (B, D). Returns (y_t, new_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,D)
    y = jnp.einsum("bkd,kd->bd", window, w.astype(x_t.dtype)) + bias.astype(x_t.dtype)
    return y, window[:, 1:, :]


def conv_prefill_state(x_raw: jax.Array, kernel: int, dtype) -> jax.Array:
    """Rolling conv window after a prefill of ``s`` tokens: the last K-1
    raw inputs, zero-left-padded when s < K-1 (zeros are exactly
    ``causal_conv1d``'s implicit history, so short-prompt prefill hands
    ``conv_decode_step`` the same state a token-by-token decode would)."""
    kk = kernel - 1
    b, s, d = x_raw.shape
    if s < kk:
        pad = jnp.zeros((b, kk - s, d), x_raw.dtype)
        x_raw = jnp.concatenate([pad, x_raw], axis=1)
    return x_raw[:, -kk:, :].astype(dtype)


# ---------------------------------------------------------------------------
# the Mamba-2 block
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_def(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_inner, h, p, n = mamba2_dims(cfg)
    k = cfg.conv_kernel
    s = 1.0 / math.sqrt(d)
    cl = 1.0 / math.sqrt(k)
    return {
        "norm_in": {"scale": ParamDef((d,), ("embed",), "ones")},
        "wz": ParamDef((d, d_inner), ("embed", "ssm_inner"), "normal", s),
        "wx": ParamDef((d, d_inner), ("embed", "ssm_inner"), "normal", s),
        "wb": ParamDef((d, n), ("embed", "state"), "normal", s),
        "wc": ParamDef((d, n), ("embed", "state"), "normal", s),
        "wdt": ParamDef((d, h), ("embed", "ssm_heads"), "normal", s),
        "conv_x": {"w": ParamDef((k, d_inner), ("conv", "ssm_inner"),
                                 "uniform_conv", cl),
                   "b": ParamDef((d_inner,), ("ssm_inner",), "zeros")},
        "conv_b": {"w": ParamDef((k, n), ("conv", "state"), "uniform_conv", cl),
                   "b": ParamDef((n,), ("state",), "zeros")},
        "conv_c": {"w": ParamDef((k, n), ("conv", "state"), "uniform_conv", cl),
                   "b": ParamDef((n,), ("state",), "zeros")},
        "a_log": ParamDef((h,), ("ssm_heads",), "zeros"),  # A = -exp(a_log)
        "d_skip": ParamDef((h,), ("ssm_heads",), "ones"),
        "dt_bias": ParamDef((h,), ("ssm_heads",), "zeros"),
        "norm_gate": {"scale": ParamDef((d_inner,), ("ssm_inner",), "ones")},
        "wo": ParamDef((d_inner, d), ("ssm_inner", "embed"), "normal",
                       1.0 / math.sqrt(d_inner)),
    }


def _proj_inputs(lp, h_in, cfg: ModelConfig):
    d_inner, h, p, n = mamba2_dims(cfg)
    dt_raw = h_in @ lp["wdt"].astype(h_in.dtype)  # (B,S,H)
    z = h_in @ lp["wz"].astype(h_in.dtype)
    x_in = h_in @ lp["wx"].astype(h_in.dtype)
    b_raw = h_in @ lp["wb"].astype(h_in.dtype)
    c_raw = h_in @ lp["wc"].astype(h_in.dtype)
    return z, x_in, b_raw, c_raw, dt_raw


def mamba2_block(lp: Dict[str, Any], x: jax.Array, cfg: ModelConfig
                 ) -> jax.Array:
    """Full-sequence Mamba-2 mixing block (pre-norm residual inside)."""
    d_inner, h, p, n = mamba2_dims(cfg)
    b, s, _ = x.shape
    h_in = rms_norm(x, lp["norm_in"]["scale"], cfg.norm_eps)
    z, x_in, b_raw, c_raw, dt_raw = _proj_inputs(lp, h_in, cfg)
    x_conv = jax.nn.silu(causal_conv1d(x_in, lp["conv_x"]["w"],
                                       lp["conv_x"]["b"]))
    b_conv = jax.nn.silu(causal_conv1d(b_raw, lp["conv_b"]["w"],
                                       lp["conv_b"]["b"]))
    c_conv = jax.nn.silu(causal_conv1d(c_raw, lp["conv_c"]["w"],
                                       lp["conv_c"]["b"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))
    a_coef = -jnp.exp(lp["a_log"].astype(jnp.float32))
    xh = x_conv.reshape(b, s, h, p)
    y, _state = ssd_mix(xh, dt, a_coef, b_conv, c_conv, cfg)
    y = y + lp["d_skip"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), lp["norm_gate"]["scale"], cfg.norm_eps)
    out = y @ lp["wo"].astype(y.dtype)
    return x + out


def mamba2_cache_shapes(cfg: ModelConfig, n_layers: int, batch: int, dtype):
    d_inner, h, p, n = mamba2_dims(cfg)
    k = cfg.conv_kernel
    f32 = jnp.float32
    return {
        "ssm_state": jax.ShapeDtypeStruct((n_layers, batch, h, n, p), f32),
        "conv_x": jax.ShapeDtypeStruct((n_layers, batch, k - 1, d_inner), dtype),
        "conv_b": jax.ShapeDtypeStruct((n_layers, batch, k - 1, n), dtype),
        "conv_c": jax.ShapeDtypeStruct((n_layers, batch, k - 1, n), dtype),
    }


def mamba2_cache_axes():
    return {
        "ssm_state": ("layers", "batch", "ssm_heads", "state", None),
        "conv_x": ("layers", "batch", None, "ssm_inner"),
        "conv_b": ("layers", "batch", None, "state"),
        "conv_c": ("layers", "batch", None, "state"),
    }


def mamba2_decode_block(lp, x: jax.Array, cache: Dict[str, jax.Array],
                        cfg: ModelConfig
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode. x (B, 1, D); cache leaves without the layer dim."""
    d_inner, h, p, n = mamba2_dims(cfg)
    b = x.shape[0]
    h_in = rms_norm(x[:, 0, :], lp["norm_in"]["scale"], cfg.norm_eps)
    z, x_in, b_raw, c_raw, dt_raw = _proj_inputs(lp, h_in, cfg)
    x_c, conv_x = conv_decode_step(cache["conv_x"], x_in,
                                   lp["conv_x"]["w"], lp["conv_x"]["b"])
    b_c, conv_b = conv_decode_step(cache["conv_b"], b_raw,
                                   lp["conv_b"]["w"], lp["conv_b"]["b"])
    c_c, conv_c = conv_decode_step(cache["conv_c"], c_raw,
                                   lp["conv_c"]["w"], lp["conv_c"]["b"])
    x_c, b_c, c_c = (jax.nn.silu(t) for t in (x_c, b_c, c_c))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))
    a_coef = -jnp.exp(lp["a_log"].astype(jnp.float32))
    xh = x_c.reshape(b, h, p)
    y, state = ssd_decode_step(cache["ssm_state"], xh, dt, a_coef, b_c, c_c)
    y = y + lp["d_skip"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(b, d_inner)
    y = rms_norm(y * jax.nn.silu(z), lp["norm_gate"]["scale"], cfg.norm_eps)
    out = (y @ lp["wo"].astype(y.dtype))[:, None, :]
    new_cache = {"ssm_state": state, "conv_x": conv_x, "conv_b": conv_b,
                 "conv_c": conv_c}
    return x + out, new_cache
