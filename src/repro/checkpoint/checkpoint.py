"""Mesh-agnostic pytree checkpoints: per-leaf .npy + JSON manifest.

Design goals (the fault-tolerance contract):

* **atomic** — written to ``<dir>/tmp.<step>``, fsynced, then renamed to
  ``<dir>/step_<step>``; a crash mid-write never corrupts the latest
  checkpoint.
* **mesh-agnostic** — leaves are stored as full logical arrays; restore
  applies whatever shardings the *new* mesh wants (elastic restart with a
  different device count is just a different `shardings` tree at load).
  At fleet scale the same manifest format extends to per-shard files keyed
  by (leaf, shard-index); single-process here, so leaves are whole.
* **self-validating** — the manifest records shape/dtype *and a crc32
  content checksum* per leaf plus a payload count; `latest_step` skips
  incomplete directories and `restore` raises
  :class:`CheckpointCorruption` on any shape/dtype/checksum mismatch or
  unreadable payload.  ``CheckpointManager.restore_latest`` turns that into
  automatic fallback: the corrupt directory is quarantined (renamed
  ``corrupt.step_*`` so no future restart trusts it, but the payload stays
  on disk for postmortems) and the previous ``step_*`` directory is tried.
* **host state included** — curriculum state, loss-ratio tracker, data
  cursor, token counters ride along in the manifest's ``host`` dict, so a
  restart resumes the SLW schedule exactly.
* **fault-injectable** — the two rename-boundary crash points call into
  ``repro.distributed.fault_injection`` (no-ops unless a test/chaos run
  armed an injector), so crash-mid-checkpoint is a tested path, not an
  assumed one.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.distributed.fault_injection import checkpoint_crash_point


class CheckpointCorruption(ValueError):
    """A checkpoint directory failed validation (missing/unreadable payload,
    shape/dtype mismatch, or content-checksum mismatch)."""


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree: Any,
         host_state: Optional[Dict] = None) -> str:
    """Atomically write checkpoint `step`. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:012d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves), "leaves": {},
                "host": host_state or {}}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes())}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    checkpoint_crash_point("post_tmp", step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    checkpoint_crash_point("post_rename", step)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        path = os.path.join(directory, name, "manifest.json")
        if not os.path.exists(path):
            continue  # incomplete
        step = int(m.group(1))
        best = step if best is None else max(best, step)
    return best


def available_steps(directory: str) -> List[int]:
    """Steps with a complete-looking checkpoint directory, newest first."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out, reverse=True)


def quarantine(directory: str, step: int) -> str:
    """Rename a corrupt ``step_*`` directory to ``corrupt.step_*`` so no
    future restart trusts it (payload kept on disk for postmortems).
    Returns the quarantine path."""
    src = os.path.join(directory, f"step_{step:012d}")
    dst = os.path.join(directory, f"corrupt.step_{step:012d}")
    if os.path.exists(dst):
        shutil.rmtree(dst)
    os.rename(src, dst)
    return dst


def _legacy_opt_alias(key: str) -> Optional[str]:
    """Map a chain-format optimizer leaf key to its legacy monolithic
    location: pre-chain checkpoints stored the AdamW state flat under
    ``opt/`` (``opt/m/...``, ``opt/v/...``, ``opt/count``); the composable
    chain nests it under the ``adam`` transform slot.  Restoring an old
    checkpoint into a new trainer is therefore a key rename, not a copy."""
    m = re.fullmatch(r"opt/(?:shampoo/)?adam/((?:m|v)(?:/.*)?|count)", key)
    return f"opt/{m.group(1)}" if m else None


def restore(directory: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`, if given (same structure), device_puts
    each leaf with the *new* sharding — elastic re-mesh happens here.

    Every payload is validated against the manifest (shape, dtype, crc32
    content checksum when present — pre-hardening manifests lack it and
    still restore); any mismatch raises :class:`CheckpointCorruption`.
    Legacy ``{"m","v","count"}`` optimizer payloads are transparently
    migrated into the chain format via :func:`_legacy_opt_alias`.
    """
    path = os.path.join(directory, f"step_{step:012d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruption(f"unreadable manifest in {path}: {e}")
    keys = {}
    for k, _ in _flatten(like):
        if k in manifest["leaves"]:
            keys[k] = k
            continue
        alias = _legacy_opt_alias(k)
        if alias is not None and alias in manifest["leaves"]:
            keys[k] = alias
        else:
            keys[k] = k  # reported missing below
    missing = [k for k, src in keys.items() if src not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]} ...")
    arrays = {}
    for key, src in keys.items():
        meta = manifest["leaves"][src]
        fpath = os.path.join(path, meta["file"])
        try:
            arr = np.load(fpath)
        except Exception as e:  # noqa: BLE001 — any load failure = corrupt
            raise CheckpointCorruption(f"unreadable payload {fpath}: {e}")
        if list(arr.shape) != list(meta["shape"]) \
                or str(arr.dtype) != meta["dtype"]:
            raise CheckpointCorruption(
                f"{fpath}: shape/dtype {arr.shape}/{arr.dtype} != manifest "
                f"{tuple(meta['shape'])}/{meta['dtype']}")
        if "crc32" in meta:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise CheckpointCorruption(
                    f"{fpath}: crc32 {crc:#010x} != manifest "
                    f"{meta['crc32']:#010x}")
        arrays[key] = arr
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat_like))
    out = []
    for (key, _), sh in zip(_flatten(like), flat_sh):
        arr = arrays[key]
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["host"]


def migrate_host_state(host: Dict) -> Dict:
    """Upgrade a legacy host dict to the unified controller format.

    Pre-regulator checkpoints carried per-object payloads
    (``{"curriculum": ..., "tracker": ...}``); the control plane now
    checkpoints one ``controller`` dict (see core.regulators.ControllerState).
    Legacy curriculum state maps onto the ``seqlen`` regulator's slot.
    A host dict carrying a legacy monolithic ``{"m","v","count"}`` opt
    state (in-memory snapshots, ring payloads) is upgraded into the chain
    format (``{"adam": {...}, ...}``); the on-disk equivalent happens
    leaf-wise in :func:`restore` via :func:`_legacy_opt_alias`.
    """
    if isinstance(host.get("opt"), dict):
        from repro.optim.transforms import migrate_opt_state
        new_opt = migrate_opt_state(host["opt"])
        if new_opt is not host["opt"]:
            host = dict(host)
            host["opt"] = new_opt
    if "controller" in host:  # already new-format: pass through untouched
        return host
    out = dict(host)
    regs = {}
    if "curriculum" in host:
        regs["seqlen"] = host["curriculum"]
    out["controller"] = {
        "step": host.get("step", 0),
        "tokens_seen": host.get("tokens_seen", 0),
        "regulators": regs,
        "tracker": host.get("tracker", {}),
    }
    return out


class CheckpointManager:
    """keep-N garbage collection + convenience wrappers."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        # (step, quarantine path, reason) for every corrupt dir sidelined
        self.quarantined: List[Tuple[int, str, str]] = []

    def save(self, step: int, tree: Any, host_state: Optional[Dict] = None):
        path = save(self.directory, step, tree, host_state)
        self._gc()
        return path

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore_latest(self, like: Any, shardings: Optional[Any] = None):
        """Restore the newest checkpoint that passes validation.

        A corrupt newest checkpoint (bitflip, torn write) is quarantined —
        renamed ``corrupt.step_*``, payload kept for postmortems — and the
        previous ``step_*`` directory is tried, until one validates or none
        are left (then the None-tuple, same as an empty directory: the
        caller cold-starts)."""
        for step in available_steps(self.directory):
            try:
                tree, host = restore(self.directory, step, like, shardings)
                return step, tree, host
            except CheckpointCorruption as e:
                self.quarantined.append(
                    (step, quarantine(self.directory, step), str(e)))
        return None, None, None

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n)
             for n in os.listdir(self.directory)) if m)
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:012d}"),
                          ignore_errors=True)
