from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    latest_step,
    migrate_host_state,
    restore,
    save,
)
