from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointCorruption,
    CheckpointManager,
    available_steps,
    latest_step,
    migrate_host_state,
    quarantine,
    restore,
    save,
)
