"""Logical-axis sharding: rules map logical axis names -> mesh axes.

Params/activations/caches carry *logical* axis names (declared in the model
ParamDef trees).  A rule set translates them to PartitionSpecs for whatever
mesh is active — so moving from the single-pod (16,16) mesh to the multi-pod
(2,16,16) mesh, or to an elastic restart with a different device count, is a
rules/mesh change, not a model change.

Two built-in rule sets:

* ``baseline`` — paper-era Megatron-style DP+TP: params replicated over the
  data axis, TP over ``model`` (vocab/heads/mlp/experts).
* ``fsdp`` — optimized: baseline + params/optimizer sharded over ``data``
  (ZeRO-3-style), which is what makes the 32B-scale cells fit.

Conflict/divisibility fallback: if a logical axis maps to a mesh axis already
used by an earlier dim of the same tensor, or the dim size is not divisible by
the mesh axis size, that dim stays unsharded (recorded via `fallbacks`).
This is what keeps e.g. smollm's 15 attention heads correct on a 16-way model
axis (replicated attention weights, sharded everything else).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...]]

# logical axis -> mesh axis (or tuple of mesh axes) templates; axes absent
# from the active mesh are dropped at resolution time.
PARAM_RULES: Dict[str, Dict[str, MeshAxes]] = {
    "baseline": {
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "experts": "model",
        "ssm_heads": "model",
        "ssm_inner": "model",
        "rwkv_heads": "model",
        "rwkv_inner": "model",
    },
    "fsdp": {
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "experts": "model",
        "ssm_heads": "model",
        "ssm_inner": "model",
        "rwkv_heads": "model",
        "rwkv_inner": "model",
        "embed": "data",  # ZeRO-3-style: weights sharded over the data axis
        "pos": "data",
    },
    # fsdp_pure: weights *stored* sharded over both axes (same as fsdp) but
    # compute is pure data parallelism — the batch spreads over every mesh
    # axis and layers run with gathered weights.  Trades per-layer weight
    # all-gathers (small) for the removal of per-layer activation psums
    # (large at big batch*seq) — §Perf lever for large dense training.
    "fsdp_pure": {
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "experts": "model",
        "ssm_heads": "model",
        "ssm_inner": "model",
        "rwkv_heads": "model",
        "rwkv_inner": "model",
        "embed": "data",
        "pos": "data",
    },
    # serve_tp: inference layout — params replicated over `data` and
    # TP-sharded over `model` only (no per-step FSDP weight gathers, the
    # decode-path §Perf lever); the KV cache seq axis carries the memory.
    "serve_tp": {
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "experts": "model",
        "ssm_heads": "model",
        "ssm_inner": "model",
        "rwkv_heads": "model",
        "rwkv_inner": "model",
    },
}

ACT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "mlp": "model",
    "vocab": "model",
    "ssm_heads": "model",
    "ssm_inner": "model",
    "rwkv_heads": "model",
    "rwkv_inner": "model",
}

# fsdp_pure: batch over the whole mesh; no activation TP entries (weights
# are gathered per layer instead)
ACT_RULES_PURE: Dict[str, MeshAxes] = {
    "batch": ("pod", "data", "model"),
    "vocab": "model",
}

# long-context decode: KV caches additionally sharded along the sequence axis
SEQ_SHARDED_CACHE_RULE = {"seq": "data"}


@dataclass
class ShardingRules:
    mesh: Mesh
    param_rules: Dict[str, MeshAxes]
    act_rules: Dict[str, MeshAxes]
    fallbacks: List[str] = field(default_factory=list)

    @classmethod
    def make(cls, mesh: Mesh, rule_set: str = "fsdp",
             seq_sharded_cache: bool = False,
             seq_shard_axis: str = "data") -> "ShardingRules":
        act = dict(ACT_RULES_PURE if rule_set == "fsdp_pure" else ACT_RULES)
        if seq_sharded_cache:
            act["seq"] = seq_shard_axis
        return cls(mesh=mesh, param_rules=dict(PARAM_RULES[rule_set]),
                   act_rules=act)

    # -- resolution --------------------------------------------------------
    def _resolve(self, rules: Dict[str, MeshAxes], axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]], what: str) -> P:
        mesh_axes = set(self.mesh.axis_names)
        used: set = set()
        out: List[Optional[MeshAxes]] = []
        for i, name in enumerate(axes):
            target = rules.get(name) if name else None
            if target is None:
                out.append(None)
                continue
            cand = tuple(a for a in (
                (target,) if isinstance(target, str) else target)
                if a in mesh_axes and a not in used)
            if not cand:
                out.append(None)
                continue
            if shape is not None:
                size = int(np.prod([self.mesh.shape[a] for a in cand]))
                if shape[i] % size != 0:
                    # divisibility fallback: try prefix subsets
                    while cand and shape[i] % int(
                            np.prod([self.mesh.shape[a] for a in cand])) != 0:
                        cand = cand[:-1]
                    if not cand:
                        self.fallbacks.append(
                            f"{what}: dim {i} ({name}={shape[i]}) replicated")
                        out.append(None)
                        continue
            used.update(cand)
            out.append(cand[0] if len(cand) == 1 else cand)
        return P(*out)

    def param_spec(self, axes, shape=None) -> P:
        return self._resolve(self.param_rules, axes, shape, "param")

    def act_spec(self, axes, shape=None) -> P:
        return self._resolve(self.act_rules, axes, shape, "act")

    def param_sharding(self, axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_spec(axes, shape))

    def act_sharding(self, axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.act_spec(axes, shape))


def is_axes_leaf(x: Any) -> bool:
    """A logical-axes tuple leaf in an axes tree (e.g. ("batch", "seq"))."""
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)


def _tree_shardings(method, axes_tree: Any, shape_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda axes, sds: method(axes, sds.shape), axes_tree, shape_tree,
        is_leaf=is_axes_leaf)


def tree_param_shardings(rules: ShardingRules, axes_tree: Any,
                         shape_tree: Any) -> Any:
    """NamedSharding tree from a logical-axes tree + ShapeDtypeStruct tree."""
    return _tree_shardings(rules.param_sharding, axes_tree, shape_tree)


def tree_act_shardings(rules: ShardingRules, axes_tree: Any,
                       shape_tree: Any) -> Any:
    """NamedSharding tree under the *activation* rules.

    Used for stateful activation trees such as the serve decode cache
    (``model_zoo.decode_cache_axes``): the slot axis is the cache's "batch"
    logical axis, so under ``serve_tp`` rules slots spread over the data
    mesh axis while heads/states stay TP-sharded — one spec tree drives
    jit donation placement for the whole engine state.
    """
    return _tree_shardings(rules.act_sharding, axes_tree, shape_tree)


# ---------------------------------------------------------------------------
# activation constraints inside model code (no-op when no rules active)
# ---------------------------------------------------------------------------

_ACTIVE = threading.local()


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_ACTIVE, "rules", None)
    _ACTIVE.rules = rules
    try:
        yield
    finally:
        _ACTIVE.rules = prev


def active_rules() -> Optional[ShardingRules]:
    return getattr(_ACTIVE, "rules", None)


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical axes; identity if no rules active."""
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.act_spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
