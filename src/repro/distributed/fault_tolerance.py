"""Fault tolerance: restart supervision, drain-on-signal, straggler watchdog.

What runs here (single process) and what it maps to at fleet scale:

* ``TrainSupervisor`` — wraps the step loop; on an exception it restores the
  last valid checkpoint and replays.  At fleet scale the same retry loop runs
  under a cluster scheduler; the checkpoint manager's atomic rename + keep-N
  semantics are what make blind restarts safe.
* drain — SIGTERM/SIGINT set a flag; the loop checkpoints at the next step
  boundary and exits 0 (preemption-safe).  This is the TPU-maintenance-event
  path.
* ``StepWatchdog`` — per-step wall-time ring buffer; flags a straggler when
  the trailing step exceeds ``factor`` x the rolling median.  In a
  multi-host deployment the flag feeds the coordinator's evict/replace
  decision; here it is surfaced in metrics and tested directly.
* elasticity — restarts may change dp_size/mesh: checkpoints are
  mesh-agnostic (see repro.checkpoint) and the data pipeline is pure index
  arithmetic, so re-partitioning is automatic.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


class DrainSignal:
    """Latches SIGTERM/SIGINT; the train loop polls `should_drain`."""

    def __init__(self, install: bool = True):
        self._flag = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:  # not in main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def should_drain(self) -> bool:
        return self._flag

    def trigger(self) -> None:  # for tests
        self._flag = True


@dataclass
class StepWatchdog:
    window: int = 64
    factor: float = 3.0
    durations: List[float] = field(default_factory=list)
    straggler_steps: List[int] = field(default_factory=list)
    _t0: Optional[float] = None
    _step: int = 0

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record the step; returns True if it was a straggler."""
        dt = time.monotonic() - self._t0
        self.durations.append(dt)
        self.durations = self.durations[-self.window:]
        self._step += 1
        if len(self.durations) >= 8:
            med = float(np.median(self.durations[:-1]))
            if dt > self.factor * med:
                self.straggler_steps.append(self._step)
                return True
        return False

    def summary(self) -> Dict[str, float]:
        d = np.asarray(self.durations or [0.0])
        return {"step_time_p50": float(np.median(d)),
                "step_time_p95": float(np.percentile(d, 95)),
                "stragglers": len(self.straggler_steps)}


@dataclass
class TrainSupervisor:
    """Retry loop around a (resumable) train function.

    `run_fn(resume: bool) -> str` must itself restore from the latest
    checkpoint when `resume` is True and return a status string.
    """
    max_restarts: int = 3
    backoff_s: float = 0.0
    restarts: int = 0
    failures: List[str] = field(default_factory=list)

    def run(self, run_fn: Callable[[bool], str]) -> str:
        resume = False
        while True:
            try:
                return run_fn(resume)
            except Exception as e:  # noqa: BLE001 — supervisor boundary
                self.restarts += 1
                self.failures.append(f"{type(e).__name__}: {e}")
                if self.restarts > self.max_restarts:
                    raise
                if self.backoff_s:
                    time.sleep(self.backoff_s)
                resume = True
