"""Fault tolerance: restart supervision, drain-on-signal, straggler watchdog.

What runs here (single process) and what it maps to at fleet scale:

* ``TrainSupervisor`` — wraps the step loop; on an exception it restores the
  last valid checkpoint and replays.  At fleet scale the same retry loop runs
  under a cluster scheduler; the checkpoint manager's atomic rename + keep-N
  semantics are what make blind restarts safe.
* drain — SIGTERM/SIGINT set a flag; the loop checkpoints at the next step
  boundary and exits 0 (preemption-safe).  This is the TPU-maintenance-event
  path.
* ``StepWatchdog`` — per-step wall-time ring buffer; flags a straggler when
  the trailing step exceeds ``factor`` x the rolling median.  In a
  multi-host deployment the flag feeds the coordinator's evict/replace
  decision; here it is surfaced in metrics and tested directly.
* elasticity — restarts may change dp_size/mesh: checkpoints are
  mesh-agnostic (see repro.checkpoint) and the data pipeline is pure index
  arithmetic, so re-partitioning is automatic.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np


class DrainSignal:
    """Latches SIGTERM/SIGINT; the train loop polls `should_drain`."""

    def __init__(self, install: bool = True):
        self._flag = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:  # not in main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def should_drain(self) -> bool:
        return self._flag

    def trigger(self) -> None:  # for tests
        self._flag = True

    def uninstall(self) -> None:
        """Restore the handlers that were active before installation.

        Without this the latched handler leaks across Trainer instances and
        tests (the next DrainSignal would record *our* stale handler as the
        previous one).  Idempotent; the Trainer calls it at teardown via the
        drain hook's ``close``.
        """
        for sig, prev in self._prev.items():
            try:
                # == not `is`: each _handler attribute access builds a fresh
                # bound method, so identity never matches the stored one
                if signal.getsignal(sig) == self._handler:
                    signal.signal(sig, prev)
            except ValueError:  # not in main thread
                pass
        self._prev = {}


@dataclass
class StepWatchdog:
    window: int = 64
    factor: float = 3.0
    durations: List[float] = field(default_factory=list)
    straggler_steps: List[int] = field(default_factory=list)
    _t0: Optional[float] = None
    _step: int = 0

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record the step; returns True if it was a straggler.

        ``stop`` without a matching ``start`` records nothing — a hook
        order that skips ``start`` (drain/early-stop paths) used to crash
        on ``self._t0`` being None.
        """
        if self._t0 is None:
            return False
        dt = time.monotonic() - self._t0
        self._t0 = None
        self.durations.append(dt)
        self.durations = self.durations[-self.window:]
        self._step += 1
        if len(self.durations) >= 8:
            med = float(np.median(self.durations[:-1]))
            if dt > self.factor * med:
                self.straggler_steps.append(self._step)
                return True
        return False

    def summary(self) -> Dict[str, float]:
        d = np.asarray(self.durations or [0.0])
        return {"step_time_p50": float(np.median(d)),
                "step_time_p95": float(np.percentile(d, 95)),
                "stragglers": len(self.straggler_steps)}


@dataclass(frozen=True)
class RetryPolicy:
    """How many times to retry and how long to wait — the one policy shared
    by process-level restarts (:class:`TrainSupervisor`) and in-process
    rollbacks (:class:`repro.core.recovery.RollbackController`), so the two
    containment layers are budgeted together rather than multiplying."""

    max_retries: int = 3
    backoff_s: float = 0.0       # base sleep before retry `1` (0 = none)
    backoff_factor: float = 2.0  # exponential growth per further retry
    backoff_cap_s: float = 60.0  # ceiling on any single sleep

    def delay(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (1-based)."""
        if self.backoff_s <= 0.0:
            return 0.0
        return min(self.backoff_s * self.backoff_factor ** (attempt - 1),
                   self.backoff_cap_s)


@dataclass
class TrainSupervisor:
    """Retry loop around a (resumable) train function.

    `run_fn(resume: bool) -> str` must itself restore from the latest
    checkpoint when `resume` is True and return a status string.

    Retries back off exponentially (``policy``; the legacy
    ``max_restarts``/``backoff_s`` fields seed a default policy), and every
    failure is recorded with its wall-clock timestamp and attempt number in
    ``failures`` for postmortems.
    """
    max_restarts: int = 3
    backoff_s: float = 0.0
    restarts: int = 0
    failures: List[Dict] = field(default_factory=list)
    policy: Optional[RetryPolicy] = None

    def run(self, run_fn: Callable[[bool], str]) -> str:
        policy = self.policy or RetryPolicy(max_retries=self.max_restarts,
                                            backoff_s=self.backoff_s)
        resume = False
        while True:
            try:
                return run_fn(resume)
            except Exception as e:  # noqa: BLE001 — supervisor boundary
                self.restarts += 1
                self.failures.append({
                    "error": f"{type(e).__name__}: {e}",
                    "time": time.time(),
                    "attempt": self.restarts,
                })
                if self.restarts > policy.max_retries:
                    raise
                delay = policy.delay(self.restarts)
                if delay:
                    time.sleep(delay)
                resume = True
