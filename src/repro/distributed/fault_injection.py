"""Deterministic, seedable fault injection for the recovery paths.

Every recovery mechanism in this repo (divergence rollback, checkpoint
quarantine, supervisor restart, watchdog stragglers) is exercised by
*injected* faults rather than assumed to work: the :class:`FaultInjector`
holds a step-indexed list of :class:`FaultSpec` entries and fires each one
exactly once, with all randomness (which parameter leaf to poison, which
byte to flip) derived from ``seed`` + the fault's step — two runs with the
same spec corrupt the same element.

Fault kinds (CLI syntax ``kind@step[:arg]``, comma-separated):

* ``nan_grad@12``       — poison one parameter element with NaN before step
                          12; the forward/backward then produce NaN loss and
                          gradients (the paper's terminal divergence).
* ``spike@20:8.0``      — scale all parameters by ``arg`` (default 8.0)
                          before step 20: a finite loss explosion, the
                          loss-ratio spike precursor.
* ``stall@8:0.25``      — sleep ``arg`` seconds before step 8 (straggler;
                          feeds the StepWatchdog).
* ``grad_spike@15:64|attn`` — scale the raw gradients of every param leaf
                          whose label contains ``attn`` by 64 for step 15
                          only (``factor|leaf_substr``; substring empty or
                          omitted = one deterministically-chosen leaf).
                          Unlike ``spike`` this targets *one block's
                          gradients*, so per-leaf telemetry must name the
                          poisoned group — the per-layer-blame drill.
* ``crash@30:post_tmp`` — raise :class:`InjectedCrash` from inside the
                          checkpoint writer at step 30, at the named crash
                          point: ``post_tmp`` (payload + manifest written,
                          **before** the atomic rename — the classic
                          partial-checkpoint crash) or ``post_rename``
                          (after the rename; the checkpoint is valid but
                          the process dies before reporting).

Checkpoint-payload corruption is not step-indexed — it is a storage fault,
injected directly with :meth:`FaultInjector.corrupt_checkpoint` (flip one
deterministic byte in one payload file of a written checkpoint).

Wiring: ``FaultInjectionHook`` mutates the trainer at ``on_step_start``
(duck-typed TrainerHook — no import cycle with ``launch.train``); the crash
points require module-level arming (:func:`arm` / :func:`disarm`) because
the checkpoint writer has no injector handle — ``repro.checkpoint`` calls
:func:`checkpoint_crash_point` at its two rename-boundary sites, a no-op
unless a spec armed here matches.
"""
from __future__ import annotations

import os
import re
import time
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

KINDS = ("nan_grad", "spike", "grad_spike", "stall", "crash")
CRASH_POINTS = ("post_tmp", "post_rename")


class InjectedCrash(RuntimeError):
    """A deliberate, test-only process death (caught by supervisors)."""


@dataclass(frozen=True)
class FaultSpec:
    kind: str   # nan_grad | spike | stall | crash
    step: int
    arg: str = ""

    def __str__(self) -> str:
        return f"{self.kind}@{self.step}" + (f":{self.arg}" if self.arg
                                             else "")


def parse_faults(spec: str) -> Tuple[FaultSpec, ...]:
    """Parse the CLI syntax: ``"nan_grad@12,spike@20:8.0,crash@30:post_tmp"``.

    Raises ValueError on unknown kinds, malformed entries, or a crash point
    that the checkpoint writer does not define.
    """
    out: List[FaultSpec] = []
    for entry in filter(None, (e.strip() for e in spec.split(","))):
        m = re.fullmatch(r"([a-z_]+)@(\d+)(?::([^,]+))?", entry)
        if not m:
            raise ValueError(f"malformed fault spec {entry!r} "
                             f"(want kind@step[:arg])")
        kind, step, arg = m.group(1), int(m.group(2)), m.group(3) or ""
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r} (one of {KINDS})")
        if kind == "crash" and arg and arg not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {arg!r} "
                             f"(one of {CRASH_POINTS})")
        out.append(FaultSpec(kind, step, arg))
    return tuple(out)


class FaultInjector:
    """Fires each spec exactly once, deterministically.

    Fire-once matters for recovery testing: after a rollback the trainer
    re-executes the faulted step index, and a fault that re-fired forever
    would make every recovery test a guaranteed failure — transient faults
    are the model here (persistent ones are what the retry *budget* is
    for).
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.specs = tuple(specs)
        self.seed = seed
        self.fired: List[str] = []
        self._done = set()

    @classmethod
    def from_cli(cls, spec: str, seed: int = 0) -> "FaultInjector":
        return cls(parse_faults(spec), seed=seed)

    def _rng(self, spec: FaultSpec) -> np.random.RandomState:
        return np.random.RandomState((self.seed * 1_000_003 + spec.step)
                                     % (2 ** 31 - 1))

    def _take(self, kind: str, step: int) -> Optional[FaultSpec]:
        for i, s in enumerate(self.specs):
            if i not in self._done and s.kind == kind and s.step == step:
                self._done.add(i)
                self.fired.append(str(s))
                return s
        return None

    # -- step-indexed faults (trainer pre-step) ------------------------------
    def pre_step(self, trainer) -> None:
        """Apply any fault scheduled for ``trainer.step`` (mutates
        ``trainer.state`` in place for the parameter faults)."""
        step = trainer.step
        s = self._take("stall", step)
        if s is not None:
            time.sleep(float(s.arg or 0.25))
        s = self._take("nan_grad", step)
        if s is not None:
            trainer.state = self.poison_params(trainer.state, step)
        s = self._take("spike", step)
        if s is not None:
            trainer.state = self.scale_params(trainer.state, step,
                                              float(s.arg or 8.0))
        s = self._take("grad_spike", step)
        if s is not None:
            factor, _, substr = (s.arg or "64").partition("|")
            trainer._pending_grad_fault = (float(factor or 64.0), substr)

    def grad_scale_vector(self, labels: Sequence[str], step: int,
                          factor: float, substr: str) -> np.ndarray:
        """(n_leaves,) multiplier vector for a ``grad_spike``: ``factor`` on
        every leaf whose label contains ``substr`` (one deterministically-
        chosen leaf when the substring is empty or matches nothing)."""
        scale = np.ones(len(labels), np.float32)
        hit = [i for i, lb in enumerate(labels) if substr and substr in lb]
        if not hit:
            rng = self._rng(FaultSpec("grad_spike", step))
            hit = [rng.randint(len(labels))]
        scale[hit] = factor
        return scale

    def poison_params(self, state: Any, step: int) -> Any:
        """NaN one deterministically-chosen parameter element."""
        rng = self._rng(FaultSpec("nan_grad", step))
        leaves, treedef = jax.tree_util.tree_flatten(state["params"])
        float_idx = [i for i, x in enumerate(leaves)
                     if np.issubdtype(np.asarray(x).dtype, np.floating)]
        pick = float_idx[rng.randint(len(float_idx))]
        arr = np.array(jax.device_get(leaves[pick]))
        arr.flat[rng.randint(arr.size)] = np.nan
        leaves[pick] = jnp.asarray(arr)
        out = dict(state)
        out["params"] = jax.tree_util.tree_unflatten(treedef, leaves)
        return out

    def scale_params(self, state: Any, step: int, factor: float) -> Any:
        """Multiply every parameter by ``factor`` (finite loss explosion)."""
        out = dict(state)
        out["params"] = jax.tree_util.tree_map(
            lambda x: x * np.asarray(factor, np.asarray(x).dtype),
            state["params"])
        return out

    # -- checkpoint crash points ---------------------------------------------
    def maybe_crash(self, point: str, step: int) -> None:
        for i, s in enumerate(self.specs):
            if i in self._done or s.kind != "crash" or s.step != step:
                continue
            if (s.arg or "post_tmp") == point:
                self._done.add(i)
                self.fired.append(str(s))
                raise InjectedCrash(f"injected crash at checkpoint "
                                    f"{point} (step {step})")

    # -- storage faults ------------------------------------------------------
    def corrupt_checkpoint(self, directory: str,
                           step: Optional[int] = None) -> str:
        """Flip one deterministic byte in one payload file of checkpoint
        ``step`` (newest if None).  Returns the corrupted file's path."""
        from repro.checkpoint import latest_step
        if step is None:
            step = latest_step(directory)
        if step is None:
            raise ValueError(f"no checkpoint to corrupt in {directory}")
        path = os.path.join(directory, f"step_{step:012d}")
        payloads = sorted(n for n in os.listdir(path) if n.endswith(".npy"))
        rng = self._rng(FaultSpec("bitflip", step))
        target = os.path.join(path, payloads[rng.randint(len(payloads))])
        with open(target, "r+b") as f:
            data = bytearray(f.read())
            # flip a bit in the back half: inside the array payload, past
            # the .npy header, so np.load still parses and the *checksum*
            # has to catch it
            pos = len(data) // 2 + rng.randint(max(len(data) // 2, 1))
            pos = min(pos, len(data) - 1)
            data[pos] ^= 1 << rng.randint(8)
            f.seek(0)
            f.write(data)
        self.fired.append(f"bitflip@{step}:{os.path.basename(target)}")
        return target


class FaultInjectionHook:
    """Duck-typed TrainerHook applying step-indexed faults before the plan
    is made (so the injected state is what the step consumes)."""

    def __init__(self, injector: FaultInjector):
        self.injector = injector

    def on_run_start(self, tr) -> None:
        arm(self.injector)

    def on_step_start(self, tr) -> None:
        self.injector.pre_step(tr)

    def on_step_end(self, tr, tele, plan, metrics) -> None:
        pass

    def on_run_end(self, tr) -> None:
        tr.result.faults_fired = list(self.injector.fired)

    def close(self) -> None:
        disarm()


# ---------------------------------------------------------------------------
# module-level arming for the checkpoint crash points
# ---------------------------------------------------------------------------

_armed: Optional[FaultInjector] = None


def arm(injector: FaultInjector) -> None:
    global _armed
    _armed = injector


def disarm() -> None:
    global _armed
    _armed = None


def checkpoint_crash_point(point: str, step: int) -> None:
    """Called by ``repro.checkpoint`` at its rename boundaries; no-op unless
    an injector with a matching ``crash@step:point`` spec is armed."""
    if _armed is not None:
        _armed.maybe_crash(point, step)
