"""Version-tolerant JAX API shims.

``shard_map`` moved between jax releases: on 0.4.x it lives at
``jax.experimental.shard_map.shard_map`` and takes ``check_rep=``; newer
releases export ``jax.shard_map`` taking ``check_vma=``.  Import it from
here so the rest of the tree is release-agnostic.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.5: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map

# the check_rep -> check_vma rename landed independently of the top-level
# export, so probe the actual signature rather than inferring from location
_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """Uniform signature over jax versions (``check_vma`` name wins)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


def make_mesh(shape, axes, devices):
    """``jax.make_mesh`` with Auto axis types where the release supports
    them (``jax.sharding.AxisType`` arrived after 0.4.x; earlier meshes are
    implicitly Auto)."""
    import jax
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = ({"axis_types": (axis_type.Auto,) * len(axes)}
          if axis_type is not None else {})
    return jax.make_mesh(shape, axes, devices=devices, **kw)
