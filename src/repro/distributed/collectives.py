"""shard_map collectives: flash-decoding over a sequence-sharded KV cache.

For the ``long_500k`` decode cells the KV cache (or attention over a long
context generally) is sharded along the *sequence* axis across the ``data``
mesh axis.  Plain SPMD would all-gather the cache to every device
(seq_len * kv * head_dim bytes — the collective term explodes).  The
flash-decoding formulation computes a *partial* softmax per shard and merges
(max, sum-exp, weighted-value) triples with three tiny collectives — bytes
proportional to B*H*D instead of B*S*KV*D.

This is the beyond-paper §Perf lever for the decode-bound cells.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import shard_map

NEG_INF = -1e30


def flash_decode_sharded(mesh: Mesh, seq_axis: str = "data"):
    """Returns fn(q, k_cache, v_cache, pos) -> out.

    q: (B, 1, H, D) replicated over `seq_axis`;
    k_cache/v_cache: (B, S, KV, D) sharded along S over `seq_axis`;
    pos: () int32, number of valid cache entries (global).
    """
    n_shards = mesh.shape[seq_axis]

    def local(q, k, v, pos):
        b, sq, h, d = q.shape
        s_local, kvh = k.shape[1], k.shape[2]
        g = h // kvh
        shard = jax.lax.axis_index(seq_axis)
        base = shard * s_local  # global position of this shard's first entry
        scale = 1.0 / math.sqrt(d)
        qg = q.reshape(b, sq, kvh, g, d) * scale
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k).astype(jnp.float32)
        valid = (base + jnp.arange(s_local)) < pos
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)  # (B,KV,G,1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(v.dtype), v)
        # merge partial softmaxes across shards
        gm = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - gm)
        l_tot = jax.lax.psum(l * corr, seq_axis)
        o_tot = jax.lax.psum(o.astype(jnp.float32) * corr[..., None], seq_axis)
        out = o_tot / jnp.maximum(l_tot[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)

    def apply(q, k_cache, v_cache, pos):
        kv_spec = P(None, seq_axis, None, None)
        return shard_map(
            local, mesh=mesh,
            in_specs=(P(), kv_spec, kv_spec, P()),
            out_specs=P(),
            check_vma=False)(q, k_cache, v_cache, pos)

    return apply


def reference_decode(q, k_cache, v_cache, pos):
    """Unsharded oracle for flash_decode_sharded."""
    b, sq, h, d = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, kvh, g, d) * scale
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k_cache).astype(jnp.float32)
    valid = jnp.arange(k_cache.shape[1]) < pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bkgqd", p.astype(v_cache.dtype), v_cache)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)
