"""shard_map collectives: flash-decoding over a sequence-sharded KV cache.

For the ``long_500k`` decode cells the KV cache (or attention over a long
context generally) is sharded along the *sequence* axis across the ``data``
mesh axis.  Plain SPMD would all-gather the cache to every device
(seq_len * kv * head_dim bytes — the collective term explodes).  The
flash-decoding formulation computes a *partial* softmax per shard and merges
(max, sum-exp, weighted-value) triples with three tiny collectives — bytes
proportional to B*H*D instead of B*S*KV*D.

Masking convention — **pos = count of valid entries** (cache row ``j`` is
valid iff ``j < pos``), shared with ``models.attention.decode_attention``
and the flash-decode kernel.  The per-shard partial is the same
``(o, m, l)`` triple the kernel emits
(``kernels.flash_decode.ops.flash_decode_partials``), so the sharded merge
can consume kernel partials directly: ``backend="kernel"`` runs the Pallas
split-KV kernel inside each shard instead of the jnp local term.

This is the beyond-paper §Perf lever for the decode-bound cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.kernels import resolve_backend
from repro.kernels.flash_decode.ops import flash_decode_partials
from repro.kernels.flash_decode.ref import (decode_attention_reference,
                                            decode_partials_reference)


def flash_decode_sharded(mesh: Mesh, seq_axis: str = "data",
                         backend: str = "reference"):
    """Returns fn(q, k_cache, v_cache, pos) -> out.

    q: (B, 1, H, D) replicated over `seq_axis`;
    k_cache/v_cache: (B, S, KV, D) sharded along S over `seq_axis`;
    pos: () int32, count of valid cache entries (global).

    ``backend`` selects the per-shard partial: "reference" (jnp oracle),
    "kernel" (Pallas flash-decode kernel, compiled on TPU / reference
    fallback elsewhere) or "kernel_interpret" (kernel in interpret mode —
    the CPU validation path).
    """
    use_kernel, interpret = resolve_backend(backend, "decode backend")

    def local(q, k, v, pos):
        b, sq, h, d = q.shape
        assert sq == 1, "flash decode serves one token per step"
        s_local = k.shape[1]
        shard = jax.lax.axis_index(seq_axis)
        base = shard * s_local  # global position of this shard's first entry
        # count of valid entries inside this shard (empty shards yield
        # (o, m, l) = (0, NEG_INF, 0) and drop out of the merge exactly)
        lengths = jnp.broadcast_to(
            jnp.clip(pos - base, 0, s_local), (b,)).astype(jnp.int32)
        if use_kernel:
            o, m, l = flash_decode_partials(q[:, 0], k, v, lengths,
                                            interpret=interpret)
        else:
            o, m, l = decode_partials_reference(q[:, 0], k, v, lengths)
        # merge partial softmaxes across shards
        gm = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - gm)
        l_tot = jax.lax.psum(l * corr, seq_axis)
        o_tot = jax.lax.psum(o * corr[..., None], seq_axis)
        out = o_tot / jnp.maximum(l_tot[..., None], 1e-30)
        return out.reshape(b, sq, h, d).astype(q.dtype)

    def apply(q, k_cache, v_cache, pos):
        kv_spec = P(None, seq_axis, None, None)
        return shard_map(
            local, mesh=mesh,
            in_specs=(P(), kv_spec, kv_spec, P()),
            out_specs=P(),
            check_vma=False)(q, k_cache, v_cache, pos)

    return apply


def reference_decode(q, k_cache, v_cache, pos):
    """Unsharded oracle for flash_decode_sharded (pos = count of valid
    entries, scalar or per-row (B,) vector)."""
    b, sq, h, d = q.shape
    assert sq == 1, "flash decode serves one token per step"
    lengths = jnp.broadcast_to(jnp.asarray(pos), (b,)).astype(jnp.int32)
    out = decode_attention_reference(q[:, 0], k_cache, v_cache, lengths)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def migrate_row(src_state, src_cache, src_slot, dst_state, dst_cache,
                dst_slot, cache_len=None, placement=None):
    """Move one slot's cache row between two DecodeStates (slot migration).

    The row travels in *model format* — ``gather`` on the source, optional
    seq-capacity ``fit_row`` + cross-host/mesh ``device_put``, ``insert``
    on the destination, ``evict`` on the source — so it works across dense
    and paged states in either direction (a paged gather returns
    ``pages_per_slot * page_size`` seq entries; ``fit_row`` trims/pads to
    the destination geometry, lossless because everything past ``pos`` is
    garbage the target never reads).  This is the single-host half of the
    disaggregated-serving story: the prefill→decode handoff and the
    router's replica rebalancing both ride this path, and the ``placement``
    hook is where a multi-host destination mesh plugs in.

    Returns the updated ``(src_cache, dst_cache)``; host bookkeeping
    (scheduler slot state, page reservations) is the caller's job —
    see ``Replica.migrate_slot_to``.
    """
    row = src_state.gather(src_cache, src_slot)
    if cache_len is not None:
        row = dst_state.fit_row(row, cache_len)
    if placement is not None:
        row = jax.device_put(row, placement)
    dst_cache = dst_state.insert(dst_cache, dst_slot, row)
    src_cache = src_state.evict(src_cache, src_slot)
    return src_cache, dst_cache
