from repro.distributed.sharding import (  # noqa: F401
    ACT_RULES,
    PARAM_RULES,
    ShardingRules,
    constrain,
    tree_param_shardings,
    use_rules,
)
