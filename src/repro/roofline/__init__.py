from repro.roofline.analysis import (  # noqa: F401
    RooflineReport,
    build_report,
    model_flops,
    parse_collectives,
)
from repro.roofline import hw  # noqa: F401
