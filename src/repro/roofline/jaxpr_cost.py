"""Scan-aware FLOP/byte analysis over the traced jaxpr.

``compiled.cost_analysis()`` counts while-loop bodies *once* (verified in
tests), which undercounts scan-over-layers models by ~n_layers and chunked
recurrences by ~n_chunks.  This analyzer walks the closed jaxpr of the exact
step function the dry-run lowers and:

* counts dot_general/conv FLOPs exactly, multiplying through `scan` trip
  counts (and recursing into pjit/remat/cond calls) — the backward pass and
  remat recompute are present in the differentiated jaxpr, so they are
  counted for real, not estimated;
* estimates HBM traffic as: outputs of every equation + operands of
  dot/conv/gather/scatter/dynamic-slice ops (fused elementwise chains write
  one output in practice, so this is a documented upper-ish estimate;
  reshape/transpose/broadcast are free).

Numbers are *global* (pre-SPMD); divide by chip count for per-device terms
(exact when the op shards; sharding fallbacks recorded by the rules tell you
which archs replicate some attention math).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict

import numpy as np
from jax import core

ELEMENTWISE_FREE = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "convert_element_type",
    "bitcast_convert_type", "copy", "stop_gradient", "slice",
}

MOVER_PRIMS = {
    "gather", "scatter", "scatter-add", "scatter_add", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "pad", "take", "rev",
}

TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                  "pow", "cos", "sin", "exp2"}


def _size_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0


def _size_elems(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:  # noqa: BLE001
        return 0


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    by_prim: Dict[str, float] = field(default_factory=dict)

    def add(self, prim: str, flops: float, nbytes: float):
        self.flops += flops
        self.bytes += nbytes
        self.by_prim[prim] = self.by_prim.get(prim, 0.0) + flops

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {p: v * k for p, v in self.by_prim.items()})

    def merge(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for p, v in other.by_prim.items():
            self.by_prim[p] = self.by_prim.get(p, 0.0) + v


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = np.prod([lhs.shape[i] for i in lb], initial=1.0)
    contract = np.prod([lhs.shape[i] for i in lc], initial=1.0)
    m = np.prod([lhs.shape[i] for i in range(len(lhs.shape))
                 if i not in lc and i not in lb], initial=1.0)
    n = np.prod([rhs.shape[i] for i in range(len(rhs.shape))
                 if i not in rc and i not in rb], initial=1.0)
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    groups = eqn.params.get("feature_group_count", 1)
    k_elems = np.prod(rhs.shape, initial=1.0)
    out_spatial_batch = np.prod(out.shape, initial=1.0)
    # flops = 2 * out_elems * (kernel_elems / out_features) ... standard:
    out_feats = rhs.shape[eqn.params["dimension_numbers"].rhs_spec[0]] \
        if hasattr(eqn.params.get("dimension_numbers"), "rhs_spec") else \
        rhs.shape[-1]
    return 2.0 * out_spatial_batch * k_elems / max(out_feats, 1) / groups


def analyze_jaxpr(jaxpr) -> Cost:
    cost = Cost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        out_bytes = sum(_size_bytes(v.aval) for v in eqn.outvars)
        out_elems = sum(_size_elems(v.aval) for v in eqn.outvars)

        if name == "scan":
            inner = analyze_jaxpr(eqn.params["jaxpr"].jaxpr)
            cost.merge(inner.scaled(eqn.params["length"]))
            cost.add("scan_io", 0.0, out_bytes)
            continue
        if name == "while":
            inner = analyze_jaxpr(eqn.params["body_jaxpr"].jaxpr)
            cost.merge(inner)  # trip count unknown; repo code uses scan
            continue
        if name == "cond":
            branches = [analyze_jaxpr(b.jaxpr)
                        for b in eqn.params["branches"]]
            worst = max(branches, key=lambda c: c.flops)
            cost.merge(worst)
            continue
        sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        if sub is not None:  # pjit / remat / remat2 / custom_*_call / ...
            cost.merge(analyze_jaxpr(getattr(sub, "jaxpr", sub)))
            continue

        # HBM-traffic model: XLA fuses elementwise chains into their
        # producers/consumers, so only "materializing" ops move bytes —
        # dots/convs (operands + result), data movers (gather/scatter/...),
        # and reductions (input read).  Pure elementwise ops contribute
        # flops but no bytes (their output is the fused op's output).
        if name == "dot_general":
            in_bytes = sum(_size_bytes(v.aval) for v in eqn.invars)
            cost.add("dot_general", _dot_flops(eqn), in_bytes + out_bytes)
        elif name == "conv_general_dilated":
            in_bytes = sum(_size_bytes(v.aval) for v in eqn.invars)
            cost.add("conv", _conv_flops(eqn), in_bytes + out_bytes)
        elif name in MOVER_PRIMS:
            in_bytes = sum(_size_bytes(v.aval) for v in eqn.invars)
            cost.add(name, 0.0, min(in_bytes, out_bytes * 2) + out_bytes)
        elif name.startswith("reduce_") or name in ("reduce_sum", "reduce_max",
                                                    "cumsum", "cumlogsumexp",
                                                    "cummax", "argmax",
                                                    "sort", "top_k"):
            in_bytes = sum(_size_bytes(v.aval) for v in eqn.invars)
            cost.add(name, float(out_elems), in_bytes + out_bytes)
        elif name in ELEMENTWISE_FREE:
            pass
        elif name in TRANSCENDENTAL:
            cost.add(name, 5.0 * out_elems, 0.0)
        else:
            cost.add(name, float(out_elems), 0.0)
    return cost


def analyze_fn(fn, *abstract_args) -> Cost:
    """Trace `fn` on ShapeDtypeStructs and analyze the closed jaxpr."""
    import jax
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return analyze_jaxpr(closed.jaxpr)
