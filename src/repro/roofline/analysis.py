"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips x peak FLOP/s)
    memory term     = HLO_bytes / (chips x HBM bandwidth)
    collective term = collective wire bytes / (chips x link bandwidth)

``cost_analysis`` on a post-SPMD module is *per-device*; collective bytes
are parsed from the compiled HLO text (result shapes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops, with
replica-group sizes for ring multipliers).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{\{")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all array shapes in a result type string
    (handles tuple results)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, total_devices: int
                      ) -> List[Dict]:
    """Extract collective ops: kind, result bytes, group size."""
    out = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*)", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in COLLECTIVE_KINDS:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # counted at -start
        # result type precedes the op name
        result_part = rhs.split(f" {kind}", 1)[0]
        nbytes = _shape_bytes(result_part)
        # XLA's *CPU* pipeline promotes bf16 all-reduces to f32 (the reduce
        # computation gets a "_promoted" suffix); on the TPU target these
        # move bf16 on the wire — halve them so the roofline reflects TPU.
        promoted = "promoted" in rhs
        if promoted:
            nbytes //= 2
        group = total_devices
        mi = _GROUPS_ITOTA_RE.search(rhs)
        if mi:
            group = int(mi.group(2))
        else:
            ml = _GROUPS_LIST_RE.search(rhs)
            if ml:
                ids = [x for x in ml.group(1).split(",") if x.strip() != ""]
                group = max(len(ids), 1)
        if kind == "collective-permute":
            group = 2
        out.append({"kind": kind, "result_bytes": nbytes, "group": group,
                    "promoted": promoted})
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    kind: str  # train | prefill | decode
    chips: int
    hlo_flops: float          # per-device
    hlo_bytes: float          # per-device
    collective_wire_bytes: float  # per-device
    model_flops_global: float
    collectives: Dict[str, float] = field(default_factory=dict)
    per_device_memory_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_wire_bytes / hw.ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """Perfect-overlap model: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs) — remat/causal-waste detector."""
        total = self.hlo_flops * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """Model FLOPs / (chips x peak x step-time lower bound)."""
        t = self.step_time_lower_bound
        if not t:
            return 0.0
        return self.model_flops_global / (self.chips * hw.PEAK_FLOPS_BF16 * t)

    def summary(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "kind": self.kind, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_upper_bound": self.mfu_upper_bound,
            "collectives": self.collectives,
            "per_device_memory_bytes": self.per_device_memory_bytes,
        }


def build_report(record: Dict,
                 measure: Optional[Dict] = None) -> RooflineReport:
    """From a dry-run JSON record (see launch/dryrun.py), optionally merged
    with a `--measure` record.

    Without `measure`, flops/bytes come from compiled cost_analysis — which
    counts while-loop bodies once and therefore *undercounts* scanned models;
    prefer passing the measure record (scan-aware jaxpr flops + unrolled-
    depth collective extrapolation)."""
    chips = record["chips"]
    if measure is not None:
        flops = measure["jaxpr_flops_global"] / chips
        nbytes = measure["jaxpr_bytes_global"] / chips
        by_kind = dict(measure["collective_wire_bytes_per_device"])
        wire = sum(by_kind.values())
        model_flops = measure["model_flops"]
    else:
        flops = record["cost"].get("flops", 0.0)
        nbytes = record["cost"].get("bytes accessed", 0.0)
        by_kind = {}
        wire = 0.0
        for c in record.get("collectives", []):
            w = hw.wire_bytes(c["kind"], c["result_bytes"], c["group"])
            by_kind[c["kind"]] = by_kind.get(c["kind"], 0.0) + w
            wire += w
        model_flops = record["model_flops"]
    return RooflineReport(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        kind=record["kind"], chips=chips,
        hlo_flops=flops,
        hlo_bytes=nbytes,
        collective_wire_bytes=wire,
        model_flops_global=model_flops,
        collectives=by_kind,
        per_device_memory_bytes=record.get("memory", {}).get(
            "per_device_bytes"),
    )


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (the "useful work" numerator)
# ---------------------------------------------------------------------------

def model_flops(cfg, kind: str, batch: int, seq_len: int,
                active_params: int) -> float:
    """6*N*D for train, 2*N*D for prefill, 2*N*B for one decode step —
    plus the causal attention term where applicable."""
    if kind == "train":
        tokens = batch * seq_len
        base = 6.0 * active_params * tokens
        attn = 3.0 * _attn_fwd_flops(cfg, batch, seq_len)
    elif kind == "prefill":
        tokens = batch * seq_len
        base = 2.0 * active_params * tokens
        attn = _attn_fwd_flops(cfg, batch, seq_len)
    else:  # decode: one token against a seq_len cache
        base = 2.0 * active_params * batch
        attn = _attn_decode_flops(cfg, batch, seq_len)
    return base + attn


def _attn_layers(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every  # shared-block applications
    if cfg.family == "rwkv":
        return 0
    return cfg.n_layers


def _attn_fwd_flops(cfg, batch: int, seq_len: int) -> float:
    n_attn = _attn_layers(cfg)
    hd = cfg.resolved_head_dim
    # causal: S^2/2 effective; QK^T + PV, 2 flops/MAC
    per_layer = 2.0 * 2.0 * batch * seq_len * seq_len / 2.0 * cfg.n_heads * hd
    flops = n_attn * per_layer
    if cfg.family == "rwkv":
        # linear recurrence: ~ 3 state updates of D x D per head per token
        d = cfg.rwkv_head_dim
        h = cfg.d_model // d
        flops = cfg.n_layers * 6.0 * batch * seq_len * h * d * d
    if cfg.family == "hybrid":
        dh, nh, p, n = (cfg.ssm_expand * cfg.d_model,
                        cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim,
                        cfg.ssm_head_dim, cfg.ssm_state)
        flops += cfg.n_layers * 6.0 * batch * seq_len * nh * p * n
    return flops


def _attn_decode_flops(cfg, batch: int, seq_len: int) -> float:
    n_attn = _attn_layers(cfg)
    hd = cfg.resolved_head_dim
    flops = n_attn * 2.0 * 2.0 * batch * seq_len * cfg.n_heads * hd
    if cfg.family == "rwkv":
        d = cfg.rwkv_head_dim
        h = cfg.d_model // d
        flops = cfg.n_layers * 6.0 * batch * h * d * d
    if cfg.family == "hybrid":
        nh = cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim
        flops += cfg.n_layers * 6.0 * batch * nh * cfg.ssm_head_dim * cfg.ssm_state
    return flops
