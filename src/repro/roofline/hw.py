"""TPU v5e hardware constants (the dry-run's roofline targets)."""
from __future__ import annotations

PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW_PER_LINK = 50e9    # bytes/s per link (~)
HBM_BYTES = 16 * 2**30    # 16 GiB per chip

# bytes-on-wire multiplier per collective kind for a ring of size n:
#   all-gather      : out_bytes * (n-1)/n
#   reduce-scatter  : in_bytes  * (n-1)/n
#   all-reduce      : 2 * bytes * (n-1)/n   (RS + AG)
#   all-to-all      : bytes * (n-1)/n
#   collective-permute : bytes
def wire_bytes(kind: str, result_bytes: int, group: int) -> float:
    if group <= 1:
        return 0.0
    f = (group - 1) / group
    if kind == "all-reduce":
        return 2.0 * result_bytes * f
    if kind == "collective-permute":
        return float(result_bytes)
    return result_bytes * f
