"""Sampling as pure functions of (logits, rng): greedy / temperature /
top-k / top-p, vectorized over the slot axis with *per-slot* parameters.

Everything here is jit-friendly and shape-stable: the per-slot parameter
vectors (temperature, top_k, top_p) are runtime arrays, so one compiled
``sample_tokens`` executable serves every mix of sampling configurations the
scheduler composes into a decode batch.  ``temperature <= 0`` rows take the
greedy argmax and never consume randomness.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mask_vocab(logits: jax.Array, vocab_size: Optional[int]) -> jax.Array:
    """Mask padded vocab columns (models round the table up to 128)."""
    logits = logits.astype(jnp.float32)
    if vocab_size is not None and logits.shape[-1] != vocab_size:
        iota = jnp.arange(logits.shape[-1])
        logits = jnp.where(iota[None, :] < vocab_size, logits, NEG_INF)
    return logits


def apply_top_k(logits: jax.Array, top_k: jax.Array) -> jax.Array:
    """Keep the k largest logits per row; k <= 0 disables. logits (B, V),
    top_k (B,) int32."""
    v = logits.shape[-1]
    k = jnp.where(top_k <= 0, v, jnp.clip(top_k, 1, v))
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
    return jnp.where(logits < kth, NEG_INF, logits)


def apply_top_p(logits: jax.Array, top_p: jax.Array) -> jax.Array:
    """Nucleus truncation: keep the smallest prefix of descending-prob
    tokens whose *exclusive* cumulative mass is < top_p (the argmax row is
    always kept). logits (B, V), top_p (B,) float."""
    v = logits.shape[-1]
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # the argmax (first sorted position) survives even top_p == 0
    keep = ((cum - probs) < top_p[:, None]) | (jnp.arange(v)[None, :] == 0)
    # smallest kept logit is the admission threshold in the original order
    thresh = jnp.min(jnp.where(keep, sorted_desc, jnp.inf), axis=-1,
                     keepdims=True)
    return jnp.where(logits < thresh, NEG_INF, logits)


def sample_tokens(logits: jax.Array, keys: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array,
                  vocab_size: Optional[int] = None) -> jax.Array:
    """One next-token per row.  logits (B, V); keys (B, 2) uint32 PRNG keys
    (one independent stream per slot); temperature/top_p (B,) float,
    top_k (B,) int32.  Returns (B,) int32.

    Conventional warper order (matching mainstream servers): temperature
    scaling first, then top-k, then top-p — so the nucleus is computed on
    the *sharpened* distribution.
    """
    logits = mask_vocab(logits, vocab_size)
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    masked = apply_top_p(apply_top_k(scaled, top_k), top_p)
    sampled = jax.vmap(
        lambda key, row: jax.random.categorical(key, row))(keys, masked)
    return jnp.where(temperature <= 0.0, greedy_tok,
                     sampled.astype(jnp.int32))


def request_key(seed: int, uid: int) -> jax.Array:
    """Base PRNG key for one request (independent of batch composition)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), uid)


def step_key(base: jax.Array, step: int) -> jax.Array:
    """Per-generated-token key within a request's stream."""
    return jax.random.fold_in(base, step)
