"""Paged slot caches: block-table KV indirection behind ``DecodeState``.

Dense ``SlotDecodeState`` rows reserve ``cache_len`` tokens of KV for every
slot — worst-case memory for every request, which is exactly the
sequence-length-heterogeneity cost the paper measures at training time
showing up at serving time.  Here the attention KV leaves become a shared
pool of fixed-size **pages** plus a per-slot **page table** (vLLM-style
block tables):

* pool leaf:   dense ``(L, n_slots, cache_len, KV, D)`` becomes
  ``(L, n_pages, page_size, KV, D)`` — one allocation for the whole engine,
  sized to what requests actually use (``n_pages * page_size`` tokens)
  instead of what they might (``n_slots * cache_len``).
* page table:  ``(n_slots, pages_per_slot)`` int32, entry ``-1`` = unowned.
  Allocation is on-insert (prompt pages), grow-on-decode (one page when a
  slot's position crosses a page boundary), free-on-evict.
* admission:   a request *reserves* ``ceil((prompt_len + max_tokens) /
  page_size)`` pages before it is admitted, so grow-on-decode can never
  fail mid-flight — page exhaustion is an admission-time wait, not a
  decode-time fault (see ``Scheduler.next_admission``'s ``reserve`` hook).

Recurrent O(1) state leaves (Mamba-2 ``ssm_state``/conv windows, RWKV-6
``wkv``/shift buffers) stay dense inside the same pytree — they are
``(n_slots, ...)`` with no sequence axis, so paging buys nothing today
(conv-window paging is a recorded follow-on).  Only leaves whose
``cache_axes`` contain ``"seq"`` are paged.

The engine/scheduler call sites do not change: ``PagedDecodeState``
implements the same ``init_slots``/``insert``/``insert_many``/``evict``/
``gather``/``decode`` protocol, and ``model.decode`` routes attention
through the page table when the cache carries one (gather-based reference
path, or the page-table-walking flash-decode kernel — see
``kernels.flash_decode``).
"""
from __future__ import annotations

from typing import Any, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import model_zoo
from repro.serve.state import SlotDecodeState, _tree_map_axes


class PageExhausted(RuntimeError):
    """No free page satisfies an allocation.

    Under reservation-gated admission this is a caller bug (allocating for
    a slot that never reserved, or past its reservation), never a mid-decode
    overload: admission waits until the pool can cover a request's worst
    case before the request occupies a slot."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache entries."""
    return -(-max(int(n_tokens), 0) // page_size)


class PageAllocator:
    """Host-side free-list page allocator with per-slot page tables.

    Invariants (pinned by the property test in tests/test_paging.py):

    * every page is either on the free list or owned by exactly one slot;
    * ``table[slot, :owned[slot]]`` are that slot's pages in position order
      (page ``i`` holds token indices ``[i*page_size, (i+1)*page_size)``),
      the rest of the row is ``-1``;
    * ``sum(max(owned, reserved)) <= n_pages`` — reservations are honored,
      so a reserved slot's ``grow`` always finds a free page.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int):
        if n_pages < 1 or page_size < 1 or pages_per_slot < 1:
            raise ValueError(f"need n_pages, page_size, pages_per_slot >= 1, "
                             f"got {n_pages}, {page_size}, {pages_per_slot}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.pages_per_slot = pages_per_slot
        self.free_pages: List[int] = list(range(n_pages))[::-1]  # pop -> 0
        self.table = np.full((n_slots, pages_per_slot), -1, np.int32)
        self.owned = np.zeros(n_slots, np.int64)
        self.reserved = np.zeros(n_slots, np.int64)

    # -- accounting ---------------------------------------------------------
    @property
    def committed(self) -> int:
        """Pages promised: per slot the max of owned and reserved."""
        return int(np.maximum(self.owned, self.reserved).sum())

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self.free_pages)

    @property
    def free_page_count(self) -> int:
        """Unowned pages (router admission telemetry; note reservations
        are *not* subtracted — ``committed`` is the admission-side truth)."""
        return len(self.free_pages)

    def occupancy(self) -> float:
        return self.pages_in_use / self.n_pages

    # -- reservation (admission control) ------------------------------------
    def can_reserve(self, slot: int, n_pages: int) -> bool:
        if n_pages > self.pages_per_slot:
            return False
        cur = int(max(self.owned[slot], self.reserved[slot]))
        new = int(max(self.owned[slot], n_pages))
        return self.committed - cur + new <= self.n_pages

    def reserve(self, slot: int, n_pages: int) -> bool:
        """Reserve ``n_pages`` for ``slot``; False if the pool cannot honor
        it (the request should wait, not be admitted)."""
        if not self.can_reserve(slot, n_pages):
            return False
        self.reserved[slot] = n_pages
        return True

    # -- allocation ----------------------------------------------------------
    def _grow_one(self, slot: int) -> None:
        if self.owned[slot] >= self.pages_per_slot:
            raise PageExhausted(f"slot {slot}: page table full "
                                f"({self.pages_per_slot} pages)")
        if self.owned[slot] >= self.reserved[slot] \
                and self.committed >= self.n_pages:
            raise PageExhausted(
                f"slot {slot}: pool committed ({self.committed}/"
                f"{self.n_pages} pages) and slot has no reservation left")
        assert self.free_pages, "free list empty with headroom: invariant bug"
        page = self.free_pages.pop()
        self.table[slot, self.owned[slot]] = page
        self.owned[slot] += 1

    def allocate(self, slot: int, n_tokens: int) -> None:
        """Ensure ``slot`` owns pages covering token indices
        ``[0, n_tokens)`` (idempotent; allocates only the deficit)."""
        need = pages_for(n_tokens, self.page_size)
        while self.owned[slot] < need:
            self._grow_one(slot)

    def free_slot(self, slot: int) -> None:
        """Return all of ``slot``'s pages and drop its reservation."""
        for i in range(int(self.owned[slot])):
            self.free_pages.append(int(self.table[slot, i]))
        self.table[slot, :] = -1
        self.owned[slot] = 0
        self.reserved[slot] = 0

    def check(self) -> None:
        """Assert the ownership invariants (test hook)."""
        owned = [int(p) for row, n in zip(self.table, self.owned)
                 for p in row[:int(n)]]
        assert len(set(owned)) == len(owned), "page double-owned"
        assert not set(owned) & set(self.free_pages), "owned page on free list"
        assert sorted(owned + self.free_pages) == list(range(self.n_pages)), \
            "pages leaked"
        assert all((row[int(n):] == -1).all()
                   for row, n in zip(self.table, self.owned))
        assert self.committed <= self.n_pages


def paged_cache_specs(model, n_slots: int, cache_len: int, page_size: int,
                      n_pages: int) -> Any:
    """ShapeDtypeStruct tree for the paged slot cache.

    Leaves with a ``"seq"`` axis swap their ``(batch, seq)`` dims for
    ``(n_pages, page_size)`` pools; everything else matches
    ``model_zoo.decode_cache_specs`` (per-slot ``pos``/``active``
    bookkeeping, dense recurrent leaves).  The ``page_table`` leaf is added
    by ``PagedDecodeState.init_slots``.
    """
    axes = model_zoo.decode_cache_axes(model)
    dense = model_zoo.decode_cache_specs(model, n_slots, cache_len)

    def one(ax, sds):
        if "seq" not in ax:
            return sds
        bi, si = ax.index("batch"), ax.index("seq")
        assert si == bi + 1, f"paging assumes seq right after batch, got {ax}"
        shape = list(sds.shape)
        shape[bi], shape[si] = n_pages, page_size
        return jax.ShapeDtypeStruct(tuple(shape), sds.dtype)

    return _tree_map_axes(one, axes, dense)


class PagedDecodeState(SlotDecodeState):
    """``DecodeState`` over a paged KV pool + per-slot page tables.

    Protocol-compatible with ``SlotDecodeState`` (the engine/scheduler call
    sites are unchanged); extra surface: ``try_reserve`` (the admission
    page-budget hook) and the ``PageAllocator`` at ``self.alloc``.  The
    prefill/replay side still runs on dense batch=1 caches (``row``/
    ``stack_rows``/replay-``decode`` are inherited) — paging starts at
    ``insert``, where prompt rows scatter into owned pages.
    """

    def __init__(self, model, page_size: int, n_pages: int):
        super().__init__(model)
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        saxes = dict(self._axes)
        saxes["active"] = ()
        n_pool = self.n_pages
        ps = self.page_size

        def _page_ids(table_rows):
            # -1 (unowned) -> one-past-the-pool sentinel: scatters drop it
            return jnp.where(table_rows >= 0, table_rows, n_pool)

        def _to_pages(ax, p, pps, dtype):
            """(..., S, ...) prefill leaf -> (..., pps, ps, ...) pages."""
            si = ax.index("batch")  # batch squeezed/kept: seq sits here
            cap = pps * ps
            pad = cap - p.shape[si]
            if pad:
                width = [(0, 0)] * p.ndim
                width[si] = (0, pad)
                p = jnp.pad(p, width)
            shape = p.shape[:si] + (pps, ps) + p.shape[si + 1:]
            return p.reshape(shape).astype(dtype)

        def pinsert_fn(cache, slot, one):
            cache = dict(cache)
            table = cache.pop("page_table")
            pps = table.shape[1]
            pids = _page_ids(table[slot])  # (pps,)

            def leaf(ax, c, p):
                if "seq" in ax:
                    bi = ax.index("batch")
                    pages = _to_pages(ax, jnp.squeeze(p, axis=bi), pps,
                                      c.dtype)
                    idx = (slice(None),) * bi + (pids,)
                    return c.at[idx].set(pages, mode="drop")
                if "batch" in ax:
                    return jax.lax.dynamic_update_slice_in_dim(
                        c, p.astype(c.dtype), slot, axis=ax.index("batch"))
                return jax.lax.dynamic_update_slice_in_dim(
                    c, jnp.asarray(p)[None].astype(c.dtype), slot, axis=0)

            out = _tree_map_axes(leaf, saxes, cache, one)
            out["page_table"] = table
            return out

        def pinsert_many_fn(cache, slots, rows):
            cache = dict(cache)
            table = cache.pop("page_table")
            pps = table.shape[1]
            k = slots.shape[0]
            pids = _page_ids(table[slots])  # (k, pps)

            def leaf(ax, c, p):
                if "seq" in ax:
                    bi = ax.index("batch")
                    # p: (..., k, S, ...) -> (..., k, pps, ps, ...)
                    cap = pps * ps
                    si = bi + 1
                    pad = cap - p.shape[si]
                    if pad:
                        width = [(0, 0)] * p.ndim
                        width[si] = (0, pad)
                        p = jnp.pad(p, width)
                    shape = p.shape[:si] + (pps, ps) + p.shape[si + 1:]
                    pages = p.reshape(shape).astype(c.dtype)
                    idx = (slice(None),) * bi + (pids,)
                    return c.at[idx].set(pages, mode="drop")
                if "batch" in ax:
                    bax = ax.index("batch")
                    cm = jnp.moveaxis(c, bax, 0)
                    pm = jnp.moveaxis(p, bax, 0).astype(c.dtype)
                    return jnp.moveaxis(cm.at[slots].set(pm), 0, bax)
                p = jnp.asarray(p).astype(c.dtype)
                if p.ndim < c.ndim:
                    p = jnp.broadcast_to(p, (k,) + c.shape[1:])
                return c.at[slots].set(p)

            out = _tree_map_axes(leaf, saxes, cache, rows)
            out["page_table"] = table
            return out

        def pevict_fn(cache, slot):
            cache = dict(cache)
            table = cache.pop("page_table")

            def leaf(ax, c):
                if "batch" in ax or "seq" in ax:
                    return c  # pages return to the free list host-side
                zero = jnp.zeros((1,) + c.shape[1:], c.dtype)
                return jax.lax.dynamic_update_slice_in_dim(c, zero, slot,
                                                           axis=0)

            out = _tree_map_axes(leaf, saxes, cache)
            out["page_table"] = table
            return out

        def pgather_fn(cache, slot):
            cache = dict(cache)
            table = cache.pop("page_table")
            row = table[slot]  # (pps,)
            rowc = jnp.maximum(row, 0)

            def leaf(ax, c):
                if "seq" in ax:
                    bi = ax.index("batch")
                    pages = jnp.take(c, rowc, axis=bi)  # (..., pps, ps, ...)
                    mask = (row >= 0).reshape(
                        (1,) * bi + (row.shape[0],)
                        + (1,) * (pages.ndim - bi - 1))
                    pages = jnp.where(mask, pages, 0)
                    cap = row.shape[0] * ps
                    return pages.reshape(pages.shape[:bi] + (1, cap)
                                         + pages.shape[bi + 2:])
                if "batch" in ax:
                    return jax.lax.dynamic_slice_in_dim(
                        c, slot, 1, axis=ax.index("batch"))
                return jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=0)[0]

            out = _tree_map_axes(leaf, saxes, cache)
            out.pop("active")  # gather returns model-format (prefill) caches
            return out

        self._pinsert = jax.jit(pinsert_fn, donate_argnums=(0,))
        self._pinsert_many = jax.jit(pinsert_many_fn, donate_argnums=(0,))
        self._pevict = jax.jit(pevict_fn, donate_argnums=(0,))
        self._pgather = jax.jit(pgather_fn)

    # -- protocol ------------------------------------------------------------
    def init_slots(self, n_slots: int, cache_len: int) -> Any:
        self.n_slots, self.cache_len = n_slots, cache_len
        pps = pages_for(cache_len, self.page_size)
        self.alloc = PageAllocator(self.n_pages, self.page_size, n_slots,
                                   pps)
        self._host_pos = np.zeros(n_slots, np.int64)
        self._host_active = np.zeros(n_slots, bool)
        specs = paged_cache_specs(self.model, n_slots, cache_len,
                                  self.page_size, self.n_pages)
        cache = jax.tree_util.tree_map(
            lambda sds: jnp.zeros(sds.shape, sds.dtype), specs)
        cache["page_table"] = jnp.asarray(self.alloc.table)
        return cache

    def try_reserve(self, slot: int, request) -> bool:
        """Admission page budget: reserve the request's worst case
        (``ceil((prompt_len + max_tokens) / page_size)`` pages) so
        grow-on-decode can never fail; False = the request waits."""
        need = pages_for(request.prompt_len + request.max_tokens,
                         self.page_size)
        return self.alloc.reserve(slot, need)

    def insert(self, cache, slot, prefill_cache):
        slot = int(slot)
        n_tok = int(np.asarray(prefill_cache["pos"]))
        self.alloc.allocate(slot, n_tok)
        self._host_pos[slot] = n_tok
        self._host_active[slot] = True
        cache = dict(cache, page_table=jnp.asarray(self.alloc.table))
        one = dict(prefill_cache)
        one.setdefault("active", jnp.ones((), jnp.bool_))
        return self._pinsert(cache, jnp.asarray(slot, jnp.int32), one)

    def insert_many(self, cache, slots, prefill_cache):
        slots_np = np.asarray(slots, np.int64)
        pos_vals = np.broadcast_to(np.asarray(prefill_cache["pos"]),
                                   slots_np.shape)
        for s, n_tok in zip(slots_np, pos_vals):
            self.alloc.allocate(int(s), int(n_tok))
            self._host_pos[int(s)] = int(n_tok)
            self._host_active[int(s)] = True
        cache = dict(cache, page_table=jnp.asarray(self.alloc.table))
        rows = dict(prefill_cache)
        rows.setdefault("active", jnp.ones((), jnp.bool_))
        return self._pinsert_many(cache, jnp.asarray(slots, jnp.int32), rows)

    def evict(self, cache, slot):
        slot = int(slot)
        self.alloc.free_slot(slot)
        self._host_pos[slot] = 0
        self._host_active[slot] = False
        cache = dict(cache, page_table=jnp.asarray(self.alloc.table))
        return self._pevict(cache, jnp.asarray(slot, jnp.int32))

    def gather(self, cache, slot):
        return self._pgather(cache, jnp.asarray(int(slot), jnp.int32))

    def decode(self, params, cache, tokens):
        """Fused decode with grow-on-decode.

        Before the jitted step, every active slot whose next write index
        crosses into an unowned page gets one page from the free list
        (guaranteed by its admission reservation); the device page table is
        refreshed only when the host table changed.  Dense batch=1 replay
        caches (no ``page_table`` leaf) pass straight through — the paged
        and dense decode executables coexist keyed on cache structure.
        """
        if not (isinstance(cache, dict) and "page_table" in cache):
            return self._decode(params, cache, tokens)
        dirty = False
        for slot in np.nonzero(self._host_active)[0]:
            p = int(self._host_pos[slot])
            if p < self.cache_len \
                    and int(self.alloc.owned[slot]) * self.page_size <= p:
                self.alloc.allocate(int(slot), p + 1)
                dirty = True
        if dirty:
            cache = dict(cache, page_table=jnp.asarray(self.alloc.table))
        logits, cache = self._decode(params, cache, tokens)
        cap = self.alloc.pages_per_slot * self.page_size
        act = self._host_active
        self._host_pos[act] = np.minimum(self._host_pos[act] + 1, cap)
        return logits, cache

    # -- placement -----------------------------------------------------------
    def shardings(self, rules, n_slots: int, cache_len: int):
        """Paged pools keep head/state axes on the activation rules; the
        page and in-page axes are replicated (a page is not slot-owned, so
        the slot-axis "batch" rule does not apply to pools)."""
        from repro.distributed.sharding import tree_act_shardings
        specs = paged_cache_specs(self.model, n_slots, cache_len,
                                  self.page_size, self.n_pages)
        axes = model_zoo.decode_cache_axes(self.model)

        def one(ax, _sds):
            if "seq" not in ax:
                return ax
            return tuple(None if a in ("batch", "seq") else a for a in ax)

        paxes = _tree_map_axes(one, axes, specs)
        out = tree_act_shardings(rules, paxes, specs)
        pps = pages_for(cache_len, self.page_size)
        table = jax.ShapeDtypeStruct((n_slots, pps), jnp.int32)
        out["page_table"] = tree_act_shardings(
            rules, (None, None), table)
        return out


def cache_nbytes(cache) -> int:
    """Resident bytes of a decode cache (the dense-vs-paged memory math:
    ``n_pages * page_size`` vs ``n_slots * cache_len`` tokens of KV)."""
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))
