"""Continuous-batching scheduler: length-bucketed admission into fixed slots.

Admission control reuses the training side's TPU adaptation verbatim: the
prompt length is quantized *down* onto ``core.pacing.bucket_ladder`` (the
same ladder that bounds jit cache churn for the SLW curriculum), the bucket
prefix runs through the jitted prefill — one compiled executable per bucket
— and the sub-bucket remainder replays through the decode step, which is
exact for every backbone (no padding, no masked prefill).  The paper's
observation that sequence-length heterogeneity dominates cost applies
unchanged at serving time: ragged prompts land on a bounded shape set, and
ragged generation lengths are absorbed by per-slot eviction + backfill.

Batched prefill (``SchedulerConfig.prefill_batch``): admission can pop up
to ``k`` pending requests that share a prefill split and hand them to the
engine as one ``(k, bucket)`` prefill call — sub-bucket remainders still
decode-replay per request, so parity with sequential admission is exact.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Tuple

import numpy as np

from repro.configs.base import SLWConfig
from repro.core.pacing import bucket_ladder, quantize
from repro.serve.types import GenerationResult, Request
from repro.serve import sampling


class QueueFull(RuntimeError):
    """Bounded submit queue is at capacity — the caller must shed or retry.

    Overload is an explicit signal, not silent queue growth: at production
    rates an unbounded pending deque is memory-pressure-then-OOM, and the
    caller (router, API front-end) is the layer that knows whether to
    reject with 429, retry elsewhere, or spill."""


@dataclass(frozen=True)
class SchedulerConfig:
    """Knobs for slot/bucket composition.

    n_slots:      decode batch width (fixed; empty slots decode garbage that
                  is never surfaced)
    cache_len:    per-slot KV/state capacity; every request must satisfy
                  prompt_len + max_tokens <= cache_len
    prompt ladder (min_prompt_bucket / round_multiple / max_buckets): feeds
                  core.pacing.bucket_ladder — at most max_buckets + 1
                  distinct single-request prefill shapes ever compile (the
                  ladder plus the length-1 shape sub-bucket prompts use).
    prefill_batch: max same-bucket requests admitted as one (k, bucket)
                  prefill call (1 = sequential admission, the legacy
                  behavior; >1 amortizes weight reads across prompts and
                  multiplies the prefill shape set by at most
                  prefill_batch).
    max_pending:  bound on the pending queue (0 = unbounded, the legacy
                  behavior).  ``submit``/``submit_all`` raise
                  :class:`QueueFull` at capacity; the engine's
                  ``try_submit`` turns that into an explicit shed.
    paged:        allocate KV as a shared page pool behind a per-slot page
                  table (serve/paging.py) instead of dense
                  ``(n_slots, cache_len)`` rows.  Memory goes from
                  ``n_slots * cache_len`` to ``n_pages * page_size`` cache
                  tokens — sized to what requests actually use; admission
                  gains a page budget (a request needs
                  ``ceil((prompt_len + max_tokens) / page_size)`` pages
                  reserved or it waits in the pending queue).
    page_size:    tokens per page when paged.
    n_pages:      pool size when paged; 0 = dense-equivalent
                  (``n_slots * ceil(cache_len / page_size)`` — no memory
                  saving, same behavior; set lower to oversubscribe).
    policy:       admission policy name (serve/policies.py): "fcfs" (the
                  default — bitwise the behavior of
                  :meth:`Scheduler.next_admission`, which stays the FCFS
                  primitive), "shortest-prompt-first", or
                  "budget-packing".
    pack_budget:  token budget per admission round for
                  policy="budget-packing": the round's total worst-case
                  footprint (prompt_len + max_tokens per request) stays
                  under it.  0 resolves to cache_len * prefill_batch —
                  one full slot row per packed request, so the default
                  never binds below the FCFS batch.
    """

    n_slots: int = 8
    cache_len: int = 512
    min_prompt_bucket: int = 16
    round_multiple: int = 32
    max_buckets: int = 8
    prefill_batch: int = 1
    max_pending: int = 0
    paged: bool = False
    page_size: int = 64
    n_pages: int = 0
    policy: str = "fcfs"
    pack_budget: int = 0

    @property
    def pages_per_slot(self) -> int:
        return -(-self.cache_len // self.page_size)

    @property
    def resolved_n_pages(self) -> int:
        if not self.paged:
            return 0
        return self.n_pages or self.dense_equivalent_pages()

    def dense_equivalent_pages(self) -> int:
        return self.n_slots * self.pages_per_slot

    @property
    def resolved_pack_budget(self) -> int:
        return self.pack_budget or self.cache_len * max(self.prefill_batch, 1)

    def ladder(self) -> Tuple[int, ...]:
        slw = SLWConfig(enabled=True, start_seq_len=self.min_prompt_bucket,
                        end_seq_len=self.cache_len,
                        round_multiple=self.round_multiple,
                        max_buckets=self.max_buckets)
        return bucket_ladder(slw, self.cache_len)


def prefill_split(prompt_len: int, ladder: Tuple[int, ...]) -> int:
    """Tokens to prefill at a bucketed shape; the rest replays via decode.

    Round-*down* quantization (paper semantics, ``pacing.quantize``).
    Prompts shorter than the smallest bucket prefill a single token and
    decode-replay the rest: N distinct short lengths share the one
    length-1 prefill executable, so the compiled shape set stays
    ``ladder U {1}`` (the bounded-jit-shape guarantee above — exact-length
    prefills used to leak one executable per distinct short length).
    """
    if prompt_len < ladder[0]:
        return 1
    return quantize(prompt_len, ladder)


@dataclass
class ActiveSlot:
    """Host-side bookkeeping for one occupied slot."""

    request: Request
    result: GenerationResult
    base_key: np.ndarray  # (2,) uint32 — host copy, folded on device
    last_token: int = 0

    @property
    def n_generated(self) -> int:
        return len(self.result.tokens)


class Scheduler:
    """Admission queue + slot lifecycle.  The engine executes; the
    scheduler decides which request occupies which slot and when a slot
    retires (per-slot stopping: length budget or stop token)."""

    def __init__(self, cfg: SchedulerConfig):
        if cfg.n_slots < 1 or cfg.cache_len < 1:
            raise ValueError(f"need n_slots >= 1 and cache_len >= 1, got "
                             f"{cfg.n_slots}, {cfg.cache_len}")
        if cfg.paged:
            if cfg.page_size < 1:
                raise ValueError(f"need page_size >= 1, got {cfg.page_size}")
            if cfg.resolved_n_pages < cfg.pages_per_slot:
                # any request _validate admits (prompt + max_tokens up to
                # cache_len) must eventually get its reservation once the
                # pool drains, else admission deadlocks with work pending
                raise ValueError(
                    f"n_pages {cfg.resolved_n_pages} cannot hold one "
                    f"maximal request ({cfg.pages_per_slot} pages = "
                    f"cache_len {cfg.cache_len} / page_size "
                    f"{cfg.page_size})")
        self.cfg = cfg
        self.ladder = cfg.ladder()
        self.pending: Deque[Request] = deque()
        self.active: Dict[int, ActiveSlot] = {}
        self.free: List[int] = list(range(cfg.n_slots))[::-1]  # pop() -> 0 first
        self.finished: List[GenerationResult] = []

    # -- admission ---------------------------------------------------------
    def _validate(self, request: Request, uids: set) -> None:
        need = request.prompt_len + request.max_tokens
        if need > self.cfg.cache_len:
            raise ValueError(
                f"request {request.uid}: prompt_len + max_tokens = {need} "
                f"exceeds cache_len {self.cfg.cache_len}")
        if request.max_tokens < 1:
            raise ValueError(f"request {request.uid}: max_tokens must be >= 1")
        if request.prompt_len < 1:
            raise ValueError(f"request {request.uid}: empty prompt")
        if request.uid in uids:
            # uids key result routing and the per-request PRNG stream
            raise ValueError(f"request uid {request.uid} already in flight")
        uids.add(request.uid)

    def _in_flight_uids(self) -> set:
        return ({r.uid for r in self.pending}
                | {s.request.uid for s in self.active.values()})

    @property
    def has_room(self) -> bool:
        return (not self.cfg.max_pending
                or len(self.pending) < self.cfg.max_pending)

    def submit(self, request: Request) -> None:
        if not self.has_room:
            raise QueueFull(f"pending queue at capacity "
                            f"({self.cfg.max_pending})")
        self._validate(request, self._in_flight_uids())
        self.pending.append(request)

    def submit_all(self, requests) -> None:
        """All-or-nothing admission: a validation failure anywhere in the
        batch enqueues nothing (a half-submitted batch would leak orphan
        pending requests into the caller's next drain).  ``requests`` is
        materialized once up front — a generator used to be exhausted by
        the validation pass, silently enqueueing nothing.  Overload is
        all-or-nothing too: if the whole batch does not fit under
        ``max_pending``, :class:`QueueFull`."""
        requests = list(requests)
        if self.cfg.max_pending and \
                len(self.pending) + len(requests) > self.cfg.max_pending:
            raise QueueFull(
                f"{len(requests)} requests exceed pending capacity "
                f"{self.cfg.max_pending} ({len(self.pending)} queued)")
        uids = self._in_flight_uids()
        for r in requests:
            self._validate(r, uids)
        self.pending.extend(requests)

    def validate_batch(self, requests) -> None:
        """Validation only (uid/shape checks against in-flight + each
        other), no enqueue — the engine validates its whole request set
        up front, then feeds it through the bounded queue incrementally."""
        uids = self._in_flight_uids()
        for r in requests:
            self._validate(r, uids)

    def enqueue_validated(self, request: Request) -> None:
        """Append one already-validated request (engine backlog feed)."""
        self.pending.append(request)

    def next_admission(self, k: int = 1, reserve=None
                       ) -> List[Tuple[int, Request]]:
        """Pop up to ``k`` same-split (free slot, request) pairs; [] if no
        slot or no request is available.

        The queue head fixes the prefill split; later pending requests
        with the same split are pulled forward to fill the batch (Lau et
        al.-style batch composition: same-shape prompts amortize one
        ``(k, bucket)`` prefill), skipped requests keep their relative
        order.

        ``reserve`` is the paged-admission budget hook
        (``PagedDecodeState.try_reserve``): called as ``reserve(slot,
        request)`` before a pair is emitted.  A False for the queue *head*
        returns [] with the queue untouched — strict FCFS, the head waits
        for pages freed by retiring slots rather than being starved by
        smaller requests jumping it.  A False for a pulled-forward
        candidate just skips that candidate (it kept its queue position
        anyway).
        """
        if not self.pending or not self.free:
            return []
        if reserve is not None and not reserve(self.free[-1],
                                               self.pending[0]):
            return []
        head = self.pending.popleft()
        out = [(self.free.pop(), head)]
        if k > 1:
            split = prefill_split(head.prompt_len, self.ladder)
            skipped: List[Request] = []
            while self.pending and self.free and len(out) < k:
                r = self.pending.popleft()
                if prefill_split(r.prompt_len, self.ladder) != split:
                    skipped.append(r)
                    continue
                if reserve is not None and not reserve(self.free[-1], r):
                    skipped.append(r)
                    continue
                out.append((self.free.pop(), r))
            self.pending.extendleft(reversed(skipped))
        return out

    def activate(self, slot: int, request: Request,
                 first_token: int, prefill_s: float) -> ActiveSlot:
        st = ActiveSlot(
            request=request,
            result=GenerationResult(uid=request.uid,
                                    prompt_len=request.prompt_len,
                                    prefill_s=prefill_s),
            base_key=np.asarray(sampling.request_key(request.sampling.seed,
                                                     request.uid)),
            last_token=first_token)
        st.result.tokens.append(first_token)
        self.active[slot] = st
        return st

    # -- stopping ----------------------------------------------------------
    def stop_reason(self, st: ActiveSlot) -> str:
        sp = st.request.sampling
        if sp.stop_token is not None and st.result.tokens \
                and st.result.tokens[-1] == sp.stop_token:
            return "stop_token"
        if st.n_generated >= st.request.max_tokens:
            return "length"
        return ""

    def finish(self, slot: int, reason: str) -> GenerationResult:
        st = self.active.pop(slot)
        st.result.finish_reason = reason
        self.free.append(slot)
        self.finished.append(st.result)
        return st.result

    def abort(self, slot: int, request: Request, detail: str = ""
              ) -> GenerationResult:
        """Retire a slot whose request failed: free the slot, record an
        ``error`` result so the caller still gets an answer for the uid.

        If the request had already activated, its partial result — tokens
        generated so far, possibly already streamed via ``on_token`` — is
        preserved on the error result (a fresh empty result here used to
        silently drop that work, so the caller saw tokens stream and then
        vanish from the final answer)."""
        st = self.active.pop(slot, None)
        if st is not None:  # activated before the failure surfaced
            res = st.result
            res.finish_reason = "error"
        else:
            res = GenerationResult(uid=request.uid,
                                   prompt_len=request.prompt_len,
                                   finish_reason="error")
        self.free.append(slot)
        self.finished.append(res)
        return res

    # -- state -------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self.active) or bool(self.pending)
