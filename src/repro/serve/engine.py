"""InferenceEngine: continuous batching over the DecodeState protocol.

One engine serves every backbone family through the same three jitted
executables:

* per-bucket **prefill** (shape-keyed jit cache, bounded by the prompt
  ladder; up to ``SchedulerConfig.prefill_batch`` same-bucket requests
  stack into one ``(k, bucket)`` call) + an exact decode replay of each
  request's sub-bucket remainder,
* slot **insert/evict** surgery on the donated state buffer,
* one **fused decode step** for all slots at once (per-slot positions,
  per-slot sampling parameters, per-slot stopping).

The loop is host-driven: admit pending requests into free slots, step the
fused decode, retire finished slots, backfill.  Greedy outputs are
tokenwise identical to running each request alone through the legacy
static-batch path (tests/test_serve_engine.py pins this for dense and
recurrent backbones).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import model_zoo
from repro.serve import sampling
from repro.serve.scheduler import (QueueFull, Scheduler, SchedulerConfig,
                                   prefill_split)
from repro.serve.state import SlotDecodeState
from repro.serve.types import GenerationResult, Request

OnToken = Callable[[int, int], None]  # (request uid, token id)


# per-step decode latency samples kept for percentiles: a bounded ring,
# not a list — one float per fused step forever is a slow leak at
# production rates (a week at 100 steps/s is ~500 MB of pure bookkeeping)
STEP_TIME_WINDOW = 2048


@dataclass
class EngineStats:
    """Host wall-clock accounting for one engine lifetime."""

    prefill_s: float = 0.0
    prefill_tokens: int = 0
    decode_s: float = 0.0
    decode_steps: int = 0
    generated_tokens: int = 0
    admitted: int = 0
    step_times: Deque[float] = field(
        default_factory=lambda: deque(maxlen=STEP_TIME_WINDOW))
    # containment accounting: slots retired with reason="error" (the batch
    # kept going) and submissions shed at the bounded queue
    slot_errors: int = 0
    shed: int = 0

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / max(self.prefill_s, 1e-9)

    @property
    def decode_tok_s(self) -> float:
        """Useful fused-decode tokens per second of fused-decode wall time
        (each request's first token is emitted by its admission prefill and
        excluded here)."""
        return ((self.generated_tokens - self.admitted)
                / max(self.decode_s, 1e-9))

    def latency_percentile(self, p: float) -> float:
        """p-th percentile of per-step (== per-token) decode latency, s.

        Exact for runs up to ``STEP_TIME_WINDOW`` decode steps (every
        sample is still in the ring); beyond that it is the percentile of
        the trailing window — the production-relevant figure anyway."""
        if not self.step_times:
            return 0.0
        return float(np.percentile(
            np.fromiter(self.step_times, np.float64), p))


class InferenceEngine:
    """Continuous-batching generation over a fixed slot pool."""

    def __init__(self, model, params, cfg: Optional[SchedulerConfig] = None,
                 rules=None):
        self.model = model
        self.params = params
        self.cfg = cfg or SchedulerConfig()
        if self.cfg.paged:
            from repro.serve.paging import PagedDecodeState
            self.state = PagedDecodeState(
                model, page_size=self.cfg.page_size,
                n_pages=self.cfg.resolved_n_pages)
            # admission page budget: a request is only admitted once its
            # worst case (prompt + max_tokens) is reserved in the pool
            self._reserve = self.state.try_reserve
        else:
            self.state = SlotDecodeState(model)
            self._reserve = None
        self.scheduler = Scheduler(self.cfg)
        self.cache = self.state.init_slots(self.cfg.n_slots,
                                           self.cfg.cache_len)
        if rules is not None:
            self.cache = jax.device_put(
                self.cache, self.state.shardings(rules, self.cfg.n_slots,
                                                 self.cfg.cache_len))
        cache_len = self.cfg.cache_len
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))
        vocab = model.cfg.vocab_size
        self._sample = jax.jit(partial(sampling.sample_tokens,
                                       vocab_size=vocab))
        # fused-loop variant: per-slot base keys folded with the per-slot
        # token index *on device*, one executable call per step (no
        # host-side fold_in round-trips inside the timed decode loop)
        self._sample_at = jax.jit(
            lambda lg, keys, steps, t, k, p: sampling.sample_tokens(
                lg, jax.vmap(jax.random.fold_in)(keys, steps), t, k, p,
                vocab_size=vocab))
        # greedy fast path: all-greedy batches (the default) skip the
        # top-k/top-p sorts and the categorical draw entirely
        self._greedy = jax.jit(lambda lg: jnp.argmax(
            sampling.mask_vocab(lg, vocab), axis=-1).astype(jnp.int32))
        self.stats = EngineStats()

    # -- construction helpers ----------------------------------------------
    @classmethod
    def from_arch(cls, arch: str, use_reduced: bool = True, seed: int = 0,
                  cfg: Optional[SchedulerConfig] = None,
                  decode_backend: Optional[str] = None, **kw
                  ) -> "InferenceEngine":
        from repro.configs import get_arch, reduced as reduce_cfg
        spec = get_arch(arch)
        mcfg = reduce_cfg(spec.model) if use_reduced else spec.model
        if decode_backend:
            mcfg = mcfg.replace(decode_backend=decode_backend)
        model = model_zoo.build_model(mcfg, dtype=jnp.float32, remat="none")
        params = model_zoo.init_params(jax.random.PRNGKey(seed), mcfg)
        return cls(model, params, cfg=cfg, **kw)

    # -- admission: bucketed (k, bucket) prefill + exact remainder replay ---
    def _first_token(self, req: Request, logits: jax.Array) -> int:
        """Sample the admission token from one request's (1, V) logits."""
        sp = req.sampling
        if sp.temperature <= 0.0:
            return int(self._greedy(logits)[0])
        key = sampling.step_key(
            sampling.request_key(sp.seed, req.uid), 0)[None]
        return int(self._sample(
            logits, key,
            jnp.full((1,), sp.temperature, jnp.float32),
            jnp.full((1,), sp.top_k, jnp.int32),
            jnp.full((1,), sp.top_p, jnp.float32))[0])

    def _admit_batch(self, admissions, on_token: Optional[OnToken]) -> None:
        """Admit same-split requests as one ``(k, bucket)`` prefill call.

        The scheduler guarantees every request in ``admissions`` shares a
        prefill split, so their bucket prefixes stack into one jitted
        prefill (shape set bounded by (ladder U {1}) x prefill_batch).
        Ragged sub-bucket remainders then decode-replay per request on the
        sliced row cache — exact for every backbone — and the rows land in
        their slots through one multi-row ``insert_many``.  Per-request
        ``prefill_s`` reports the batch wall time amortized over k.
        """
        t0 = time.time()
        reqs = [r for _, r in admissions]
        try:
            split = prefill_split(reqs[0].prompt_len, self.scheduler.ladder)
            toks = jnp.asarray([r.tokens[:split] for r in reqs], jnp.int32)
            logits, kcache = self._prefill(self.params, {"tokens": toks})
        except Exception:  # noqa: BLE001 — shared phase: all k slots fail
            for slot, req in admissions:
                # evict even though nothing was inserted: it releases the
                # slot's admission page reservation (no-op for dense)
                self.cache = self.state.evict(self.cache, slot)
                self.scheduler.abort(slot, req)
                self.stats.slot_errors += 1
            return
        row_logits = [logits[i:i + 1] for i in range(len(reqs))]
        failed = [False] * len(reqs)
        if any(r.prompt_len > split for r in reqs):
            rows = [self.state.row(kcache, i) for i in range(len(reqs))]
            for i, r in enumerate(reqs):
                try:
                    full = jnp.asarray(r.tokens, jnp.int32)[None, :]
                    for j in range(split, r.prompt_len):
                        row_logits[i], rows[i] = self.state.decode(
                            self.params, rows[i], full[:, j:j + 1])
                except Exception:  # noqa: BLE001 — this request only
                    failed[i] = True
            live = [i for i in range(len(reqs)) if not failed[i]]
            stacked = (self.state.stack_rows([rows[i] for i in live])
                       if live else None)
        else:
            live = list(range(len(reqs)))
            stacked = kcache
        if stacked is not None:
            self.cache = self.state.insert_many(
                self.cache,
                np.asarray([admissions[i][0] for i in live], np.int32),
                stacked)
        firsts: Dict[int, int] = {}
        for i in live:
            try:
                firsts[i] = self._first_token(reqs[i], row_logits[i])
            except Exception:  # noqa: BLE001 — per-request sampling fault
                failed[i] = True
        dt = time.time() - t0
        self.stats.prefill_s += dt
        self.stats.prefill_tokens += sum(r.prompt_len for i, r
                                         in enumerate(reqs) if not failed[i])
        n_ok = sum(not f for f in failed)
        self.stats.admitted += n_ok
        self.stats.generated_tokens += n_ok
        for i, (slot, req) in enumerate(admissions):
            if failed[i]:
                # the failing request retires alone; the evict clears its
                # cache row if one was inserted (sampling failed after
                # insert_many) and releases its page reservation either
                # way — the rest of the batch proceeds
                self.cache = self.state.evict(self.cache, slot)
                self.scheduler.abort(slot, req)
                self.stats.slot_errors += 1
                continue
            st = self.scheduler.activate(slot, req, firsts[i],
                                         dt / max(n_ok, 1))
            try:
                if on_token:
                    on_token(req.uid, firsts[i])
                reason = self.scheduler.stop_reason(st)
            except Exception:  # noqa: BLE001 — consumer callback fault
                self._retire(slot, "error")
                self.stats.slot_errors += 1
                continue
            if reason:
                self._retire(slot, reason)

    def _retire(self, slot: int, reason: str) -> GenerationResult:
        self.cache = self.state.evict(self.cache, slot)
        res = self.scheduler.finish(slot, reason)
        res.decode_steps = max(len(res.tokens) - 1, 0)
        return res

    # -- the fused decode step ---------------------------------------------
    def _fused_step(self, on_token: Optional[OnToken]) -> None:
        n = self.cfg.n_slots
        toks = np.zeros((n, 1), np.int32)
        temps = np.zeros((n,), np.float32)
        topk = np.zeros((n,), np.int32)
        topp = np.ones((n,), np.float32)
        keys = np.zeros((n, 2), np.uint32)
        steps = np.zeros((n,), np.int32)
        active_now: List[tuple] = list(self.scheduler.active.items())
        all_greedy = True
        for slot, st in active_now:
            sp = st.request.sampling
            toks[slot, 0] = st.last_token
            temps[slot] = sp.temperature
            topk[slot] = sp.top_k
            topp[slot] = sp.top_p
            if sp.temperature > 0.0:
                all_greedy = False
                keys[slot] = st.base_key
                steps[slot] = st.n_generated
        t0 = time.time()
        logits, self.cache = self.state.decode(self.params, self.cache,
                                               jnp.asarray(toks))
        if all_greedy:
            nxt = np.asarray(self._greedy(logits))
        else:
            nxt = np.asarray(self._sample_at(
                logits, jnp.asarray(keys), jnp.asarray(steps),
                jnp.asarray(temps), jnp.asarray(topk), jnp.asarray(topp)))
        dt = time.time() - t0
        self.stats.step_times.append(dt)
        self.stats.decode_s += dt
        self.stats.decode_steps += 1
        self.stats.generated_tokens += len(active_now)
        for slot, st in active_now:
            try:
                tok = int(nxt[slot])
                st.result.tokens.append(tok)
                st.last_token = tok
                if on_token:
                    on_token(st.request.uid, tok)
                reason = self.scheduler.stop_reason(st)
            except Exception:  # noqa: BLE001 — retire only this slot; the
                self._retire(slot, "error")  # rest of the batch finishes
                self.stats.slot_errors += 1
                continue
            if reason:
                self._retire(slot, reason)

    # -- driver -------------------------------------------------------------
    def run(self, requests: Sequence[Request],
            on_token: Optional[OnToken] = None) -> List[GenerationResult]:
        """Generate for all ``requests``; returns results in request order.

        ``on_token(uid, token)`` streams tokens as they are produced (the
        first token of a request arrives during its admission prefill).
        The engine is reusable: each call drains its own request set and
        hands back exactly those results (uids must be unique per call).
        Validation is all-or-nothing: a bad request enqueues nothing.
        """
        requests = list(requests)  # tolerate generators: iterated 3 times
        self.scheduler.validate_batch(requests)
        # feed through the bounded queue: run() owns its whole request set,
        # so nothing is shed — the backlog drains as pending slots open
        backlog = deque(requests)
        while backlog or self.scheduler.busy:
            while backlog and self.scheduler.has_room:
                self.scheduler.enqueue_validated(backlog.popleft())
            while True:
                adm = self.scheduler.next_admission(self.cfg.prefill_batch,
                                                    reserve=self._reserve)
                if not adm:
                    break
                self._admit_batch(adm, on_token)
            if self.scheduler.active:
                self._fused_step(on_token)
        done, self.scheduler.finished = self.scheduler.finished, []
        by_uid: Dict[int, GenerationResult] = {r.uid: r for r in done}
        return [by_uid[r.uid] for r in requests]

    def try_submit(self, request: Request) -> bool:
        """Streaming-caller admission with explicit shed on overload:
        returns False (and counts the shed) when the bounded pending queue
        is full.  Invalid requests still raise — a malformed request is a
        caller bug, not an overload signal."""
        try:
            self.scheduler.submit(request)
            return True
        except QueueFull:
            self.stats.shed += 1
            return False

    def reset_stats(self) -> EngineStats:
        """Swap in a fresh stats accumulator (returns the old one)."""
        old, self.stats = self.stats, EngineStats()
        return old
