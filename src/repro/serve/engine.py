"""EngineCore / Replica: continuous batching over the DecodeState protocol.

The serving stack is four explicit layers, each independently testable:

* :class:`EngineCore` (here) — the pure device layer: jitted prefill /
  fused decode / sample executables plus the ``DecodeState`` cache.  No
  scheduler knowledge; slots arrive as plain integers.  ``prefill_batch``
  runs the shared ``(k, bucket)`` prefill + per-row ragged replay +
  multi-row insert and reports per-row :class:`PrefillOutcome`s;
  ``decode_step`` is the device half of the fused step.
* ``AdmissionPolicy`` (serve/policies.py) — who gets the next free slots:
  fcfs (the legacy behavior, bitwise), shortest-prompt-first,
  budget-packing.
* :class:`Replica` (here) — slot ownership, retirement and containment
  (the per-slot try/except rings, :class:`EngineStats`) around one core.
  A ``role="decode"`` replica delegates admission prefills to a
  ``role="prefill"`` partner's core; the stacked rows + first tokens land
  in the decode core via the same ``insert_many`` path.
* ``Router`` (serve/router.py) — a request front-end over N replicas.

One core serves every backbone family through the same three jitted
executables: per-bucket **prefill** (shape-keyed jit cache bounded by the
prompt ladder; up to ``SchedulerConfig.prefill_batch`` same-bucket
requests stack into one ``(k, bucket)`` call) + exact decode replay of
each request's sub-bucket remainder, slot **insert/evict** surgery on the
donated state buffer, and one **fused decode step** for all slots at once.

The loop is host-driven: admit pending requests into free slots, step the
fused decode, retire finished slots, backfill.  Greedy outputs are
tokenwise identical to running each request alone through the legacy
static-batch path (tests/test_serve_engine.py pins this for dense and
recurrent backbones), and — because each request's stream never depends on
batch composition — identical again under any router/policy/role split
(tests/test_router.py).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import model_zoo
from repro.serve import sampling
from repro.serve.policies import make_policy
from repro.serve.scheduler import (QueueFull, Scheduler, SchedulerConfig,
                                   prefill_split)
from repro.serve.state import SlotDecodeState
from repro.serve.types import (GenerationResult, PrefillOutcome,
                               ReplicaTelemetry, Request)

OnToken = Callable[[int, int], None]  # (request uid, token id)


# per-step decode latency samples kept for percentiles: a bounded ring,
# not a list — one float per fused step forever is a slow leak at
# production rates (a week at 100 steps/s is ~500 MB of pure bookkeeping)
STEP_TIME_WINDOW = 2048


@dataclass
class EngineStats:
    """Host wall-clock accounting for one replica lifetime."""

    prefill_s: float = 0.0
    prefill_tokens: int = 0
    decode_s: float = 0.0
    decode_steps: int = 0
    generated_tokens: int = 0
    admitted: int = 0
    step_times: Deque[float] = field(
        default_factory=lambda: deque(maxlen=STEP_TIME_WINDOW))
    # containment accounting: slots retired with reason="error" (the batch
    # kept going) and submissions shed at the bounded queue
    slot_errors: int = 0
    shed: int = 0

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / max(self.prefill_s, 1e-9)

    @property
    def decode_tok_s(self) -> float:
        """Useful fused-decode tokens per second of fused-decode wall time
        (each request's first token is emitted by its admission prefill and
        excluded here)."""
        return ((self.generated_tokens - self.admitted)
                / max(self.decode_s, 1e-9))

    def latency_percentile(self, p: float) -> float:
        """p-th percentile of per-step (== per-token) decode latency, s.

        Exact for runs up to ``STEP_TIME_WINDOW`` decode steps (every
        sample is still in the ring); beyond that it is the percentile of
        the trailing window — the production-relevant figure anyway."""
        if not self.step_times:
            return 0.0
        return float(np.percentile(
            np.fromiter(self.step_times, np.float64), p))


class EngineCore:
    """The pure device layer: jitted executables + the DecodeState cache.

    Knows nothing about schedulers, queues or retirement — callers hand it
    slot integers and it reports what the device did.  A
    ``role="prefill"`` core owns no slot cache at all (it only ever
    produces model-format rows for some other core's ``insert_rows``) and
    always uses the dense ``SlotDecodeState`` — prefill rows are dense
    model format regardless of how the decode side pages its pool.
    """

    def __init__(self, model, params, cfg: Optional[SchedulerConfig] = None,
                 rules=None, role: str = "both"):
        if role not in ("both", "prefill", "decode"):
            raise ValueError(f"unknown role {role!r}")
        self.model = model
        self.params = params
        self.cfg = cfg or SchedulerConfig()
        self.role = role
        if self.cfg.paged and role != "prefill":
            from repro.serve.paging import PagedDecodeState
            self.state = PagedDecodeState(
                model, page_size=self.cfg.page_size,
                n_pages=self.cfg.resolved_n_pages)
            # admission page budget: a request is only admitted once its
            # worst case (prompt + max_tokens) is reserved in the pool
            self.reserve = self.state.try_reserve
        else:
            self.state = SlotDecodeState(model)
            self.reserve = None
        self.ladder = self.cfg.ladder()
        if role == "prefill":
            self.cache = None
        else:
            self.cache = self.state.init_slots(self.cfg.n_slots,
                                               self.cfg.cache_len)
            if rules is not None:
                self.cache = jax.device_put(
                    self.cache,
                    self.state.shardings(rules, self.cfg.n_slots,
                                         self.cfg.cache_len))
        cache_len = self.cfg.cache_len
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))
        vocab = model.cfg.vocab_size
        self._sample = jax.jit(partial(sampling.sample_tokens,
                                       vocab_size=vocab))
        # fused-loop variant: per-slot base keys folded with the per-slot
        # token index *on device*, one executable call per step (no
        # host-side fold_in round-trips inside the timed decode loop)
        self._sample_at = jax.jit(
            lambda lg, keys, steps, t, k, p: sampling.sample_tokens(
                lg, jax.vmap(jax.random.fold_in)(keys, steps), t, k, p,
                vocab_size=vocab))
        # greedy fast path: all-greedy batches (the default) skip the
        # top-k/top-p sorts and the categorical draw entirely
        self._greedy = jax.jit(lambda lg: jnp.argmax(
            sampling.mask_vocab(lg, vocab), axis=-1).astype(jnp.int32))

    # -- sampling ------------------------------------------------------------
    def _first_token(self, req: Request, logits: jax.Array) -> int:
        """Sample the admission token from one request's (1, V) logits."""
        sp = req.sampling
        if sp.temperature <= 0.0:
            return int(self._greedy(logits)[0])
        key = sampling.step_key(
            sampling.request_key(sp.seed, req.uid), 0)[None]
        return int(self._sample(
            logits, key,
            jnp.full((1,), sp.temperature, jnp.float32),
            jnp.full((1,), sp.top_k, jnp.int32),
            jnp.full((1,), sp.top_p, jnp.float32))[0])

    # -- admission prefill ---------------------------------------------------
    def prefill_batch(self, admissions, target: Optional["EngineCore"] = None
                      ) -> List[PrefillOutcome]:
        """Prefill same-split requests as one ``(k, bucket)`` call and land
        the rows in ``target`` (default: this core).

        Every request in ``admissions`` must share a prefill split (the
        admission policy guarantees it), so their bucket prefixes stack
        into one jitted prefill — shape set bounded by
        ``(ladder U {1}) x prefill_batch``.  Ragged sub-bucket remainders
        then decode-replay per request on the sliced row cache — exact for
        every backbone — and the surviving rows land in their slots through
        one multi-row ``insert_many`` on the target core (the
        prefill→decode disaggregation handoff is exactly
        ``prefill_core.prefill_batch(adm, target=decode_core)``).

        Returns one :class:`PrefillOutcome` per admission row: either a
        first token or which device phase failed.  What to *do* about a
        failure (abort, free pages, count) is the Replica's decision.
        """
        target = target if target is not None else self
        reqs = [r for _, r in admissions]
        outcomes = [PrefillOutcome(slot=s, request=r) for s, r in admissions]
        try:
            split = prefill_split(reqs[0].prompt_len, self.ladder)
            toks = jnp.asarray([r.tokens[:split] for r in reqs], jnp.int32)
            logits, kcache = self._prefill(self.params, {"tokens": toks})
        except Exception:  # noqa: BLE001 — shared phase: all k rows fail
            for o in outcomes:
                o.error = "prefill"
            return outcomes
        row_logits = [logits[i:i + 1] for i in range(len(reqs))]
        if any(r.prompt_len > split for r in reqs):
            rows = [self.state.row(kcache, i) for i in range(len(reqs))]
            for i, r in enumerate(reqs):
                try:
                    full = jnp.asarray(r.tokens, jnp.int32)[None, :]
                    for j in range(split, r.prompt_len):
                        row_logits[i], rows[i] = self.state.decode(
                            self.params, rows[i], full[:, j:j + 1])
                except Exception:  # noqa: BLE001 — this request only
                    outcomes[i].error = "replay"
            live = [i for i in range(len(reqs)) if not outcomes[i].error]
            stacked = (self.state.stack_rows([rows[i] for i in live])
                       if live else None)
        else:
            live = list(range(len(reqs)))
            stacked = kcache
        if stacked is not None:
            target.insert_rows(
                np.asarray([outcomes[i].slot for i in live], np.int32),
                stacked)
        for i in live:
            try:
                outcomes[i].first_token = self._first_token(reqs[i],
                                                            row_logits[i])
            except Exception:  # noqa: BLE001 — per-request sampling fault
                outcomes[i].error = "sample"
        return outcomes

    # -- slot surgery --------------------------------------------------------
    def insert_rows(self, slots: np.ndarray, stacked) -> None:
        """Multi-row insert of stacked model-format rows into slots."""
        self.cache = self.state.insert_many(self.cache, slots, stacked)

    def evict(self, slot: int) -> None:
        """Clear one slot (and release its page reservation when paged —
        a no-op for dense states and for slots nothing was inserted into)."""
        self.cache = self.state.evict(self.cache, slot)

    def gather(self, slot: int):
        """Model-format row for one slot (the migration export path)."""
        return self.state.gather(self.cache, slot)

    # -- the fused decode step (device half) --------------------------------
    def decode_step(self, toks, keys, steps, temps, topk, topp,
                    all_greedy: bool) -> np.ndarray:
        """One fused decode + sample over all slots; returns the (n_slots,)
        next-token array.  Inactive rows compute garbage the caller never
        surfaces (their cache writes are dropped by the "active" mask)."""
        logits, self.cache = self.state.decode(self.params, self.cache,
                                               jnp.asarray(toks))
        if all_greedy:
            return np.asarray(self._greedy(logits))
        return np.asarray(self._sample_at(
            logits, jnp.asarray(keys), jnp.asarray(steps),
            jnp.asarray(temps), jnp.asarray(topk), jnp.asarray(topp)))

    # -- telemetry -----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Free pages in the paged pool; -1 for dense states."""
        alloc = getattr(self.state, "alloc", None)
        return alloc.free_page_count if alloc is not None else -1


class Replica:
    """Slot ownership + retirement + containment around one EngineCore.

    Owns the :class:`Scheduler`, the admission policy, and
    :class:`EngineStats`; every device phase runs inside a per-slot
    try/except ring so one poisoned request retires alone while the batch
    keeps going.

    Roles: ``"both"`` (the default — one core prefills and decodes),
    ``"decode"`` (admission prefills delegate to ``prefill_source``'s
    core; rows land here via ``insert_many``), ``"prefill"`` (core only —
    no scheduler, no slots; it exists to serve decode-role partners).
    """

    def __init__(self, model, params, cfg: Optional[SchedulerConfig] = None,
                 rules=None, role: str = "both",
                 prefill_source: Optional["Replica"] = None, name: str = ""):
        self.cfg = cfg or SchedulerConfig()
        self.role = role
        self.name = name or role
        self.stats = EngineStats()
        self.core = EngineCore(model, params, self.cfg, rules=rules,
                               role=role)
        # optional per-step metrics hook (launch/serve.py --metrics-jsonl)
        self.on_step_metrics: Optional[Callable[[dict], None]] = None
        self.prefill_replica: Optional["Replica"] = None
        if role == "prefill":
            if prefill_source is not None:
                raise ValueError("a prefill-role replica cannot have a "
                                 "prefill_source")
            self.scheduler = None
            self.policy = None
            self.prefill_core = self.core
            return
        if prefill_source is not None:
            if role != "decode":
                raise ValueError("prefill_source requires role='decode'")
            self.prefill_replica = prefill_source
            self.prefill_core = prefill_source.core
        else:
            if role == "decode":
                raise ValueError("role='decode' requires a prefill_source")
            self.prefill_core = self.core
        self.scheduler = Scheduler(self.cfg)
        self.policy = make_policy(self.cfg)
        # fused-step staging, preallocated once and refreshed in place:
        # rebuilding six (n_slots,) arrays every decode step was measurable
        # host churn at small-model decode rates.  Stale entries in rows no
        # longer active are harmless — per-slot sampling is independent,
        # inactive cache writes are dropped, and inactive outputs are never
        # surfaced.
        n = self.cfg.n_slots
        self._toks = np.zeros((n, 1), np.int32)
        self._temps = np.zeros((n,), np.float32)
        self._topk = np.zeros((n,), np.int32)
        self._topp = np.ones((n,), np.float32)
        self._keys = np.zeros((n, 2), np.uint32)
        self._steps = np.zeros((n,), np.int32)

    # -- construction helpers ----------------------------------------------
    @classmethod
    def from_arch(cls, arch: str, use_reduced: bool = True, seed: int = 0,
                  cfg: Optional[SchedulerConfig] = None,
                  decode_backend: Optional[str] = None, **kw) -> "Replica":
        from repro.configs import get_arch, reduced as reduce_cfg
        spec = get_arch(arch)
        mcfg = reduce_cfg(spec.model) if use_reduced else spec.model
        if decode_backend:
            mcfg = mcfg.replace(decode_backend=decode_backend)
        model = model_zoo.build_model(mcfg, dtype=jnp.float32, remat="none")
        params = model_zoo.init_params(jax.random.PRNGKey(seed), mcfg)
        return cls(model, params, cfg=cfg, **kw)

    # -- compatibility surface (the pre-split InferenceEngine monolith) ----
    # Tests and callers reach into the device layer through the replica;
    # property setters keep instance-level monkeypatching working by
    # forwarding onto the core.
    @property
    def model(self):
        return self.core.model

    @property
    def params(self):
        return self.core.params

    @property
    def state(self):
        return self.core.state

    @property
    def cache(self):
        return self.core.cache

    @cache.setter
    def cache(self, value):
        self.core.cache = value

    @property
    def _prefill(self):
        return self.core._prefill

    @_prefill.setter
    def _prefill(self, fn):
        self.core._prefill = fn

    @property
    def _first_token(self):
        return self.core._first_token

    @_first_token.setter
    def _first_token(self, fn):
        self.core._first_token = fn

    # -- admission -----------------------------------------------------------
    def _admit_batch(self, admissions, on_token: Optional[OnToken]) -> None:
        """Admit same-split requests through the prefill core; activate,
        abort or retire each row per its :class:`PrefillOutcome`.
        Per-request ``prefill_s`` reports the batch wall time amortized
        over the rows that survived."""
        t0 = time.time()
        outcomes = self.prefill_core.prefill_batch(admissions,
                                                   target=self.core)
        if all(o.error == "prefill" for o in outcomes):
            # shared phase failed: all k slots abort, no timing accounted
            # (nothing was inserted; evict still releases page reservations)
            for o in outcomes:
                self.core.evict(o.slot)
                self.scheduler.abort(o.slot, o.request)
                self.stats.slot_errors += 1
            return
        dt = time.time() - t0
        n_ok = sum(1 for o in outcomes if not o.error)
        self.stats.prefill_s += dt
        self.stats.prefill_tokens += sum(o.request.prompt_len
                                         for o in outcomes if not o.error)
        self.stats.admitted += n_ok
        self.stats.generated_tokens += n_ok
        if self.prefill_replica is not None:
            # disaggregated: the prefill partner did the device work —
            # mirror the prefill accounting onto its stats too
            self.prefill_replica.stats.prefill_s += dt
            self.prefill_replica.stats.prefill_tokens += sum(
                o.request.prompt_len for o in outcomes if not o.error)
        for o in outcomes:
            if o.error:
                # the failing request retires alone; the evict clears its
                # cache row if one was inserted (sampling failed after
                # insert_many) and releases its page reservation either
                # way — the rest of the batch proceeds
                self.core.evict(o.slot)
                self.scheduler.abort(o.slot, o.request)
                self.stats.slot_errors += 1
                continue
            st = self.scheduler.activate(o.slot, o.request, o.first_token,
                                         dt / max(n_ok, 1))
            try:
                if on_token:
                    on_token(o.request.uid, o.first_token)
                reason = self.scheduler.stop_reason(st)
            except Exception:  # noqa: BLE001 — consumer callback fault
                self._retire(o.slot, "error")
                self.stats.slot_errors += 1
                continue
            if reason:
                self._retire(o.slot, reason)

    def _retire(self, slot: int, reason: str) -> GenerationResult:
        self.core.evict(slot)
        res = self.scheduler.finish(slot, reason)
        res.decode_steps = max(len(res.tokens) - 1, 0)
        return res

    def admit(self, on_token: Optional[OnToken] = None) -> bool:
        """One admission round under the configured policy; False when
        nothing was admissible."""
        adm = self.policy.select(self.scheduler, self.cfg.prefill_batch,
                                 reserve=self.core.reserve)
        if not adm:
            return False
        self._admit_batch(adm, on_token)
        return True

    # -- the fused decode step ---------------------------------------------
    def step(self, on_token: Optional[OnToken] = None) -> None:
        """One fused decode step over the active slots: refresh the staging
        buffers in place, run the device half, append/stream/retire."""
        toks, temps, topk = self._toks, self._temps, self._topk
        topp, keys, steps = self._topp, self._keys, self._steps
        active_now: List[tuple] = list(self.scheduler.active.items())
        all_greedy = True
        for slot, st in active_now:
            sp = st.request.sampling
            toks[slot, 0] = st.last_token
            temps[slot] = sp.temperature
            topk[slot] = sp.top_k
            topp[slot] = sp.top_p
            if sp.temperature > 0.0:
                all_greedy = False
                keys[slot] = st.base_key
                steps[slot] = st.n_generated
        t0 = time.time()
        nxt = self.core.decode_step(toks, keys, steps, temps, topk, topp,
                                    all_greedy)
        dt = time.time() - t0
        self.stats.step_times.append(dt)
        self.stats.decode_s += dt
        self.stats.decode_steps += 1
        self.stats.generated_tokens += len(active_now)
        for slot, st in active_now:
            try:
                tok = int(nxt[slot])
                st.result.tokens.append(tok)
                st.last_token = tok
                if on_token:
                    on_token(st.request.uid, tok)
                reason = self.scheduler.stop_reason(st)
            except Exception:  # noqa: BLE001 — retire only this slot; the
                self._retire(slot, "error")  # rest of the batch finishes
                self.stats.slot_errors += 1
                continue
            if reason:
                self._retire(slot, reason)
        if self.on_step_metrics is not None:
            self.on_step_metrics(self.metrics_row(dt))

    # -- driver --------------------------------------------------------------
    def pump(self, on_token: Optional[OnToken] = None) -> bool:
        """Admit everything admissible, then one fused step if anything is
        active.  Returns whether any progress was made (the router's
        drain-loop termination signal)."""
        progressed = False
        while self.admit(on_token):
            progressed = True
        if self.scheduler.active:
            self.step(on_token)
            progressed = True
        return progressed

    def run(self, requests: Sequence[Request],
            on_token: Optional[OnToken] = None) -> List[GenerationResult]:
        """Generate for all ``requests``; returns results in request order.

        ``on_token(uid, token)`` streams tokens as they are produced (the
        first token of a request arrives during its admission prefill).
        The replica is reusable: each call drains its own request set and
        hands back exactly those results (uids must be unique per call).
        Validation is all-or-nothing: a bad request enqueues nothing.
        """
        requests = list(requests)  # tolerate generators: iterated 3 times
        self.scheduler.validate_batch(requests)
        # feed through the bounded queue: run() owns its whole request set,
        # so nothing is shed — the backlog drains as pending slots open
        backlog = deque(requests)
        while backlog or self.scheduler.busy:
            while backlog and self.scheduler.has_room:
                self.scheduler.enqueue_validated(backlog.popleft())
            self.pump(on_token)
        done = self.take_finished()
        by_uid: Dict[int, GenerationResult] = {r.uid: r for r in done}
        return [by_uid[r.uid] for r in requests]

    def try_submit(self, request: Request) -> bool:
        """Streaming-caller admission with explicit shed on overload:
        returns False (and counts the shed) when the bounded pending queue
        is full.  Invalid requests still raise — a malformed request is a
        caller bug, not an overload signal."""
        try:
            self.scheduler.submit(request)
            return True
        except QueueFull:
            self.stats.shed += 1
            return False

    def take_finished(self) -> List[GenerationResult]:
        """Drain and return the finished-result list (router collection)."""
        done, self.scheduler.finished = self.scheduler.finished, []
        return done

    # -- migration -----------------------------------------------------------
    def migrate_slot_to(self, slot: int, other: "Replica") -> int:
        """Move one active slot — device row + host bookkeeping — onto
        ``other``; returns the destination slot.  The token stream
        continues identically on the destination (tests/test_router.py
        pins this), which is what makes live rebalancing safe."""
        from repro.distributed.collectives import migrate_row
        if slot not in self.scheduler.active:
            raise KeyError(f"slot {slot} is not active")
        if not other.scheduler.free:
            raise RuntimeError("destination replica has no free slot")
        st = self.scheduler.active[slot]
        dst_slot = other.scheduler.free[-1]
        if other.core.reserve is not None and \
                not other.core.reserve(dst_slot, st.request):
            raise RuntimeError("destination replica cannot reserve pages")
        other.scheduler.free.pop()
        self.core.cache, other.core.cache = migrate_row(
            self.core.state, self.core.cache, slot,
            other.core.state, other.core.cache, dst_slot,
            cache_len=other.cfg.cache_len)
        del self.scheduler.active[slot]
        self.scheduler.free.append(slot)
        other.scheduler.active[dst_slot] = st
        return dst_slot

    # -- telemetry -----------------------------------------------------------
    def telemetry(self) -> ReplicaTelemetry:
        """Admission telemetry snapshot for the router's routing score."""
        return ReplicaTelemetry(
            name=self.name,
            queue_depth=len(self.scheduler.pending),
            active=len(self.scheduler.active),
            free_slots=len(self.scheduler.free),
            free_pages=self.core.free_pages,
            p95_step_s=self.stats.latency_percentile(95))

    def metrics_row(self, step_s: float) -> dict:
        """One JSONL-able per-step metrics row (--metrics-jsonl)."""
        s = self.stats
        return {
            "replica": self.name,
            "decode_step": s.decode_steps,
            "step_s": step_s,
            "active": len(self.scheduler.active),
            "queue_depth": len(self.scheduler.pending),
            "free_slots": len(self.scheduler.free),
            "free_pages": self.core.free_pages,
            "generated_tokens": s.generated_tokens,
            "admitted": s.admitted,
            "slot_errors": s.slot_errors,
            "shed": s.shed,
            "p50_s": s.latency_percentile(50),
            "p95_s": s.latency_percentile(95),
        }

    def reset_stats(self) -> EngineStats:
        """Swap in a fresh stats accumulator (returns the old one)."""
        old, self.stats = self.stats, EngineStats()
        return old


class InferenceEngine(Replica):
    """Single-host continuous-batching engine: a ``role="both"`` Replica.

    Kept as the stable public name — and as the single-engine parity
    oracle the router tests compare against.  The disaggregated stack
    composes the same layers explicitly (EngineCore / Replica / Router;
    see serve/router.py)."""
