"""Unified inference subsystem: continuous batching over one DecodeState
protocol for every backbone (transformer / MoE / Mamba-2 / RWKV-6 / Zamba-2).

    from repro.serve import InferenceEngine, Request, SamplingParams

    engine = InferenceEngine.from_arch("gpt2-117m", use_reduced=True)
    results = engine.run([Request(uid=0, tokens=(1, 2, 3), max_tokens=16)])
"""
from repro.serve.engine import EngineStats, InferenceEngine
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import Scheduler, SchedulerConfig, prefill_split
from repro.serve.state import DecodeState, SlotDecodeState
from repro.serve.types import GenerationResult, Request, SamplingParams

__all__ = [
    "DecodeState", "EngineStats", "GenerationResult", "InferenceEngine",
    "Request", "SamplingParams", "Scheduler", "SchedulerConfig",
    "SlotDecodeState", "prefill_split", "sample_tokens",
]
