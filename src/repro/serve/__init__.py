"""Unified inference subsystem: continuous batching over one DecodeState
protocol for every backbone (transformer / MoE / Mamba-2 / RWKV-6 / Zamba-2).

    from repro.serve import InferenceEngine, Request, SamplingParams

    engine = InferenceEngine.from_arch("gpt2-117m", use_reduced=True)
    results = engine.run([Request(uid=0, tokens=(1, 2, 3), max_tokens=16)])
"""
from repro.serve.engine import EngineStats, InferenceEngine
from repro.serve.paging import (PageAllocator, PagedDecodeState,
                                PageExhausted, cache_nbytes)
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import Scheduler, SchedulerConfig, prefill_split
from repro.serve.state import DecodeState, SlotDecodeState
from repro.serve.types import GenerationResult, Request, SamplingParams

__all__ = [
    "DecodeState", "EngineStats", "GenerationResult", "InferenceEngine",
    "PageAllocator", "PagedDecodeState", "PageExhausted", "Request",
    "SamplingParams", "Scheduler", "SchedulerConfig", "SlotDecodeState",
    "cache_nbytes", "prefill_split", "sample_tokens",
]
