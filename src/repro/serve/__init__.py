"""Unified inference subsystem: continuous batching over one DecodeState
protocol for every backbone (transformer / MoE / Mamba-2 / RWKV-6 / Zamba-2).

    from repro.serve import InferenceEngine, Request, SamplingParams

    engine = InferenceEngine.from_arch("gpt2-117m", use_reduced=True)
    results = engine.run([Request(uid=0, tokens=(1, 2, 3), max_tokens=16)])

The serving stack is layered (see serve/engine.py): ``EngineCore`` (pure
device layer) / ``AdmissionPolicy`` (serve/policies.py) / ``Replica``
(slot lifecycle + containment) / ``Router`` (serve/router.py, N-replica
front-end).  ``InferenceEngine`` is the single-host composition of the
first three and the tokenwise-parity oracle for the rest.
"""
from repro.serve.engine import (EngineCore, EngineStats, InferenceEngine,
                                Replica)
from repro.serve.paging import (PageAllocator, PagedDecodeState,
                                PageExhausted, cache_nbytes)
from repro.serve.policies import (POLICIES, AdmissionPolicy,
                                  BudgetPackingPolicy, FCFSPolicy,
                                  ShortestPromptFirstPolicy, make_policy)
from repro.serve.router import Router, RouterStats, make_replicas
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import (QueueFull, Scheduler, SchedulerConfig,
                                   prefill_split)
from repro.serve.state import DecodeState, SlotDecodeState
from repro.serve.types import (GenerationResult, PrefillOutcome,
                               ReplicaTelemetry, Request, SamplingParams)

__all__ = [
    "AdmissionPolicy", "BudgetPackingPolicy", "DecodeState", "EngineCore",
    "EngineStats", "FCFSPolicy", "GenerationResult", "InferenceEngine",
    "POLICIES", "PageAllocator", "PagedDecodeState", "PageExhausted",
    "PrefillOutcome", "QueueFull", "Replica", "ReplicaTelemetry", "Request",
    "Router", "RouterStats", "SamplingParams", "Scheduler",
    "SchedulerConfig", "ShortestPromptFirstPolicy", "SlotDecodeState",
    "cache_nbytes", "make_policy", "make_replicas", "prefill_split",
    "sample_tokens",
]
