"""Router: a request front-end over N serving replicas.

The outermost layer of the disaggregated stack (EngineCore / Replica /
Router): routes each request to one replica by per-replica admission
telemetry (queue depth, free slots/pages, trailing p95 step latency),
spills to the next replica on ``QueueFull``, sheds explicitly when every
replica is full, and aggregates per-replica :class:`EngineStats` into
:class:`RouterStats`.

Routing modes: ``"least-loaded"`` (fewest requests in flight, ties break
on replica order) and ``"round-robin"``.  Tokenwise parity with a single
engine is structural, not incidental: greedy/seeded streams are
per-request functions of (params, prompt, sampling) and never of batch
composition, so any routing decision yields identical tokens —
tests/test_router.py pins this across policies, paged + dense replicas,
and disaggregated role splits.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.serve.engine import Replica
from repro.serve.scheduler import QueueFull, SchedulerConfig
from repro.serve.types import GenerationResult, ReplicaTelemetry, Request

OnToken = Callable[[int, int], None]

ROUTES = ("least-loaded", "round-robin")


@dataclass
class RouterStats:
    """Front-end accounting: where requests landed and what bounced.

    ``routed[name]`` counts acceptances per replica; ``spilled`` counts
    requests that bounced off at least one full replica before landing;
    ``shed`` counts requests every replica refused (the caller's 429).
    Per-replica engine accounting stays on each replica's ``stats``.
    """

    routed: Dict[str, int] = field(default_factory=dict)
    spilled: int = 0
    shed: int = 0

    @property
    def total_routed(self) -> int:
        return sum(self.routed.values())


class Router:
    """Route requests over N replicas; drive them; merge their results."""

    def __init__(self, replicas: Sequence[Replica],
                 route: str = "least-loaded"):
        if not replicas:
            raise ValueError("need at least one replica")
        if route not in ROUTES:
            raise ValueError(f"unknown route {route!r} "
                             f"(want one of {ROUTES})")
        for rep in replicas:
            if rep.role == "prefill":
                raise ValueError(
                    f"replica {rep.name!r} has role='prefill': route to "
                    f"serving replicas (role 'both'/'decode'); prefill "
                    f"workers are reached through their decode partner")
        self.replicas = list(replicas)
        self.route = route
        self._rr = 0
        self.stats = RouterStats()

    # -- telemetry -----------------------------------------------------------
    def telemetry(self) -> List[ReplicaTelemetry]:
        return [rep.telemetry() for rep in self.replicas]

    @property
    def busy(self) -> bool:
        return any(rep.scheduler.busy for rep in self.replicas)

    # -- routing -------------------------------------------------------------
    def _candidates(self) -> List[Replica]:
        """Replicas in routing-preference order for one request."""
        if self.route == "round-robin":
            n = len(self.replicas)
            order = [self.replicas[(self._rr + i) % n] for i in range(n)]
            self._rr = (self._rr + 1) % n
            return order
        scored = sorted(range(len(self.replicas)),
                        key=lambda i: (self.replicas[i].telemetry().load, i))
        return [self.replicas[i] for i in scored]

    def _try_route(self, request: Request, count_shed: bool) -> bool:
        spilled = False
        for rep in self._candidates():
            try:
                rep.scheduler.submit(request)
            except QueueFull:
                spilled = True
                continue
            if spilled:
                self.stats.spilled += 1
            self.stats.routed[rep.name] = \
                self.stats.routed.get(rep.name, 0) + 1
            return True
        if count_shed:
            self.stats.shed += 1
        return False

    def submit(self, request: Request) -> bool:
        """Route one request; False (counted as shed) when every replica's
        queue is full.  Invalid requests raise — malformed input is a
        caller bug, not an overload signal."""
        return self._try_route(request, count_shed=True)

    # -- driver --------------------------------------------------------------
    def pump(self, on_token: Optional[OnToken] = None) -> bool:
        """One admission + decode round on every replica."""
        progressed = False
        for rep in self.replicas:
            progressed = rep.pump(on_token) or progressed
        return progressed

    def take_finished(self) -> List[GenerationResult]:
        out: List[GenerationResult] = []
        for rep in self.replicas:
            out.extend(rep.take_finished())
        return out

    def run(self, requests: Sequence[Request],
            on_token: Optional[OnToken] = None) -> List[GenerationResult]:
        """Generate for all ``requests`` across the fleet; results come
        back in request order.  Validation is all-or-nothing and a routable
        request must be valid on *every* replica (heterogeneous fleets
        admit only the intersection — the router may send it anywhere).
        Nothing is shed: a backlog head that no replica can queue right
        now simply waits for the next pump round.
        """
        requests = list(requests)
        uids = set()
        for r in requests:
            if r.uid in uids:
                raise ValueError(f"request uid {r.uid} duplicated")
            uids.add(r.uid)
        for rep in self.replicas:
            rep.scheduler.validate_batch(requests)
        backlog = deque(requests)
        done: Dict[int, GenerationResult] = {}
        while backlog or self.busy:
            while backlog and self._try_route(backlog[0], count_shed=False):
                backlog.popleft()
            self.pump(on_token)
            for res in self.take_finished():
                done[res.uid] = res
        for res in self.take_finished():
            done[res.uid] = res
        return [done[r.uid] for r in requests]

    # -- aggregation ---------------------------------------------------------
    def summary(self) -> dict:
        """Aggregated fleet accounting (CLI report / metrics JSONL tail)."""
        agg = {"generated_tokens": 0, "admitted": 0, "decode_steps": 0,
               "prefill_s": 0.0, "decode_s": 0.0, "slot_errors": 0,
               "replica_shed": 0}
        per = {}
        for rep in self.replicas:
            s = rep.stats
            agg["generated_tokens"] += s.generated_tokens
            agg["admitted"] += s.admitted
            agg["decode_steps"] += s.decode_steps
            agg["prefill_s"] += s.prefill_s
            agg["decode_s"] += s.decode_s
            agg["slot_errors"] += s.slot_errors
            agg["replica_shed"] += s.shed
            per[rep.name] = {
                "generated_tokens": s.generated_tokens,
                "admitted": s.admitted,
                "decode_tok_s": s.decode_tok_s,
                "p95_step_s": s.latency_percentile(95),
                "slot_errors": s.slot_errors,
            }
        return {"routed": dict(self.stats.routed),
                "spilled": self.stats.spilled,
                "shed": self.stats.shed,
                "aggregate": agg,
                "replicas": per}


def make_replicas(model, params, cfg: SchedulerConfig, n_replicas: int, *,
                  rules=None, disaggregate: bool = False,
                  policies: Optional[Sequence[str]] = None
                  ) -> List[Replica]:
    """Build a homogeneous fleet sharing one set of params.

    ``disaggregate=True`` builds each serving unit as a prefill-role +
    decode-role pair (Lamy-Poirier-style phase split: the compute-bound
    prefill worker feeds the memory-bound decode worker through the
    ``insert_many`` handoff); the returned list holds the decode replicas —
    the routable side — each with its partner at ``.prefill_replica``.
    ``policies`` optionally overrides ``cfg.policy`` per replica
    (cycled when shorter than the fleet).
    """
    if n_replicas < 1:
        raise ValueError(f"need n_replicas >= 1, got {n_replicas}")
    reps: List[Replica] = []
    for i in range(n_replicas):
        rcfg = cfg
        if policies:
            rcfg = dataclasses.replace(cfg, policy=policies[i % len(policies)])
        if disaggregate:
            pre = Replica(model, params, rcfg, rules=rules, role="prefill",
                          name=f"prefill{i}")
            reps.append(Replica(model, params, rcfg, rules=rules,
                                role="decode", prefill_source=pre,
                                name=f"decode{i}"))
        else:
            reps.append(Replica(model, params, rcfg, rules=rules,
                                name=f"replica{i}"))
    return reps
