"""Pluggable admission policies: who gets the next free slots.

Extracted from ``Scheduler.next_admission`` — which remains the FCFS
primitive; the ``fcfs`` policy delegates to it verbatim, so the default
path is bitwise the pre-refactor behavior, including the paged
strict-FCFS reserve gate.  A policy returns up to ``k`` (slot, request)
pairs that **share one prefill split** (the engine stacks them into a
single ``(k, bucket)`` prefill call) and honors the ``reserve``
page-budget hook.

Contracts every implementation must keep (pinned by the property tests in
tests/test_router.py):

* work-conserving, no starvation: under sustained load every pending
  request is eventually admitted (shortest-prompt-first ages skipped
  requests into forced heads; the other two keep a strict-FCFS head);
* same-split batches only — the shared ``(k, bucket)`` prefill requires
  every admitted row to quantize to the head's split;
* reserve gating: a pair is emitted only after ``reserve(slot, request)``
  accepted it, and a blocked *head* returns ``[]`` with the queue
  untouched (the head waits for retiring slots to free pages rather than
  being jumped).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Protocol, Tuple, \
    runtime_checkable

from repro.serve.scheduler import Scheduler, SchedulerConfig, prefill_split
from repro.serve.types import Request

Reserve = Optional[Callable[[int, Request], bool]]


@runtime_checkable
class AdmissionPolicy(Protocol):
    """Decides which pending requests occupy which free slots."""

    name: str

    def select(self, scheduler: Scheduler, k: int, reserve: Reserve = None
               ) -> List[Tuple[int, Request]]:
        """Pop up to ``k`` same-split (slot, request) pairs off
        ``scheduler.pending``/``scheduler.free``; [] admits nothing."""
        ...


class FCFSPolicy:
    """Strict first-come-first-served: delegates to
    ``Scheduler.next_admission`` verbatim (same-split pull-forward, paged
    head gate and all), so single-replica fcfs is the legacy engine."""

    name = "fcfs"

    def select(self, scheduler: Scheduler, k: int, reserve: Reserve = None
               ) -> List[Tuple[int, Request]]:
        return scheduler.next_admission(k, reserve=reserve)


class ShortestPromptFirstPolicy:
    """Admit the shortest pending prompt first.

    Minimizes head-of-line blocking from long prefills (the serving-side
    face of the paper's sequence-length-heterogeneity cost); same-split
    pull-forward fills the batch shortest-first.  Skipped requests age:
    once a request has been passed over ``age_limit`` times it becomes the
    forced head, so a long prompt cannot starve under a stream of short
    arrivals.
    """

    name = "shortest-prompt-first"

    def __init__(self, age_limit: int = 16):
        if age_limit < 1:
            raise ValueError(f"need age_limit >= 1, got {age_limit}")
        self.age_limit = age_limit
        self._skips: Dict[int, int] = {}

    def select(self, scheduler: Scheduler, k: int, reserve: Reserve = None
               ) -> List[Tuple[int, Request]]:
        pend = scheduler.pending
        if not pend or not scheduler.free:
            return []
        head_i = None
        for i in range(len(pend)):  # oldest over-aged request wins
            if self._skips.get(pend[i].uid, 0) >= self.age_limit:
                head_i = i
                break
        if head_i is None:
            head_i = min(range(len(pend)),
                         key=lambda i: (pend[i].prompt_len, i))
        head = pend[head_i]
        if reserve is not None and not reserve(scheduler.free[-1], head):
            return []  # the chosen head waits; queue untouched
        del pend[head_i]
        out = [(scheduler.free.pop(), head)]
        if k > 1 and pend and scheduler.free:
            split = prefill_split(head.prompt_len, scheduler.ladder)
            cands = sorted(
                (i for i in range(len(pend))
                 if prefill_split(pend[i].prompt_len,
                                  scheduler.ladder) == split),
                key=lambda i: (pend[i].prompt_len, i))
            taken: List[int] = []
            for i in cands:
                if len(out) >= k or not scheduler.free:
                    break
                r = pend[i]
                if reserve is not None and \
                        not reserve(scheduler.free[-1], r):
                    continue
                out.append((scheduler.free.pop(), r))
                taken.append(i)
            for i in sorted(taken, reverse=True):
                del pend[i]
        for r in pend:
            self._skips[r.uid] = self._skips.get(r.uid, 0) + 1
        for _, r in out:
            self._skips.pop(r.uid, None)
        return out


class BudgetPackingPolicy:
    """FCFS head + same-split packing under a token budget (Lau et
    al.-style adaptive batch composition).

    The head always admits in queue order — keeping the strict-FCFS
    no-starvation guarantee and the paged head gate — then pending
    requests are pulled forward in queue order while the admission round's
    total worst-case footprint (``prompt_len + max_tokens`` per request)
    stays within ``budget``.  One giant batchmate can no longer blow the
    round's page/step-token footprint: it simply waits for a round whose
    budget it fits.
    """

    name = "budget-packing"

    def __init__(self, budget: int):
        if budget < 1:
            raise ValueError(f"need budget >= 1, got {budget}")
        self.budget = budget

    @staticmethod
    def _need(r: Request) -> int:
        return r.prompt_len + r.max_tokens

    def select(self, scheduler: Scheduler, k: int, reserve: Reserve = None
               ) -> List[Tuple[int, Request]]:
        pend = scheduler.pending
        if not pend or not scheduler.free:
            return []
        if reserve is not None and not reserve(scheduler.free[-1], pend[0]):
            return []
        head = pend.popleft()
        out = [(scheduler.free.pop(), head)]
        spent = self._need(head)
        if k > 1:
            split = prefill_split(head.prompt_len, scheduler.ladder)
            skipped: List[Request] = []
            while pend and scheduler.free and len(out) < k:
                r = pend.popleft()
                if prefill_split(r.prompt_len, scheduler.ladder) != split \
                        or spent + self._need(r) > self.budget:
                    skipped.append(r)
                    continue
                if reserve is not None and \
                        not reserve(scheduler.free[-1], r):
                    skipped.append(r)
                    continue
                out.append((scheduler.free.pop(), r))
                spent += self._need(r)
            pend.extendleft(reversed(skipped))
        return out


POLICIES = ("fcfs", "shortest-prompt-first", "budget-packing")


def make_policy(cfg: SchedulerConfig) -> AdmissionPolicy:
    """Instantiate the policy named by ``cfg.policy``.

    One instance per Replica — shortest-prompt-first carries per-queue
    aging state that must not be shared across replicas.
    """
    name = cfg.policy
    if name == "fcfs":
        return FCFSPolicy()
    if name in ("shortest-prompt-first", "spf"):
        return ShortestPromptFirstPolicy()
    if name in ("budget-packing", "budget"):
        return BudgetPackingPolicy(cfg.resolved_pack_budget)
    raise ValueError(
        f"unknown admission policy {name!r} (want one of {POLICIES})")
