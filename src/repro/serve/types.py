"""Typed serving API surface: Request -> GenerationResult.

Frozen dataclasses so request/sampling configurations are hashable and safe
to log, diff and replay.  ``SamplingParams`` defaults to greedy decoding
(``temperature == 0``), which is the mode the engine-vs-legacy parity tests
pin down tokenwise.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class SamplingParams:
    """Pure-function-of-logits sampling configuration (see serve.sampling).

    temperature == 0 selects greedy argmax (rng unused); top_k == 0 and
    top_p == 1.0 disable the respective truncations.  ``seed`` derives the
    per-request PRNG stream — results are reproducible independently of
    batch composition or admission order.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop_token: Optional[int] = None

    def replace(self, **kw) -> "SamplingParams":
        import dataclasses
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class Request:
    """One generation request: prompt tokens + a generation budget."""

    uid: int
    tokens: Tuple[int, ...]
    max_tokens: int = 16
    sampling: SamplingParams = field(default_factory=SamplingParams)

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclass
class PrefillOutcome:
    """Per-row result of one ``EngineCore.prefill_batch`` call.

    The device layer reports *which phase* failed for *which row*
    (``error`` in ``"" | "prefill" | "replay" | "sample"``); what to do
    about it — abort, retire, count — is the ``Replica`` layer's call.
    A ``"prefill"`` error means the shared ``(k, bucket)`` phase failed,
    so every row of the admission carries it.
    """

    slot: int
    request: "Request"
    first_token: Optional[int] = None
    error: str = ""  # "" = ok | "prefill" | "replay" | "sample"


@dataclass(frozen=True)
class ReplicaTelemetry:
    """Admission telemetry one replica exposes to the router.

    ``free_pages`` is ``-1`` for dense (non-paged) replicas; ``p95_step_s``
    is the trailing p95 fused-step latency from the stats ring.
    """

    name: str
    queue_depth: int
    active: int
    free_slots: int
    free_pages: int
    p95_step_s: float

    @property
    def load(self) -> int:
        """Requests in flight (queued + decoding) — the least-loaded
        routing score.  Ties break on replica order, so an idle fleet
        fills deterministically."""
        return self.queue_depth + self.active


@dataclass
class GenerationResult:
    """Completed (or in-flight) generation for one request."""

    uid: int
    prompt_len: int
    tokens: list = field(default_factory=list)
    finish_reason: str = ""  # length | stop_token | aborted | error
    # engine accounting (host wall-clock, seconds)
    prefill_s: float = 0.0
    decode_steps: int = 0

    @property
    def n_generated(self) -> int:
        return len(self.tokens)
