"""DecodeState: one slot-addressable interface over every backbone's cache.

The transformer/MoE KV cache, the Mamba-2 and RWKV-6 recurrent states and
the Zamba-2 hybrid cache all reduce to the same shape discipline: a pytree
whose leaves carry a "batch" logical axis (the *slot* axis) plus a per-slot
``pos`` vector.  ``SlotDecodeState`` implements the protocol generically
from each model's ``cache_shapes``/``cache_axes`` contract — no per-family
branches — with ``insert``/``evict``/``decode`` jitted and the state buffer
donated, so slot surgery happens in place on the accelerator.
"""
from __future__ import annotations

from typing import Any, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import is_axes_leaf
from repro.models import model_zoo


class DecodeState(Protocol):
    """Slot-addressable decode cache for continuous batching."""

    def init_slots(self, n_slots: int, cache_len: int) -> Any:
        """Allocate a zeroed ``n_slots``-wide cache."""

    def insert(self, cache: Any, slot: jax.Array, prefill_cache: Any) -> Any:
        """Scatter one request's batch=1 prefill cache into ``slot``."""

    def evict(self, cache: Any, slot: jax.Array) -> Any:
        """Retire ``slot`` (resets its position bookkeeping)."""

    def gather(self, cache: Any, slot: jax.Array) -> Any:
        """Extract ``slot``'s state as a batch=1 cache (slot migration)."""

    def decode(self, params: Any, cache: Any, tokens: jax.Array
               ) -> Tuple[jax.Array, Any]:
        """One fused decode step for all slots; per-slot positions."""


def _tree_map_axes(fn, axes_tree, *trees):
    return jax.tree_util.tree_map(fn, axes_tree, *trees,
                                  is_leaf=is_axes_leaf)


class SlotDecodeState:
    """Generic ``DecodeState`` over any model with the uniform cache API.

    ``slot`` arguments are traced int32 scalars, so one compiled
    insert/evict executable serves every slot index.
    """

    def __init__(self, model):
        self.model = model
        self._axes = model.cache_axes()  # original axes ("pos" leaves = ())
        self.slot_axes = model_zoo.decode_cache_axes(model)

        def insert_fn(cache, slot, one):
            def leaf(ax, c, p):
                if "batch" in ax:
                    return jax.lax.dynamic_update_slice_in_dim(
                        c, p.astype(c.dtype), slot, axis=ax.index("batch"))
                # promoted bookkeeping leaf: scalar -> per-slot vector
                return jax.lax.dynamic_update_slice_in_dim(
                    c, jnp.asarray(p)[None].astype(c.dtype), slot, axis=0)
            return _tree_map_axes(leaf, self._axes, cache, one)

        def evict_fn(cache, slot):
            def leaf(ax, c):
                if "batch" in ax:
                    return c  # rows are overwritten wholesale on next insert
                zero = jnp.zeros((1,) + c.shape[1:], c.dtype)
                return jax.lax.dynamic_update_slice_in_dim(c, zero, slot,
                                                           axis=0)
            return _tree_map_axes(leaf, self._axes, cache)

        def gather_fn(cache, slot):
            def leaf(ax, c):
                if "batch" in ax:
                    return jax.lax.dynamic_slice_in_dim(
                        c, slot, 1, axis=ax.index("batch"))
                return jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=0)[0]
            return _tree_map_axes(leaf, self._axes, cache)

        self._insert = jax.jit(insert_fn, donate_argnums=(0,))
        self._evict = jax.jit(evict_fn, donate_argnums=(0,))
        self._gather = jax.jit(gather_fn)
        self._decode = jax.jit(model.decode, donate_argnums=(1,))

    # -- protocol ----------------------------------------------------------
    def init_slots(self, n_slots: int, cache_len: int) -> Any:
        return model_zoo.init_decode_cache(self.model, n_slots, cache_len)

    def insert(self, cache, slot, prefill_cache):
        return self._insert(cache, jnp.asarray(slot, jnp.int32),
                            prefill_cache)

    def evict(self, cache, slot):
        return self._evict(cache, jnp.asarray(slot, jnp.int32))

    def gather(self, cache, slot):
        return self._gather(cache, jnp.asarray(slot, jnp.int32))

    def decode(self, params, cache, tokens):
        return self._decode(params, cache, tokens)

    # -- placement ---------------------------------------------------------
    def shardings(self, rules, n_slots: int, cache_len: int):
        """NamedSharding tree for the slot cache under activation rules
        (slot axis rides the "batch" rule — see sharding.tree_act_shardings).
        """
        from repro.distributed.sharding import tree_act_shardings
        specs = model_zoo.decode_cache_specs(self.model, n_slots, cache_len)
        return tree_act_shardings(rules, self.slot_axes, specs)
