"""DecodeState: one slot-addressable interface over every backbone's cache.

The transformer/MoE KV cache, the Mamba-2 and RWKV-6 recurrent states and
the Zamba-2 hybrid cache all reduce to the same shape discipline: a pytree
whose leaves carry a "batch" logical axis (the *slot* axis) plus a per-slot
``pos`` vector.  ``SlotDecodeState`` implements the protocol generically
from each model's ``cache_shapes``/``cache_axes`` contract — no per-family
branches — with ``insert``/``evict``/``decode`` jitted and the state buffer
donated, so slot surgery happens in place on the accelerator.
"""
from __future__ import annotations

from typing import Any, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import is_axes_leaf
from repro.models import model_zoo


class DecodeState(Protocol):
    """Slot-addressable decode cache for continuous batching."""

    def init_slots(self, n_slots: int, cache_len: int) -> Any:
        """Allocate a zeroed ``n_slots``-wide cache."""

    def insert(self, cache: Any, slot: jax.Array, prefill_cache: Any) -> Any:
        """Scatter one request's batch=1 prefill cache into ``slot``."""

    def insert_many(self, cache: Any, slots: jax.Array,
                    prefill_cache: Any) -> Any:
        """Scatter a batch=k prefill cache into the ``k`` ``slots``."""

    def evict(self, cache: Any, slot: jax.Array) -> Any:
        """Retire ``slot`` (resets its position bookkeeping)."""

    def gather(self, cache: Any, slot: jax.Array) -> Any:
        """Extract ``slot``'s state as a batch=1 cache (slot migration)."""

    def decode(self, params: Any, cache: Any, tokens: jax.Array
               ) -> Tuple[jax.Array, Any]:
        """One fused decode step for all slots; per-slot positions."""

    def fit_row(self, row: Any, cache_len: int) -> Any:
        """Pad/trim a model-format row's "seq" capacity to ``cache_len``
        (cross-replica migration between mismatched cache geometries)."""


def _tree_map_axes(fn, axes_tree, *trees):
    return jax.tree_util.tree_map(fn, axes_tree, *trees,
                                  is_leaf=is_axes_leaf)


class SlotDecodeState:
    """Generic ``DecodeState`` over any model with the uniform cache API.

    ``slot`` arguments are traced int32 scalars, so one compiled
    insert/evict executable serves every slot index.
    """

    def __init__(self, model):
        self.model = model
        self._axes = model.cache_axes()  # original axes ("pos" leaves = ())
        # slot-cache trees carry one extra promoted leaf the model-format
        # prefill caches lack: the per-slot "active" occupancy bit (models
        # freeze pos and drop cache writes where it is False)
        self._saxes = dict(self._axes, active=())
        self.slot_axes = model_zoo.decode_cache_axes(model)

        def insert_fn(cache, slot, one):
            def leaf(ax, c, p):
                if "batch" in ax:
                    return jax.lax.dynamic_update_slice_in_dim(
                        c, p.astype(c.dtype), slot, axis=ax.index("batch"))
                # promoted bookkeeping leaf: scalar -> per-slot vector
                return jax.lax.dynamic_update_slice_in_dim(
                    c, jnp.asarray(p)[None].astype(c.dtype), slot, axis=0)
            return _tree_map_axes(leaf, self._saxes, cache, one)

        def insert_many_fn(cache, slots, rows):
            k = slots.shape[0]

            def leaf(ax, c, p):
                if "batch" in ax:
                    bax = ax.index("batch")
                    cm = jnp.moveaxis(c, bax, 0)
                    pm = jnp.moveaxis(p, bax, 0).astype(c.dtype)
                    return jnp.moveaxis(cm.at[slots].set(pm), 0, bax)
                # promoted bookkeeping leaf: scalar (shared) or (k,) per-row
                p = jnp.asarray(p).astype(c.dtype)
                if p.ndim < c.ndim:
                    p = jnp.broadcast_to(p, (k,) + c.shape[1:])
                return c.at[slots].set(p)
            return _tree_map_axes(leaf, self._saxes, cache, rows)

        def evict_fn(cache, slot):
            def leaf(ax, c):
                if "batch" in ax:
                    return c  # rows are overwritten wholesale on next insert
                zero = jnp.zeros((1,) + c.shape[1:], c.dtype)
                return jax.lax.dynamic_update_slice_in_dim(c, zero, slot,
                                                           axis=0)
            return _tree_map_axes(leaf, self._saxes, cache)

        def row_fn(kcache, i):
            def leaf(ax, c):
                if "batch" in ax:
                    return jax.lax.dynamic_slice_in_dim(
                        c, i, 1, axis=ax.index("batch"))
                return c  # scalar bookkeeping (pos) is shared by all rows
            return _tree_map_axes(leaf, self._axes, kcache)

        def gather_fn(cache, slot):
            def leaf(ax, c):
                if "batch" in ax:
                    return jax.lax.dynamic_slice_in_dim(
                        c, slot, 1, axis=ax.index("batch"))
                return jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=0)[0]
            out = _tree_map_axes(leaf, self._saxes, cache)
            out.pop("active")  # gather returns model-format (prefill) caches
            return out

        self._insert = jax.jit(insert_fn, donate_argnums=(0,))
        self._insert_many = jax.jit(insert_many_fn, donate_argnums=(0,))
        self._evict = jax.jit(evict_fn, donate_argnums=(0,))
        self._gather = jax.jit(gather_fn)
        self._row = jax.jit(row_fn)
        self._decode = jax.jit(model.decode, donate_argnums=(1,))

    # -- protocol ----------------------------------------------------------
    def init_slots(self, n_slots: int, cache_len: int) -> Any:
        return model_zoo.init_decode_cache(self.model, n_slots, cache_len)

    def insert(self, cache, slot, prefill_cache):
        one = dict(prefill_cache)
        one.setdefault("active", jnp.ones((), jnp.bool_))
        return self._insert(cache, jnp.asarray(slot, jnp.int32), one)

    def insert_many(self, cache, slots, prefill_cache):
        """Scatter a batch=k prefill cache into ``slots`` ((k,) int32, all
        distinct) in one donated executable (keyed on k, bounded by
        n_slots).  Bookkeeping leaves may be scalar (shared across the
        batch — the fresh same-bucket prefill) or (k,) per-row (after
        ragged decode-replay, see ``stack_rows``)."""
        rows = dict(prefill_cache)
        rows.setdefault("active", jnp.ones((), jnp.bool_))
        return self._insert_many(cache, jnp.asarray(slots, jnp.int32), rows)

    def evict(self, cache, slot):
        return self._evict(cache, jnp.asarray(slot, jnp.int32))

    def gather(self, cache, slot):
        return self._gather(cache, jnp.asarray(slot, jnp.int32))

    def decode(self, params, cache, tokens):
        return self._decode(params, cache, tokens)

    # -- batched-prefill helpers -------------------------------------------
    def row(self, prefill_cache, i) -> Any:
        """Slice row ``i`` of a batch=k prefill cache as a batch=1 cache
        (for per-request decode-replay of a ragged remainder)."""
        return self._row(prefill_cache, jnp.asarray(i, jnp.int32))

    def stack_rows(self, rows) -> Any:
        """Concatenate batch=1 prefill caches into a batch=k cache for
        ``insert_many``; scalar bookkeeping leaves (``pos``) become (k,)
        per-row vectors (rows end ragged replay at different depths)."""
        def leaf(ax, *cs):
            if "batch" in ax:
                return jnp.concatenate(cs, axis=ax.index("batch"))
            return jnp.stack([jnp.asarray(c) for c in cs])
        return _tree_map_axes(leaf, self._axes, *rows)

    def fit_row(self, row, cache_len: int) -> Any:
        """Pad/trim a model-format row's "seq" leaves to ``cache_len``.

        Slot migration between replicas with mismatched cache geometry:
        a paged gather returns ``pages_per_slot * page_size`` entries, a
        dense row carries ``cache_len`` — the valid prefix (up to ``pos``)
        is identical, and everything past it is garbage the insert target
        never reads, so trimming is lossless as long as the destination's
        capacity admits the request (the scheduler validated that).
        Recurrent leaves (no "seq" axis) pass through untouched.
        """
        def leaf(ax, c):
            if "seq" not in ax:
                return c
            si = ax.index("seq")
            cur = c.shape[si]
            if cur == cache_len:
                return c
            if cur > cache_len:
                sl = [slice(None)] * c.ndim
                sl[si] = slice(0, cache_len)
                return c[tuple(sl)]
            width = [(0, 0)] * c.ndim
            width[si] = (0, cache_len - cur)
            return jnp.pad(c, width)
        return _tree_map_axes(leaf, self._axes, row)

    # -- placement ---------------------------------------------------------
    def shardings(self, rules, n_slots: int, cache_len: int):
        """NamedSharding tree for the slot cache under activation rules
        (slot axis rides the "batch" rule — see sharding.tree_act_shardings).
        """
        from repro.distributed.sharding import tree_act_shardings
        specs = model_zoo.decode_cache_specs(self.model, n_slots, cache_len)
        return tree_act_shardings(rules, self.slot_axes, specs)
