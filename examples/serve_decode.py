"""Batched serving example: prefill + KV-cache greedy decode.

    PYTHONPATH=src python examples/serve_decode.py --arch smollm-360m
    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-7b  # O(1) state
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=48)
    p.add_argument("--gen", type=int, default=24)
    p.add_argument("--full", action="store_true",
                   help="use the full config (needs a real accelerator)")
    args = p.parse_args()
    serve(args.arch, use_reduced=not args.full, batch=args.batch,
          prompt_len=args.prompt_len, gen_tokens=args.gen)


if __name__ == "__main__":
    main()
