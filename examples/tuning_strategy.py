"""The paper's low-cost tuning strategy (§4), runnable.

Finds (seqlen_s, T) with short probe runs only — no full trainings:
  1. start at seqlen_s=8, T = 1x LR-warmup;
  2. raise seqlen_s until early validation perplexity stops fluctuating;
  3. binary-search the largest calm T.

    PYTHONPATH=src python examples/tuning_strategy.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.common import bench_config
from repro.configs.base import SLWConfig
from repro.core import tune_slw
from repro.launch.train import train

WARMUP = 15
LR = 6e-2


def probe(slw_cfg: SLWConfig):
    """Train only the early window; return the validation-ppl trace."""
    tc = bench_config(slw=True, lr=LR, steps=3 * WARMUP, warmup_steps=WARMUP)
    tc = dataclasses.replace(tc, slw=slw_cfg, eval_interval=5)
    res = train(tc, quiet=True, stop_on_nan=False)
    return [p for _, p in res.val_ppl_history]


def main():
    result = tune_slw(probe, SLWConfig(round_multiple=8, max_buckets=12),
                      warmup_steps=WARMUP, seqlen_s_grid=(8, 16, 32),
                      t_multiple_range=(1, 8))
    print("probe trials (seqlen_s, T, fluctuated):")
    for t in result.trials:
        print("  ", t)
    print(f"\nchosen: seqlen_s={result.seqlen_s} T={result.duration} "
          f"({result.probe_runs} probes of {3 * WARMUP} steps each — "
          f"a small fraction of any full training)")


if __name__ == "__main__":
    main()
