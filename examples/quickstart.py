"""Quickstart: train a small GPT-2-style model with the paper's joint
recipe — Sequence Length Warmup composed with batch-size and LR warmup
through the regulator control plane.

    PYTHONPATH=src python examples/quickstart.py [--steps 120]

What you should see: the per-step sequence length ramping 8 -> 256 on the
paper's linear pacing function while the batch ramps up alongside it, the
loss-ratio tracker staying spike-free, and validation perplexity (always
full-length) dropping.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_arch, reduced
from repro.configs.base import (BatchWarmupConfig, OptimizerConfig, SLWConfig,
                                TrainConfig)
from repro.launch.train import train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=120)
    p.add_argument("--preset", default="tiny", choices=["tiny", "100m"],
                   help="'100m' trains the real gpt2-117m config "
                   "(slow on CPU; sized for a real accelerator)")
    args = p.parse_args()

    if args.preset == "100m":
        model = get_arch("gpt2-117m").model
        seq, batch = 1024, 16
    else:
        model = reduced(get_arch("gpt2-117m").model).replace(
            n_layers=3, d_model=96, d_ff=384, vocab_size=512)
        seq, batch = 256, 8

    steps = args.steps
    tc = TrainConfig(
        model=model,
        optimizer=OptimizerConfig(
            lr=6e-3, min_lr=2e-4, schedule="token_cosine",
            warmup_steps=15, warmup_tokens=15 * batch * seq,
            total_steps=steps, total_tokens=steps * batch * seq),
        slw=SLWConfig(enabled=True, pacing="linear", start_seq_len=8,
                      duration_steps=steps // 3, round_multiple=8,
                      max_buckets=12),
        # composes with SLW through the regulator stack (the paper's
        # joint recipe: short sequences make the warming batch/LR safe)
        batch_warmup=BatchWarmupConfig(enabled=True, start_batch=batch // 2,
                                       warmup_tokens=steps * batch * seq // 8),
        seq_len=seq, global_batch=batch, remat="none", eval_interval=20)

    res = train(tc, quiet=False)
    print("\n== quickstart summary ==")
    print(f"steps={res.steps} tokens={res.tokens} "
          f"compiles={res.n_compiles} (bounded by the bucket ladder)")
    print(f"seqlen schedule: {res.seqlen_history[0]} -> "
          f"{res.seqlen_history[-1]}")
    print(f"batch schedule:  {res.batch_history[0]} -> "
          f"{res.batch_history[-1]}")
    print(f"stability: {res.tracker_summary}")
    print(f"val ppl: {[f'{p:.1f}' for _, p in res.val_ppl_history]}")


if __name__ == "__main__":
    main()
