"""Streaming generation through the continuous-batching engine.

Tokens arrive per request the moment each fused decode step produces them —
requests with small budgets finish early, their slots are backfilled from
the queue, and the stream interleaves accordingly.

    PYTHONPATH=src python examples/serve_stream.py --arch smollm-360m
    PYTHONPATH=src python examples/serve_stream.py --arch rwkv6-7b \
        --temperature 0.8 --top-k 40
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs import get_arch, reduced
from repro.serve import (InferenceEngine, Request, SamplingParams,
                         SchedulerConfig)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=3)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--gen", type=int, default=12)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--top-k", type=int, default=0)
    p.add_argument("--top-p", type=float, default=1.0)
    p.add_argument("--full", action="store_true",
                   help="use the full config (needs a real accelerator)")
    args = p.parse_args()

    cfg = get_arch(args.arch).model
    cfg = cfg if args.full else reduced(cfg)
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    tokens=tuple(int(t) for t in rng.integers(
                        0, cfg.vocab_size,
                        size=max(4, args.prompt_len - 3 * (i % 3)))),
                    max_tokens=max(1, args.gen - 2 * (i % 4)), sampling=sp)
            for i in range(args.requests)]

    engine = InferenceEngine.from_arch(args.arch, use_reduced=not args.full,
                                       cfg=SchedulerConfig(
                                           n_slots=args.slots,
                                           cache_len=args.prompt_len
                                           + args.gen))

    def on_token(uid: int, token: int) -> None:
        print(f"req{uid} -> {token}", flush=True)

    results = engine.run(reqs, on_token=on_token)
    print("\nper-request results:")
    for r in results:
        print(f"  req{r.uid}: prompt={r.prompt_len} "
              f"generated={r.n_generated} ({r.finish_reason}) "
              f"tokens={r.tokens}")
    s = engine.stats
    print(f"\nprefill {s.prefill_tok_s:.0f} tok/s | decode "
          f"{s.decode_tok_s:.0f} tok/s | p95 per-token "
          f"{s.latency_percentile(95)*1e3:.1f} ms")


if __name__ == "__main__":
    main()
