"""The stability-efficiency dilemma, end to end (paper §3 + §5 in miniature).

Runs the same model under (a) a moderate recipe, (b) an aggressive recipe
(large LR — the 8x-batch/4x-LR analogue), and (c) the aggressive recipe with
SLW, and prints the Table-1-style loss-ratio comparison plus the Adam
variance-max telemetry that the paper correlates with the spikes.

    PYTHONPATH=src python examples/stability_study.py
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np

from benchmarks.common import bench_config, run_arm


def main():
    steps = 120
    arms = [
        ("moderate baseline", bench_config(slw=False, lr=6e-3, steps=steps)),
        ("aggressive baseline", bench_config(slw=False, lr=6e-2, steps=steps)),
        ("aggressive + SLW", bench_config(slw=True, lr=6e-2, steps=steps,
                                          duration=steps // 3)),
        # the paper's joint recipe, one config since the regulator stack
        ("aggressive + SLW + bsz", bench_config(slw=True, batch_warmup=True,
                                                lr=6e-2, steps=steps,
                                                duration=steps // 3)),
    ]
    print(f"{'case':24s} {'spikes':>7s} {'max_ratio':>10s} "
          f"{'var_max_peak':>13s} {'final_loss':>11s}")
    for name, tc in arms:
        _, res, _ = run_arm(name, tc)
        s = res.tracker_summary
        print(f"{name:24s} {s['spikes']:7d} {s['max_loss_ratio']:10.2f} "
              f"{np.nanmax(res.var_max_history):13.3e} "
              f"{res.loss_history[-1]:11.3f}")
    print("\npaper: aggressive recipes spike; SLW removes the spikes while "
          "keeping the aggressive recipe's efficiency.")


if __name__ == "__main__":
    main()
