"""Serving engine contracts.

* engine-vs-legacy parity: greedy continuous batching is tokenwise
  identical to running each request alone through the legacy static path
  (the ISSUE acceptance criterion, dense + recurrent backbones, prompt
  lengths spanning multiple buckets, distinct generation budgets, fewer
  slots than requests so admit/evict/backfill all happen mid-stream);
* DecodeState protocol: staggered insert/evict through the slot interface
  reproduces isolated per-request decode logits; gather round-trips;
* sampling: pure functions of (logits, rng) behave as specified.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model, init_params, model_zoo
from repro.serve import (InferenceEngine, Request, SamplingParams,
                         SchedulerConfig, SlotDecodeState, prefill_split)
from repro.serve import sampling as S
from repro.serve.scheduler import Scheduler


def _build(arch, **overrides):
    cfg = reduced(get_arch(arch).model)
    if overrides:
        cfg = cfg.replace(**overrides)
    model = build_model(cfg, dtype=jnp.float32, remat="none")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _legacy_greedy(model, params, tokens, max_tokens, cache_len):
    """Per-request oracle: the legacy serve() token stream for one prompt."""
    toks = jnp.asarray(tokens, jnp.int32)[None, :]
    logits, cache = model.prefill(params, {"tokens": toks},
                                  cache_len=cache_len)
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(max_tokens - 1):
        logits, cache = model.decode(params, cache,
                                     jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def _mixed_requests(cfg, n=8, seed=3, sampling=SamplingParams()):
    """Prompt lens spanning two+ ladder buckets, distinct max_tokens."""
    rng = np.random.default_rng(seed)
    shapes = [(7, 5), (20, 9), (33, 3), (12, 7), (40, 4), (9, 8), (25, 6),
              (16, 2)][:n]
    return [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=plen)),
                    max_tokens=mt, sampling=sampling)
            for i, (plen, mt) in enumerate(shapes)]


PARITY_ARCHS = ["gpt2-117m", "rwkv6-7b",
                pytest.param("zamba2-2.7b", marks=pytest.mark.slow),
                pytest.param("smollm-360m", marks=pytest.mark.slow)]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_engine_matches_legacy_greedy(arch):
    cfg, model, params = _build(arch)
    cache_len = 64
    sched = SchedulerConfig(n_slots=3, cache_len=cache_len,
                            min_prompt_bucket=8, round_multiple=16,
                            max_buckets=4)
    engine = InferenceEngine(model, params, sched)
    reqs = _mixed_requests(cfg)
    # the workload exercises >= 2 prefill buckets and sub-bucket remainders
    splits = {prefill_split(r.prompt_len, engine.scheduler.ladder)
              for r in reqs}
    assert len(splits) >= 2
    results = engine.run(reqs)
    for req, res in zip(reqs, results):
        oracle = _legacy_greedy(model, params, req.tokens, req.max_tokens,
                                cache_len)
        assert res.tokens == oracle, f"uid {req.uid}"
        assert res.finish_reason == "length"
    # 8 requests through 3 slots: every slot was recycled, then freed
    assert engine.stats.admitted == len(reqs)
    assert sorted(engine.scheduler.free) == [0, 1, 2]
    assert not engine.scheduler.busy


def test_stop_token_and_uneven_stops():
    cfg, model, params = _build("gpt2-117m")
    sched = SchedulerConfig(n_slots=2, cache_len=48, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    engine = InferenceEngine(model, params, sched)
    rng = np.random.default_rng(0)
    base = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, size=9))
    # find what greedy emits, then stop on its second token
    oracle = _legacy_greedy(model, params, base, 6, 48)
    stop = oracle[1]
    reqs = [Request(uid=0, tokens=base, max_tokens=6,
                    sampling=SamplingParams(stop_token=stop)),
            Request(uid=1, tokens=base[:5], max_tokens=1),
            Request(uid=2, tokens=base, max_tokens=6)]
    res = engine.run(reqs)
    assert res[0].tokens == oracle[:2]
    assert res[0].finish_reason == "stop_token"
    assert res[1].n_generated == 1 and res[1].finish_reason == "length"
    assert res[2].tokens == oracle


def test_protocol_staggered_insert_evict():
    """Fused per-slot decode through SlotDecodeState matches isolated
    scalar-pos decode, with slots inserted/evicted mid-flight."""
    cfg, model, params = _build("smollm-360m")
    cache_len, n_slots = 32, 2
    state = SlotDecodeState(model)
    cache = state.init_slots(n_slots, cache_len)
    rng = np.random.default_rng(7)
    prompts = [tuple(int(t) for t in rng.integers(0, cfg.vocab_size, size=p))
               for p in (6, 11, 9)]

    def one_prefill(toks):
        return model.prefill(params, {"tokens": jnp.asarray(
            toks, jnp.int32)[None, :]}, cache_len=cache_len)

    # isolated oracles: logits trajectory per request under scalar-pos decode
    def oracle(toks, steps):
        logits, c = one_prefill(toks)
        traj = [np.asarray(logits)[0]]
        tok = int(jnp.argmax(logits, -1)[0])
        for _ in range(steps):
            logits, c = model.decode(params, c,
                                     jnp.asarray([[tok]], jnp.int32))
            traj.append(np.asarray(logits)[0])
            tok = int(jnp.argmax(logits, -1)[0])
        return traj

    orc = [oracle(p, 4) for p in prompts]

    # slot 0 <- req0; decode 2 fused steps with slot 1 empty
    lg0, c0 = one_prefill(prompts[0])
    cache = state.insert(cache, 0, c0)
    last = {0: int(jnp.argmax(lg0, -1)[0])}
    seen = {0: 0}

    def fused(cache, last):
        toks = np.zeros((n_slots, 1), np.int32)
        for s, t in last.items():
            toks[s, 0] = t
        logits, cache = state.decode(params, cache, jnp.asarray(toks))
        logits = np.asarray(logits)
        for s in list(last):
            seen[s] += 1
            np.testing.assert_allclose(logits[s], orc_for[s][seen[s]],
                                       atol=1e-4, rtol=1e-4)
            last[s] = int(np.argmax(logits[s]))
        return cache, last

    orc_for = {0: orc[0]}
    for _ in range(2):
        cache, last = fused(cache, last)
    # admit req1 into slot 1; run both
    lg1, c1 = one_prefill(prompts[1])
    cache = state.insert(cache, 1, c1)
    last[1] = int(jnp.argmax(lg1, -1)[0])
    seen[1] = 0
    orc_for[1] = orc[1]
    for _ in range(2):
        cache, last = fused(cache, last)
    # evict slot 0, backfill with req2, keep slot 1 going (uneven depths)
    cache = state.evict(cache, 0)
    del last[0]
    lg2, c2 = one_prefill(prompts[2])
    cache = state.insert(cache, 0, c2)
    last[0] = int(jnp.argmax(lg2, -1)[0])
    seen[0] = 0
    orc_for[0] = orc[2]
    for _ in range(2):
        cache, last = fused(cache, last)


@pytest.mark.slow
def test_short_prompt_conv_state_zamba():
    """Prompts shorter than conv_kernel-1 prefill a zero-left-padded conv
    window — token streams must still match the decode-replay oracle."""
    cfg, model, params = _build("zamba2-2.7b")
    sched = SchedulerConfig(n_slots=2, cache_len=32, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    engine = InferenceEngine(model, params, sched)
    rng = np.random.default_rng(9)
    reqs = [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=plen)),
                    max_tokens=5)
            for i, plen in enumerate((1, 2, 3))]
    results = engine.run(reqs)
    for req, res in zip(reqs, results):
        assert res.tokens == _legacy_greedy(model, params, req.tokens,
                                            req.max_tokens, 32)


def test_gather_roundtrip():
    cfg, model, params = _build("rwkv6-7b")
    state = SlotDecodeState(model)
    cache = state.init_slots(3, 24)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(1, 10)), jnp.int32)
    _, one = model.prefill(params, {"tokens": toks}, cache_len=24)
    cache = state.insert(cache, 1, one)
    back = state.gather(cache, 1)
    flat_a = jax.tree_util.tree_leaves(one)
    flat_b = jax.tree_util.tree_leaves(back)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0)


def test_scheduler_validation_and_buckets():
    sched = SchedulerConfig(n_slots=2, cache_len=32, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    s = Scheduler(sched)
    with pytest.raises(ValueError):
        s.submit(Request(uid=0, tokens=(1,) * 30, max_tokens=8))
    with pytest.raises(ValueError):
        s.submit(Request(uid=1, tokens=(1, 2), max_tokens=0))
    with pytest.raises(ValueError):
        s.submit(Request(uid=2, tokens=(), max_tokens=4))
    s.submit(Request(uid=3, tokens=(1, 2), max_tokens=4))
    with pytest.raises(ValueError):  # uid keys results + the PRNG stream
        s.submit(Request(uid=3, tokens=(5, 6), max_tokens=4))
    with pytest.raises(ValueError):
        Scheduler(SchedulerConfig(n_slots=0, cache_len=32))
    # all-or-nothing batch admission: nothing enqueued on failure
    before = len(s.pending)
    with pytest.raises(ValueError):
        s.submit_all([Request(uid=4, tokens=(1, 2), max_tokens=4),
                      Request(uid=5, tokens=(1,) * 30, max_tokens=8)])
    assert len(s.pending) == before
    ladder = s.ladder
    assert ladder[-1] == 32 and len(ladder) <= 6
    for plen in (3, 8, 9, 17, 31, 32):
        sp = prefill_split(plen, ladder)
        assert 1 <= sp <= plen
        # bounded jit shapes: a split is a ladder bucket or the shared
        # length-1 shape sub-bucket prompts prefill at
        assert sp in ladder or sp == 1


def test_submit_all_accepts_generator():
    """Regression: submit_all used to exhaust a generator during the
    validation pass and then extend an empty iterator — silently enqueueing
    nothing."""
    sched = SchedulerConfig(n_slots=2, cache_len=32, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    s = Scheduler(sched)
    reqs = [Request(uid=i, tokens=(1, 2, 3), max_tokens=4) for i in range(5)]
    s.submit_all(r for r in reqs)
    assert len(s.pending) == 5
    assert [r.uid for r in s.pending] == [0, 1, 2, 3, 4]
    # all-or-nothing still holds for generators
    with pytest.raises(ValueError):
        s.submit_all(Request(uid=u, tokens=(1,) * 40, max_tokens=4)
                     for u in (7, 8))
    assert len(s.pending) == 5


def test_short_prompts_share_one_prefill_shape():
    """Sub-minimum-bucket prompts must not leak one compiled prefill shape
    per distinct length: they prefill the shared length-1 shape and
    decode-replay the rest (and stay tokenwise exact)."""
    cfg, model, params = _build("gpt2-117m")
    sched = SchedulerConfig(n_slots=2, cache_len=32, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    engine = InferenceEngine(model, params, sched)
    shapes = set()
    orig = engine._prefill
    engine._prefill = lambda p, b: (shapes.add(b["tokens"].shape),
                                    orig(p, b))[1]
    rng = np.random.default_rng(11)
    reqs = [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=plen)),
                    max_tokens=3)
            for i, plen in enumerate((2, 3, 4, 5, 6, 7))]
    results = engine.run(reqs)
    assert shapes == {(1, 1)}, shapes
    for req, res in zip(reqs, results):
        assert res.tokens == _legacy_greedy(model, params, req.tokens,
                                            req.max_tokens, 32)


def test_batched_prefill_matches_sequential():
    """(k, bucket) admission prefill is tokenwise identical to
    one-at-a-time admission and genuinely batches same-bucket prompts."""
    cfg, model, params = _build("gpt2-117m")

    def run(prefill_batch):
        engine = InferenceEngine(model, params, SchedulerConfig(
            n_slots=4, cache_len=64, min_prompt_bucket=8, round_multiple=16,
            max_buckets=4, prefill_batch=prefill_batch))
        calls = []
        orig = engine._prefill
        engine._prefill = lambda p, b: (calls.append(b["tokens"].shape),
                                        orig(p, b))[1]
        return engine.run(_mixed_requests(cfg)), calls

    seq_res, seq_calls = run(1)
    bat_res, bat_calls = run(4)
    for a, b in zip(seq_res, bat_res):
        assert a.tokens == b.tokens, a.uid
        assert a.finish_reason == b.finish_reason
    assert all(shape[0] == 1 for shape in seq_calls)
    assert len(bat_calls) < len(seq_calls)  # same-bucket prompts coalesced
    assert any(shape[0] > 1 for shape in bat_calls)


def test_next_admission_same_split_batching():
    """next_admission(k) pulls same-split requests forward and preserves
    the relative order of skipped ones."""
    sched = SchedulerConfig(n_slots=4, cache_len=64, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    s = Scheduler(sched)
    lens = [16, 9, 17, 20, 33]  # splits on ladder (8, 16, 32): 16,8,16,16,32
    for i, plen in enumerate(lens):
        s.submit(Request(uid=i, tokens=(1,) * plen, max_tokens=4))
    adm = s.next_admission(3)
    assert [r.uid for _, r in adm] == [0, 2, 3]  # same split as the head
    assert len({slot for slot, _ in adm}) == 3
    assert [r.uid for r in s.pending] == [1, 4]  # skipped order preserved
    adm2 = s.next_admission(3)
    assert [r.uid for _, r in adm2] == [1]  # next head: different split


@pytest.mark.parametrize("arch", ["gpt2-117m"])
def test_engine_parity_kernel_decode_backend(arch):
    """Greedy engine output stays tokenwise identical to the legacy path
    with the flash-decode kernel on the fused step (interpret mode — the
    CPU validation of the serving hot path's kernel)."""
    cfg, model, params = _build(arch, decode_backend="kernel_interpret")
    ref_model = build_model(cfg.replace(decode_backend="reference"),
                            dtype=jnp.float32, remat="none")
    sched = SchedulerConfig(n_slots=2, cache_len=32, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4,
                            prefill_batch=2)
    engine = InferenceEngine(model, params, sched)
    rng = np.random.default_rng(13)
    reqs = [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=plen)),
                    max_tokens=mt)
            for i, (plen, mt) in enumerate(((7, 4), (12, 3), (9, 4)))]
    results = engine.run(reqs)
    for req, res in zip(reqs, results):
        oracle = _legacy_greedy(ref_model, params, req.tokens,
                                req.max_tokens, 32)
        assert res.tokens == oracle, f"uid {req.uid}"


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def _keys(n):
    return jnp.stack([jax.random.PRNGKey(i) for i in range(n)])


def test_sampling_greedy_is_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(0), (5, 64))
    out = S.sample_tokens(logits, _keys(5), jnp.zeros(5),
                          jnp.zeros(5, jnp.int32), jnp.ones(5))
    assert (np.asarray(out) == np.asarray(jnp.argmax(logits, -1))).all()


def test_sampling_topk1_and_tiny_topp_are_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    am = np.asarray(jnp.argmax(logits, -1))
    k1 = S.sample_tokens(logits, _keys(4), jnp.ones(4),
                         jnp.ones(4, jnp.int32), jnp.ones(4))
    p0 = S.sample_tokens(logits, _keys(4), jnp.ones(4),
                         jnp.zeros(4, jnp.int32), jnp.full(4, 1e-6))
    pz = S.sample_tokens(logits, _keys(4), jnp.ones(4),
                         jnp.zeros(4, jnp.int32), jnp.zeros(4))
    assert (np.asarray(k1) == am).all()
    assert (np.asarray(p0) == am).all()
    # top_p == 0 degenerates to argmax, never a uniform draw
    assert (np.asarray(pz) == am).all()


def test_sampling_topk_support_and_per_row_params():
    logits = jax.random.normal(jax.random.PRNGKey(2), (6, 128))
    ks = jnp.asarray([1, 2, 4, 8, 0, 3], jnp.int32)
    masked = S.apply_top_k(logits, ks)
    kept = (np.asarray(masked) > -1e29).sum(axis=-1)
    assert list(kept) == [1, 2, 4, 8, 128, 3]
    # sampled tokens always inside each row's top-k support
    out = np.asarray(S.sample_tokens(logits, _keys(6), jnp.ones(6), ks,
                                     jnp.ones(6)))
    for i in range(6):
        assert masked[i, out[i]] > -1e29


def test_sampling_deterministic_per_key():
    logits = jax.random.normal(jax.random.PRNGKey(3), (3, 64))
    a = S.sample_tokens(logits, _keys(3), jnp.full(3, 0.8),
                        jnp.zeros(3, jnp.int32), jnp.full(3, 0.9))
    b = S.sample_tokens(logits, _keys(3), jnp.full(3, 0.8),
                        jnp.zeros(3, jnp.int32), jnp.full(3, 0.9))
    assert (np.asarray(a) == np.asarray(b)).all()


def test_sampling_vocab_mask():
    # padded columns (>= vocab_size) are never sampled even if largest
    logits = jnp.zeros((2, 8)).at[:, 7].set(10.0)
    out = S.sample_tokens(logits, _keys(2), jnp.zeros(2),
                          jnp.zeros(2, jnp.int32), jnp.ones(2), vocab_size=7)
    assert (np.asarray(out) < 7).all()


def test_engine_reuse_across_runs():
    """Each run() returns exactly its own request set, even with uids
    reused across runs, and stats can be reset between runs."""
    cfg, model, params = _build("gpt2-117m")
    engine = InferenceEngine(model, params, SchedulerConfig(
        n_slots=2, cache_len=32, min_prompt_bucket=8, round_multiple=16,
        max_buckets=4))
    rng = np.random.default_rng(4)
    p1 = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, size=8))
    p2 = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, size=8))
    r1 = engine.run([Request(uid=0, tokens=p1, max_tokens=4)])
    old = engine.reset_stats()
    assert old.admitted == 1 and engine.stats.admitted == 0
    r2 = engine.run([Request(uid=0, tokens=p2, max_tokens=4)])
    assert r1[0].tokens == _legacy_greedy(model, params, p1, 4, 32)
    assert r2[0].tokens == _legacy_greedy(model, params, p2, 4, 32)
    assert engine.scheduler.finished == []  # no unbounded accumulation


def test_engine_mixed_sampling_isolation():
    """A greedy request's stream is unaffected by stochastic neighbors in
    the same fused batch (per-slot parameter isolation)."""
    cfg, model, params = _build("gpt2-117m")
    sched = SchedulerConfig(n_slots=2, cache_len=48, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    rng = np.random.default_rng(5)
    prompt = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, size=10))
    greedy = Request(uid=0, tokens=prompt, max_tokens=6)
    noisy = Request(uid=1, tokens=prompt, max_tokens=6,
                    sampling=SamplingParams(temperature=1.0, top_k=8,
                                            seed=11))
    res = InferenceEngine(model, params, sched).run([greedy, noisy])
    oracle = _legacy_greedy(model, params, prompt, 6, 48)
    assert res[0].tokens == oracle
    # the stochastic stream is reproducible under a fresh engine
    res2 = InferenceEngine(model, params, sched).run([noisy, greedy])
    assert res2[0].tokens == res[1].tokens


def test_admission_fault_retires_only_failing_request():
    """A per-request failure during admission (sampling fault) retires that
    request with finish_reason="error"; batchmates' token streams stay
    tokenwise exact."""
    cfg, model, params = _build("gpt2-117m")
    sched = SchedulerConfig(n_slots=3, cache_len=64, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    engine = InferenceEngine(model, params, sched)
    orig = engine._first_token

    def failing(req, logits):
        if req.uid == 1:
            raise RuntimeError("injected sampling fault")
        return orig(req, logits)

    engine._first_token = failing
    reqs = _mixed_requests(cfg, n=4)
    results = engine.run(reqs)
    assert results[1].finish_reason == "error"
    assert results[1].tokens == []
    for req, res in zip(reqs, results):
        if req.uid == 1:
            continue
        assert res.tokens == _legacy_greedy(model, params, req.tokens,
                                            req.max_tokens, 64), req.uid
        assert res.finish_reason == "length"
    assert engine.stats.slot_errors == 1
    # the failed slot was freed and recycled
    assert sorted(engine.scheduler.free) == [0, 1, 2]
    assert not engine.scheduler.busy


def test_shared_prefill_fault_aborts_batch_without_crash():
    """A failure in the shared (k, bucket) prefill phase aborts all k slots
    of that admission; the engine still returns a result per uid."""
    cfg, model, params = _build("gpt2-117m")
    engine = InferenceEngine(model, params, SchedulerConfig(
        n_slots=2, cache_len=64, min_prompt_bucket=8, round_multiple=16,
        max_buckets=4, prefill_batch=2))

    def boom(p, b):
        raise RuntimeError("injected prefill fault")

    engine._prefill = boom
    reqs = _mixed_requests(cfg, n=3)
    results = engine.run(reqs)
    assert all(r.finish_reason == "error" for r in results)
    assert engine.stats.slot_errors == len(reqs)
    assert sorted(engine.scheduler.free) == [0, 1]
    assert not engine.scheduler.busy


def test_on_token_fault_mid_decode_isolates_slot():
    """A consumer callback raising mid-decode retires only that slot; the
    rest of the fused batch keeps decoding to completion."""
    cfg, model, params = _build("gpt2-117m")
    sched = SchedulerConfig(n_slots=2, cache_len=48, min_prompt_bucket=8,
                            round_multiple=16, max_buckets=4)
    engine = InferenceEngine(model, params, sched)
    rng = np.random.default_rng(21)
    reqs = [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=10)),
                    max_tokens=6)
            for i in range(2)]
    seen = {0: 0, 1: 0}

    def on_token(uid, tok):
        seen[uid] += 1
        if uid == 0 and seen[uid] == 3:  # third token: inside _fused_step
            raise RuntimeError("injected consumer fault")

    results = engine.run(reqs, on_token=on_token)
    assert results[0].finish_reason == "error"
    assert 2 <= len(results[0].tokens) <= 3  # stream cut mid-decode
    oracle = _legacy_greedy(model, params, reqs[1].tokens, 6, 48)
    assert results[1].tokens == oracle
    assert results[1].finish_reason == "length"
    assert engine.stats.slot_errors == 1
    assert not engine.scheduler.busy


def test_bounded_queue_try_submit_sheds():
    cfg, model, params = _build("gpt2-117m")
    engine = InferenceEngine(model, params, SchedulerConfig(
        n_slots=2, cache_len=32, min_prompt_bucket=8, round_multiple=16,
        max_buckets=4, max_pending=2))
    reqs = [Request(uid=i, tokens=(1, 2, 3), max_tokens=4) for i in range(3)]
    assert engine.try_submit(reqs[0])
    assert engine.try_submit(reqs[1])
    assert not engine.try_submit(reqs[2])  # at capacity: explicit shed
    assert engine.stats.shed == 1
    # malformed requests are a caller bug, not an overload signal
    engine2 = InferenceEngine(model, params, SchedulerConfig(
        n_slots=2, cache_len=32, max_pending=2))
    with pytest.raises(ValueError):
        engine2.try_submit(Request(uid=9, tokens=(1,) * 40, max_tokens=8))
    assert engine2.stats.shed == 0


def test_scheduler_bounded_queue_semantics():
    from repro.serve.scheduler import QueueFull
    s = Scheduler(SchedulerConfig(n_slots=2, cache_len=32,
                                  min_prompt_bucket=8, round_multiple=16,
                                  max_buckets=4, max_pending=2))
    s.submit(Request(uid=0, tokens=(1, 2), max_tokens=4))
    assert s.has_room
    s.submit(Request(uid=1, tokens=(1, 2), max_tokens=4))
    assert not s.has_room
    with pytest.raises(QueueFull):
        s.submit(Request(uid=2, tokens=(1, 2), max_tokens=4))
    # submit_all overload is all-or-nothing: nothing enqueued
    s2 = Scheduler(SchedulerConfig(n_slots=2, cache_len=32, max_pending=2))
    with pytest.raises(QueueFull):
        s2.submit_all([Request(uid=i, tokens=(1, 2), max_tokens=4)
                       for i in range(3)])
    assert len(s2.pending) == 0


def test_run_respects_bounded_queue_and_completes():
    """run() owns its request set: with max_pending=1 the backlog drains
    through the bounded queue without shedding, and every request finishes
    tokenwise exact."""
    cfg, model, params = _build("gpt2-117m")
    engine = InferenceEngine(model, params, SchedulerConfig(
        n_slots=2, cache_len=32, min_prompt_bucket=8, round_multiple=16,
        max_buckets=4, max_pending=1))
    rng = np.random.default_rng(17)
    reqs = [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=9)),
                    max_tokens=4)
            for i in range(4)]
    results = engine.run(reqs)
    assert engine.stats.shed == 0
    for req, res in zip(reqs, results):
        assert res.tokens == _legacy_greedy(model, params, req.tokens, 4, 32)
        assert res.finish_reason == "length"
    assert len(engine.scheduler.pending) == 0


def test_decode_cache_specs_slot_promotion():
    for arch in ("gpt2-117m", "rwkv6-7b", "zamba2-2.7b"):
        _, model, _ = _build(arch)
        specs = model_zoo.decode_cache_specs(model, n_slots=5, cache_len=16)
        axes = model_zoo.decode_cache_axes(model)
        from repro.distributed.sharding import is_axes_leaf
        flat_s = jax.tree_util.tree_leaves(specs)
        flat_a = jax.tree_util.tree_leaves(axes, is_leaf=is_axes_leaf)
        for sds, ax in zip(flat_s, flat_a):
            assert "batch" in ax
            assert sds.shape[ax.index("batch")] == 5
