"""The low-cost tuning strategy (paper Section 4)."""
from repro.configs.base import SLWConfig
from repro.core import significant_fluctuation, tune_slw


def test_significant_fluctuation_threshold():
    assert not significant_fluctuation([10.0, 9.0, 8.5, 8.0])
    assert significant_fluctuation([10.0, 9.0, 12.0])  # 12 > 1.3 * 9
    assert not significant_fluctuation([10.0, 9.0, 11.0])  # 11 < 1.3 * 9


def test_tuner_finds_largest_calm_duration():
    """Synthetic probe: fluctuates iff T > 6*warmup or seqlen_s < 16."""
    warmup = 100

    def probe(cfg: SLWConfig):
        calm = cfg.start_seq_len >= 16 and cfg.duration_steps <= 6 * warmup
        return [10.0, 9.0, 8.0] if calm else [10.0, 9.0, 14.0]

    res = tune_slw(probe, SLWConfig(), warmup_steps=warmup,
                   seqlen_s_grid=(8, 16, 32), t_multiple_range=(1, 16))
    assert res.seqlen_s == 16
    assert res.duration == 6 * warmup
    # cost is probe runs, not full trainings
    assert res.probe_runs <= 3 + 5  # grid walk + log2(16) binary search


def test_tuner_prefers_small_seqlen_s():
    def probe(cfg: SLWConfig):
        return [10.0, 9.0, 8.0]  # always calm

    res = tune_slw(probe, SLWConfig(), warmup_steps=10,
                   seqlen_s_grid=(8, 16), t_multiple_range=(1, 4))
    assert res.seqlen_s == 8
    assert res.duration == 4 * 10
