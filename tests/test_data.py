"""Data pipeline: determinism, resume, elastic resharding."""
import numpy as np

from repro.data import DataPipeline, SyntheticCorpus


def test_corpus_deterministic_random_access():
    c = SyntheticCorpus(vocab_size=512, seq_len=64, seed=7)
    a = c.sequence(42)
    b = c.sequence(42)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (65,)
    assert a.min() >= 0 and a.max() < 512


def test_corpus_is_learnable_structure():
    """Consecutive tokens follow the affine map most of the time."""
    c = SyntheticCorpus(vocab_size=512, seq_len=256, seed=7, noise=0.1)
    seq = c.sequence(3).astype(np.int64)
    # find the document's (a, b) by majority vote over observed transitions
    hits = 0
    for a in range(1, 512):
        b0 = (seq[1] - a * seq[0]) % 512
        pred = (a * seq[:-1] + b0) % 512
        hits = max(hits, (pred == seq[1:]).mean())
    assert hits > 0.5  # structure is recoverable


def test_pipeline_resume_and_determinism():
    c = SyntheticCorpus(vocab_size=128, seq_len=32)
    p = DataPipeline(c, global_batch=8)
    b1 = p.batch_at(5)
    b2 = DataPipeline(c, global_batch=8).batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"],
                                  np.roll(b1["tokens"], -1, axis=1)
                                  if False else b2["labels"])


def test_elastic_resharding_partitions_stream():
    """dp shards at any dp_size tile the same global index space."""
    c = SyntheticCorpus(vocab_size=128, seq_len=16)
    full = DataPipeline(c, global_batch=8, dp_rank=0, dp_size=1).batch_at(3)
    parts = [DataPipeline(c, global_batch=8, dp_rank=r, dp_size=4).batch_at(3)
             for r in range(4)]
    stacked = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], stacked)


def test_eval_stream_disjoint():
    c = SyntheticCorpus(vocab_size=128, seq_len=16)
    p = DataPipeline(c, global_batch=4)
    train = p.batch_at(0)["tokens"]
    ev = p.eval_batch(0, 4)["tokens"]
    assert not np.array_equal(train, ev)


def test_frontend_stubs_present():
    from repro.configs import get_arch, reduced
    cfg = reduced(get_arch("llava-next-mistral-7b").model)
    c = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=32)
    p = DataPipeline(c, global_batch=2, model_cfg=cfg)
    b = p.batch_at(0)
    assert b["patch_embeds"].shape == (2, cfg.prefix_tokens, cfg.d_model)
