"""Token-wise LR decay (paper A.2) — closed-form checks."""
import math

import pytest

from repro.configs.base import OptimizerConfig
from repro.optim import lr_at


def test_token_cosine_closed_form():
    cfg = OptimizerConfig(lr=6e-4, min_lr=1e-5, schedule="token_cosine",
                          warmup_tokens=1000, total_tokens=101_000)
    # warmup: linear in tokens
    assert lr_at(cfg, 0, 0) == pytest.approx(6e-4 / 1000)
    assert lr_at(cfg, 0, 499) == pytest.approx(6e-4 * 0.5, rel=1e-2)
    # cosine midpoint
    mid = lr_at(cfg, 0, 1000 + 50_000)
    assert mid == pytest.approx(1e-5 + 0.5 * (6e-4 - 1e-5), rel=1e-3)
    # end
    assert lr_at(cfg, 0, 101_000) == pytest.approx(1e-5)
    assert lr_at(cfg, 0, 10**12) == pytest.approx(1e-5)


def test_step_cosine_matches_token_cosine_at_constant_tokens_per_step():
    """With constant tokens/step the two schedules coincide — the paper's
    A.2 argument is exactly that SLW breaks this equivalence."""
    per_step = 100
    s_cfg = OptimizerConfig(lr=1e-3, min_lr=0.0, schedule="step_cosine",
                            warmup_steps=10, total_steps=110)
    t_cfg = OptimizerConfig(lr=1e-3, min_lr=0.0, schedule="token_cosine",
                            warmup_tokens=10 * per_step,
                            total_tokens=110 * per_step)
    for step in (10, 50, 80, 109):  # post-warmup (warmup discretizes
        # differently: per-step vs per-token granularity)
        assert lr_at(s_cfg, step, 0) == pytest.approx(
            lr_at(t_cfg, 0, step * per_step), rel=0.15)


def test_slw_tokenwise_slower_than_stepwise_early():
    """During warmup SLW sees fewer tokens/step; token-wise decay therefore
    holds LR *higher* at the same step index (A.2 Figure 8)."""
    full_tokens_per_step = 1000
    cfg_t = OptimizerConfig(lr=1e-3, min_lr=0.0, schedule="token_cosine",
                            warmup_tokens=0, total_tokens=100_000)
    cfg_s = OptimizerConfig(lr=1e-3, min_lr=0.0, schedule="step_cosine",
                            warmup_steps=0, total_steps=100)
    # at step 50, SLW has seen only ~20% of the tokens a full-length run has
    slw_tokens = 50 * full_tokens_per_step // 5
    assert lr_at(cfg_t, 50, slw_tokens) > lr_at(cfg_s, 50, 0)


def test_constant():
    cfg = OptimizerConfig(lr=3e-4, schedule="constant")
    assert lr_at(cfg, 123, 456) == 3e-4
