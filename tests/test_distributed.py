"""Distribution layer: sharding rules, flash-decode shard_map, compressed
all-reduce, and a mini-mesh dry-run — all on fake CPU devices in
subprocesses (the main test process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 540) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# rules (no devices needed)
# ---------------------------------------------------------------------------

def test_rules_conflict_and_divisibility_fallback():
    out = _run("""
        import jax
        from repro.distributed.sharding import ShardingRules
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((4, 2), ("data", "model"))
        rules = ShardingRules.make(mesh, "fsdp")
        # moe weight: experts takes model; embed takes data; mlp must back off
        spec = rules.param_spec(("experts", "embed", "mlp"), (8, 16, 64))
        assert spec == jax.sharding.PartitionSpec("model", "data", None), spec
        # non-divisible head count falls back to replication
        spec2 = rules.param_spec(("embed", "heads", "head_dim"), (16, 5, 64))
        assert spec2[1] is None, spec2
        assert any("heads=5" in f for f in rules.fallbacks)
        print("RULES_OK")
    """)
    assert "RULES_OK" in out


def test_serve_slot_state_shardings():
    """serve_tp placement of the engine's slot cache: the slot axis (the
    cache's "batch" logical axis, incl. the promoted per-slot pos vector)
    spreads over the data mesh axis; TP axes stay on model."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch, reduced
        from repro.distributed.sharding import (ShardingRules,
                                                tree_act_shardings)
        from repro.launch.mesh import make_host_mesh
        from repro.models import model_zoo
        from repro.serve import SlotDecodeState

        mesh = make_host_mesh((4, 2), ("data", "model"))
        rules = ShardingRules.make(mesh, "serve_tp")
        cfg = reduced(get_arch("gpt2-117m").model)
        model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
        shards = SlotDecodeState(model).shardings(rules, n_slots=8,
                                                  cache_len=32)
        P = jax.sharding.PartitionSpec
        assert shards["k"].spec[1] == "data", shards["k"].spec   # slot axis
        assert shards["pos"].spec == P("data"), shards["pos"].spec
        cache = model_zoo.init_decode_cache(model, 8, 32)
        cache = jax.device_put(cache, shards)
        assert cache["k"].sharding.spec[1] == "data"
        print("SLOT_SHARD_OK")
    """)
    assert "SLOT_SHARD_OK" in out


@pytest.mark.parametrize("backend", ["reference", "kernel_interpret"])
def test_flash_decode_sharded_matches_reference(backend):
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import (flash_decode_sharded,
                                                   reference_decode)
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((8,), ("data",))
        b, s, h, kv, d = 2, 64, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, 1, h, d))
        k = jax.random.normal(ks[1], (b, s, kv, d))
        v = jax.random.normal(ks[2], (b, s, kv, d))
        pos = jnp.int32(41)  # partial cache: some shards full, one ragged,
                             # some empty — the per-shard masking sweep
        fn = flash_decode_sharded(mesh, "data", backend="{backend}")
        out = jax.jit(fn)(q, k, v, pos)
        ref = reference_decode(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        print("FLASH_DECODE_OK")
    """)
    assert "FLASH_DECODE_OK" in out


@pytest.mark.slow
def test_compressed_allreduce_error_feedback_converges():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim.compression import (compressed_allreduce,
                                             init_error_state)
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((4,), ("pod",))
        sync = compressed_allreduce(mesh, "pod")
        g = {"w": jnp.array([0.5, -0.02, 0.3, -0.7])}
        err = init_error_state(g)
        acc = np.zeros(4)
        n = 40
        for _ in range(n):
            mean, err = sync(g, err)
            acc += np.asarray(mean["w"])
        # replicated input: exact mean == g; EF average must converge to it
        np.testing.assert_allclose(acc / n, np.asarray(g["w"]), atol=0.05)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


@pytest.mark.slow
def test_mini_mesh_dryrun_train_and_decode():
    """A scaled-down replica of the production dry-run on 8 fake devices:
    the same code path the 256/512-chip run uses (lower+compile+analyze)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch
        from repro.configs.base import OptimizerConfig
        from repro.distributed.sharding import ShardingRules
        from repro.launch import steps as steps_lib
        from repro.launch.mesh import make_host_mesh
        from repro.models import model_zoo
        from repro.roofline import analysis as roofline

        mesh = make_host_mesh((4, 2), ("data", "model"))
        cfg = get_arch("qwen2-1.5b").model
        rules = ShardingRules.make(mesh, "fsdp")
        model = model_zoo.build_model(cfg, dtype=jnp.bfloat16, remat="full")
        step = steps_lib.make_train_step(model, OptimizerConfig(), rules)
        state = steps_lib.abstract_train_state(cfg)
        st_sh = steps_lib.train_state_shardings(rules, cfg)
        batch = model_zoo.train_batch_specs(cfg, 8, 512)
        b_sh = steps_lib.batch_shardings(rules, cfg, batch)
        with mesh:
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh, None),
                              out_shardings=(st_sh, None),
                              donate_argnums=(0,)).lower(
                state, batch, jax.ShapeDtypeStruct((), jnp.float32))
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list): cost = cost[0]
        assert cost.get("flops", 0) > 0
        colls = roofline.parse_collectives(compiled.as_text(), 8)
        kinds = {c["kind"] for c in colls}
        # FSDP must produce gathers and grad reductions
        assert "all-gather" in kinds, kinds
        assert ("all-reduce" in kinds) or ("reduce-scatter" in kinds), kinds
        print("MINI_DRYRUN_OK", int(cost["flops"]))
    """, timeout=570)
    assert "MINI_DRYRUN_OK" in out
