"""Checkpoint: roundtrip, host state, keep-N GC, corruption tolerance."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruption, CheckpointManager,
                              available_steps, latest_step, restore, save)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros(8)},
            "opt": {"m": {"w": jnp.ones((4, 8)), "b": jnp.zeros(8)},
                    "count": jnp.int32(7)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save(d, 7, tree, {"tokens_seen": 12345, "curriculum": {"step": 7}})
    got, host = restore(d, 7, jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert host["tokens_seen"] == 12345


def test_latest_skips_incomplete(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 3, _tree())
    save(d, 9, _tree())
    # simulate a crash mid-write at step 12: directory without manifest
    os.makedirs(os.path.join(d, "step_000000000012"))
    assert latest_step(d) == 9


def test_keep_n_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert len(steps) == 2
    assert mgr.latest_step() == 4


def test_restore_latest_roundtrip_manager(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=3)
    tree = _tree()
    mgr.save(11, tree, {"step": 11})
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    step, got, host = mgr.restore_latest(like)
    assert step == 11 and host["step"] == 11


def test_restore_missing_leaf_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError, match="missing"):
        restore(d, 1, {"a": jnp.zeros(3), "b": jnp.zeros(3)})


# ---------------------------------------------------------------------------
# corruption detection + quarantine fallback (PR: recovery hardening)
# ---------------------------------------------------------------------------

def _like(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _flip_payload_byte(d, step, which=0):
    path = os.path.join(d, f"step_{step:012d}")
    payloads = sorted(n for n in os.listdir(path) if n.endswith(".npy"))
    target = os.path.join(path, payloads[which])
    with open(target, "r+b") as f:
        data = bytearray(f.read())
        data[-1] ^= 0xFF  # inside the array payload, past the .npy header
        f.seek(0)
        f.write(data)
    return target


def test_manifest_carries_crc32_per_leaf(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 2, _tree())
    with open(os.path.join(d, "step_000000000002", "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["leaves"]
    for meta in manifest["leaves"].values():
        assert isinstance(meta["crc32"], int)


def test_bitflip_fails_checksum(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save(d, 2, tree)
    _flip_payload_byte(d, 2)
    with pytest.raises(CheckpointCorruption, match="crc32"):
        restore(d, 2, _like(tree))


def test_shape_mismatch_is_corruption(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save(d, 2, tree)
    path = os.path.join(d, "step_000000000002")
    payloads = sorted(n for n in os.listdir(path) if n.endswith(".npy"))
    np.save(os.path.join(path, payloads[0]), np.zeros((2, 2)))
    with pytest.raises(CheckpointCorruption):
        restore(d, 2, _like(tree))


def test_unreadable_manifest_is_corruption(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save(d, 2, tree)
    with open(os.path.join(d, "step_000000000002", "manifest.json"),
              "w") as f:
        f.write("{not json")
    with pytest.raises(CheckpointCorruption):
        restore(d, 2, _like(tree))


def test_legacy_manifest_without_crc_still_restores(tmp_path):
    """Pre-hardening checkpoints lack the crc32 field — they must keep
    restoring (validation falls back to shape/dtype only)."""
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save(d, 2, tree)
    mpath = os.path.join(d, "step_000000000002", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    for meta in manifest["leaves"].values():
        del meta["crc32"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    got, _ = restore(d, 2, _like(tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_quarantines_and_falls_back(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=3)
    tree = _tree()
    for s in (3, 6, 9):
        mgr.save(s, tree, {"step": s})
    _flip_payload_byte(d, 9)
    step, got, host = mgr.restore_latest(_like(tree))
    assert step == 6 and host["step"] == 6
    assert [q[0] for q in mgr.quarantined] == [9]
    assert "crc32" in mgr.quarantined[0][2]
    # quarantined dir is renamed out of the trust path, payload kept
    assert os.path.isdir(os.path.join(d, "corrupt.step_000000000009"))
    assert latest_step(d) == 6
    assert available_steps(d) == [6, 3]


def test_restore_latest_all_corrupt_cold_starts(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=3)
    tree = _tree()
    for s in (3, 6):
        mgr.save(s, tree)
    _flip_payload_byte(d, 3)
    _flip_payload_byte(d, 6)
    step, got, host = mgr.restore_latest(_like(tree))
    assert step is None and got is None and host is None
    assert sorted(q[0] for q in mgr.quarantined) == [3, 6]
