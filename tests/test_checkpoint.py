"""Checkpoint: roundtrip, host state, keep-N GC, corruption tolerance."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros(8)},
            "opt": {"m": {"w": jnp.ones((4, 8)), "b": jnp.zeros(8)},
                    "count": jnp.int32(7)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save(d, 7, tree, {"tokens_seen": 12345, "curriculum": {"step": 7}})
    got, host = restore(d, 7, jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert host["tokens_seen"] == 12345


def test_latest_skips_incomplete(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 3, _tree())
    save(d, 9, _tree())
    # simulate a crash mid-write at step 12: directory without manifest
    os.makedirs(os.path.join(d, "step_000000000012"))
    assert latest_step(d) == 9


def test_keep_n_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert len(steps) == 2
    assert mgr.latest_step() == 4


def test_restore_latest_roundtrip_manager(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=3)
    tree = _tree()
    mgr.save(11, tree, {"step": 11})
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    step, got, host = mgr.restore_latest(like)
    assert step == 11 and host["step"] == 11


def test_restore_missing_leaf_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError, match="missing"):
        restore(d, 1, {"a": jnp.zeros(3), "b": jnp.zeros(3)})
