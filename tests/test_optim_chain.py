"""The composable optimizer chain: legacy-exactness, state migration,
decay masking, the opt-in arms (SM3 / Shampoo / AGC / per-leaf LR), and
per-parameter telemetry driving per-layer blame end to end.

The legacy-parity tests are the contract that lets the chain replace
``adamw_update`` on the hot path: the default chain must reproduce the
legacy trajectory *numerically exactly* (params, opt state, scalar
telemetry), including across a mid-run checkpoint/restore.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt_lib
from repro.checkpoint import migrate_host_state
from repro.configs import get_arch, reduced
from repro.configs.base import (OptimizerConfig, RegulatorSpec, SLWConfig,
                                TrainConfig)
from repro.optim import (adamw_update, adaptive_grad_clip, apply_updates,
                         abstract_chain_state, build_optimizer, chain,
                         clip_by_global_norm, decay_mask_tree,
                         init_opt_state, migrate_opt_state, scale_by_lr,
                         scale_by_sm3, scale_per_leaf)
from repro.optim import transforms as tx_lib


def _toy_params(seed=0):
    """Mixed-shape tree shaped like the model zoo: scan-stacked layer
    leaves under 'layers', a matrix, a bias, a scalar."""
    rng = np.random.RandomState(seed)
    return {
        "embed": jnp.asarray(rng.randn(16, 8), jnp.float32),
        "layers": {
            "w": jnp.asarray(rng.randn(2, 8, 8), jnp.float32),
            "scale": jnp.asarray(rng.randn(2, 8), jnp.float32),
        },
        "bias": jnp.asarray(rng.randn(8), jnp.float32),
        "gain": jnp.asarray(rng.randn(), jnp.float32),
    }


def _grads_like(params, seed):
    rng = np.random.RandomState(seed)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.randn(*p.shape), jnp.float32), params)


def _legacy_step(params, grads, opt, lr, cfg, clip_scale=1.0):
    clipped, gnorm = clip_by_global_norm(grads, cfg.grad_clip * clip_scale)
    new_p, new_opt, tel = adamw_update(params, clipped, opt,
                                       jnp.float32(lr), cfg)
    tel = dict(tel, grad_norm=gnorm)
    return new_p, new_opt, tel


def _chain_step(tx, params, grads, opt, lr, clip_scale=1.0):
    updates, new_opt, tel = tx.update(
        grads, opt, params,
        {"lr": jnp.float32(lr), "clip_scale": jnp.float32(clip_scale)})
    return apply_updates(params, updates), new_opt, tel


# ---------------------------------------------------------------------------
# legacy parity (the acceptance contract)
# ---------------------------------------------------------------------------

def test_default_chain_matches_legacy_over_50_steps(tmp_path):
    """Default chain == legacy clip+AdamW for 50 steps, bitwise on params
    and opt state, with a checkpoint/restore of the chain state at step 25
    (restore must not perturb the trajectory either)."""
    cfg = OptimizerConfig(lr=3e-3, weight_decay=0.01, grad_clip=1.0)
    tx = build_optimizer(cfg)

    p_legacy = p_chain = _toy_params()
    o_legacy = init_opt_state(p_legacy)
    o_chain = tx.init(p_chain)

    for step in range(50):
        lr = 3e-3 * (0.5 + 0.5 * math.cos(step / 50 * math.pi))
        clip_scale = 0.5 if 20 <= step < 30 else 1.0  # runtime retuning
        g = _grads_like(p_legacy, seed=100 + step)
        p_legacy, o_legacy, t_legacy = _legacy_step(
            p_legacy, g, o_legacy, lr, cfg, clip_scale)
        p_chain, o_chain, t_chain = _chain_step(
            tx, p_chain, g, o_chain, lr, clip_scale)

        if step == 25:  # mid-run checkpoint/restore of the chain state
            ckpt_lib.save(str(tmp_path), step, {"opt": o_chain})
            like = {"opt": abstract_chain_state(
                tx, jax.eval_shape(lambda: p_chain))}
            restored, _ = ckpt_lib.restore(str(tmp_path), step, like)
            o_chain = restored["opt"]

    for a, b in zip(jax.tree_util.tree_leaves(p_legacy),
                    jax.tree_util.tree_leaves(p_chain)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(o_legacy),
                    jax.tree_util.tree_leaves(o_chain["adam"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # same scalar telemetry, same values
    for k in ("var_max", "var_l1", "grad_norm"):
        assert float(t_legacy[k]) == float(t_chain[k]), k


def test_chain_state_layout_and_abstract_shapes():
    cfg = OptimizerConfig()
    tx = build_optimizer(cfg)
    p = _toy_params()
    st = tx.init(p)
    assert sorted(st.keys()) == ["adam", "clip", "decay", "lr"]
    assert st["clip"] == {} and st["lr"] == {}
    abs_st = abstract_chain_state(tx, jax.eval_shape(lambda: p))
    assert (jax.tree_util.tree_structure(abs_st)
            == jax.tree_util.tree_structure(st))


# ---------------------------------------------------------------------------
# legacy checkpoint / host-state migration (satellite: migrate tests)
# ---------------------------------------------------------------------------

def test_restore_legacy_flat_opt_checkpoint_into_chain(tmp_path):
    """A pre-chain checkpoint stored the AdamW state flat under ``opt/``;
    restoring into the chain layout must remap it into the ``adam`` slot."""
    p = _toy_params()
    legacy_opt = init_opt_state(p)
    # march the legacy state so the payload is non-trivial
    cfg = OptimizerConfig(lr=1e-2)
    p2, legacy_opt, _ = adamw_update(p, _grads_like(p, 7), legacy_opt,
                                     jnp.float32(1e-2), cfg)
    ckpt_lib.save(str(tmp_path), 3, {"params": p2, "opt": legacy_opt})

    tx = build_optimizer(cfg)
    like = {"params": jax.eval_shape(lambda: p2),
            "opt": abstract_chain_state(tx, jax.eval_shape(lambda: p2))}
    restored, _ = ckpt_lib.restore(str(tmp_path), 3, like)
    assert int(restored["opt"]["adam"]["count"]) == 1
    for a, b in zip(jax.tree_util.tree_leaves(legacy_opt["m"]),
                    jax.tree_util.tree_leaves(restored["opt"]["adam"]["m"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_migrate_host_state_upgrades_legacy_opt():
    host = {"opt": {"m": {"w": [1.0]}, "v": {"w": [2.0]}, "count": 5},
            "controller": {"step": 5}}
    out = migrate_host_state(host)
    assert out["opt"]["adam"]["count"] == 5
    assert out["opt"]["clip"] == {} and out["opt"]["lr"] == {}
    # already-migrated passes through untouched
    assert migrate_opt_state(out["opt"]) is out["opt"]


# ---------------------------------------------------------------------------
# decay mask (satellite: the decay-every-leaf fix)
# ---------------------------------------------------------------------------

def test_decay_mask_std_exempts_norm_gains_and_biases():
    p = _toy_params()
    mask = decay_mask_tree(p, "std")
    assert mask["embed"] is True            # matrix: decays
    assert mask["layers"]["w"] is True      # stacked matrices: decay
    assert mask["layers"]["scale"] is False  # stacked norm gain (L, d): no
    assert mask["bias"] is False
    assert mask["gain"] is False
    # legacy mode decays everything (the old behavior, still the default)
    assert all(jax.tree_util.tree_leaves(decay_mask_tree(p, "all")))
    with pytest.raises(ValueError):
        decay_mask_tree(p, "nope")


def test_adamw_std_mask_leaves_gains_undecayed():
    """Regression for the decay-every-leaf bug: with zero grads the Adam
    core contributes nothing, so the only movement is weight decay — masked
    leaves must not move at all under decay_mask='std'."""
    cfg = OptimizerConfig(lr=1e-2, weight_decay=0.1, decay_mask="std")
    p = _toy_params()
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p)
    new_p, _, _ = adamw_update(p, zeros, init_opt_state(p),
                               jnp.float32(cfg.lr), cfg)
    np.testing.assert_array_equal(np.asarray(new_p["bias"]),
                                  np.asarray(p["bias"]))
    np.testing.assert_array_equal(np.asarray(new_p["layers"]["scale"]),
                                  np.asarray(p["layers"]["scale"]))
    # while matrices did decay
    assert not np.array_equal(np.asarray(new_p["embed"]),
                              np.asarray(p["embed"]))
    # and the chain applies the identical mask
    tx = build_optimizer(cfg)
    chain_p, _, _ = _chain_step(tx, p, zeros, tx.init(p), cfg.lr)
    for a, b in zip(jax.tree_util.tree_leaves(new_p),
                    jax.tree_util.tree_leaves(chain_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# the opt-in arms
# ---------------------------------------------------------------------------

def test_sm3_memory_shape_and_descent():
    cfg = OptimizerConfig(optimizer="sm3", lr=1e-2, weight_decay=0.0,
                          grad_clip=0.0, sm3_momentum=0.9)
    tx = build_optimizer(cfg)
    p = _toy_params()
    st = tx.init(p)
    # accumulators are per-dimension, not per-element: a (16, 8) leaf costs
    # 16 + 8 floats, not 128.  Leaves flatten in sorted-key order:
    # bias, embed, gain, layers/scale, layers/w
    accs = st["sm3"]["acc"][1]  # embed (16, 8)
    assert [a.shape for a in accs] == [(16, 1), (1, 8)]
    g = _grads_like(p, 3)
    new_p, new_st, tel = _chain_step(tx, p, g, st, 1e-2)
    assert "var_max" in tel and np.isfinite(float(tel["var_max"]))
    # the update moved every leaf
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(new_p)):
        assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_shampoo_grafts_adam_norm():
    """The Shampoo direction is rescaled per block to the Adam update norm:
    block norms of the final update must match the Adam arm's block norms."""
    cfg_sh = OptimizerConfig(optimizer="shampoo", lr=1e-2, weight_decay=0.0,
                             grad_clip=0.0, shampoo_interval=1)
    cfg_ad = dataclasses.replace(cfg_sh, optimizer="adamw")
    p = {"w": jnp.asarray(np.random.RandomState(0).randn(2, 8, 8),
                          jnp.float32)}
    g = _grads_like(p, 5)
    hyper = {"lr": jnp.float32(1.0), "clip_scale": jnp.float32(1.0)}

    tx_sh = build_optimizer(cfg_sh)
    u_sh, _, _ = tx_sh.update(g, tx_sh.init(p), p, hyper)
    tx_ad = build_optimizer(cfg_ad)
    u_ad, _, _ = tx_ad.update(g, tx_ad.init(p), p, hyper)

    n_sh = np.sqrt(np.sum(np.asarray(u_sh["w"]) ** 2, axis=(-2, -1)))
    n_ad = np.sqrt(np.sum(np.asarray(u_ad["w"]) ** 2, axis=(-2, -1)))
    np.testing.assert_allclose(n_sh, n_ad, rtol=1e-5)
    # but the direction differs (the preconditioner did something)
    assert not np.allclose(np.asarray(u_sh["w"]), np.asarray(u_ad["w"]),
                           rtol=1e-3)


def test_shampoo_ineligible_leaf_falls_back_to_adam():
    cfg = OptimizerConfig(optimizer="shampoo", lr=1e-2, weight_decay=0.0,
                          grad_clip=0.0, shampoo_block_size=4)
    p = {"big": jnp.ones((8, 8)), "vec": jnp.ones((5,))}  # both ineligible
    tx = build_optimizer(cfg)
    st = tx.init(p)
    assert st["shampoo"]["stats"] == (None, None)
    cfg_ad = dataclasses.replace(cfg, optimizer="adamw")
    tx_ad = build_optimizer(cfg_ad)
    g = _grads_like(p, 9)
    hyper = {"lr": jnp.float32(1.0), "clip_scale": jnp.float32(1.0)}
    u_sh, _, _ = tx.update(g, st, p, hyper)
    u_ad, _, _ = tx_ad.update(g, tx_ad.init(p), p, hyper)
    for a, b in zip(jax.tree_util.tree_leaves(u_sh),
                    jax.tree_util.tree_leaves(u_ad)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_agc_clips_by_grad_to_weight_ratio():
    agc = adaptive_grad_clip(clipping=0.1)
    p = {"w": jnp.full((4,), 2.0)}          # ||p|| = 4
    g_small = {"w": jnp.full((4,), 0.05)}   # ||g|| = 0.1 < 0.1*4: untouched
    g_big = {"w": jnp.full((4,), 5.0)}      # ||g|| = 10  > 0.4: clipped
    out, _, _ = agc.update(g_small, {}, p, {})
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(g_small["w"]), rtol=1e-6)
    out, _, _ = agc.update(g_big, {}, p, {})
    gn = float(np.sqrt(np.sum(np.asarray(out["w"]) ** 2)))
    assert gn == pytest.approx(0.4, rel=1e-5)


def test_scale_per_leaf_patterns_compose():
    tx = chain(scale_per_leaf((("layers", 0.5), ("scale", 0.4))),
               scale_by_lr())
    p = _toy_params()
    u = jax.tree_util.tree_map(jnp.ones_like, p)
    out, _, _ = tx.update(u, tx.init(p), p, {"lr": jnp.float32(2.0)})
    assert float(out["embed"][0, 0]) == pytest.approx(2.0)       # no match
    assert float(out["layers"]["w"][0, 0, 0]) == pytest.approx(1.0)
    # both patterns match layers/scale: 2.0 * 0.5 * 0.4
    assert float(out["layers"]["scale"][0, 0]) == pytest.approx(0.4)


def test_per_leaf_telemetry_vectors_line_up_with_labels():
    from repro.core.telemetry import param_labels, split_metrics
    cfg = OptimizerConfig(telemetry_level="per_leaf")
    tx = build_optimizer(cfg)
    p = _toy_params()
    labels = param_labels(p)
    g = _grads_like(p, 11)
    _, _, tel = tx.update(g, tx.init(p), p,
                          {"lr": jnp.float32(1e-3),
                           "clip_scale": jnp.float32(1.0)})
    scalars, per_leaf = split_metrics(dict(tel))
    assert per_leaf is not None
    for key in ("var_max", "grad_norm", "update_norm", "param_norm",
                "grad_to_weight"):
        assert per_leaf[key].shape == (len(labels),), key
    # scalar keys unpolluted by vectors
    assert all(np.ndim(v) == 0 for v in scalars.values())
    # the per-leaf grad norms recompose into the global norm
    gnorm = float(np.sqrt(np.sum(per_leaf["grad_norm"] ** 2)))
    assert gnorm == pytest.approx(float(scalars["grad_norm"]), rel=1e-5)


# ---------------------------------------------------------------------------
# end to end: per-layer blame under an injected one-block gradient spike
# ---------------------------------------------------------------------------

def _blame_tc(steps, telemetry_level="per_leaf"):
    cfg = reduced(get_arch("gpt2-117m").model).replace(vocab_size=128)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=steps,
                          total_tokens=10 ** 9, schedule="constant",
                          telemetry_level=telemetry_level)
    tc = TrainConfig(model=cfg, optimizer=opt, seq_len=32, global_batch=4,
                     seed=0, eval_interval=0, checkpoint_interval=0)
    from repro.core.regulators import auto_specs
    return dataclasses.replace(
        tc, regulators=auto_specs(tc)
        + (RegulatorSpec(kind="var_lr_throttle"),))


def test_per_leaf_blame_identifies_injected_layer():
    """The acceptance drill: --inject-faults targeting one block's grads;
    the per-leaf-telemetry-fed throttle must name that block."""
    from repro.distributed.fault_injection import FaultInjector
    from repro.launch.train import train

    class Grab:
        tr = None

        def on_run_start(self, tr):
            Grab.tr = tr

        def on_step_start(self, tr):
            pass

        def on_step_end(self, tr, tele, plan, metrics):
            pass

        def on_run_end(self, tr):
            pass

        def close(self):
            pass

    inj = FaultInjector.from_cli("grad_spike@8:1000|layers/attn", seed=0)
    res = train(_blame_tc(12), fault_injector=inj, hooks=[Grab()])
    assert res.faults_fired == ["grad_spike@8:1000|layers/attn"]
    throttle = Grab.tr.stack["var_lr_throttle"]
    assert throttle.blamed.startswith("layers/attn"), throttle.blamed
    assert throttle.scale < 1.0  # and it actually intervened


# ---------------------------------------------------------------------------
# shampoo preconditioner-staleness telemetry
# ---------------------------------------------------------------------------

def test_shampoo_staleness_telemetry_tracks_refresh_cadence():
    """`shampoo_staleness` counts steps since the last eigh refresh: a
    sawtooth 0..interval-1, resetting on every recompute step."""
    interval = 5
    cfg = OptimizerConfig(optimizer="shampoo", lr=1e-2, weight_decay=0.0,
                          grad_clip=0.0, shampoo_interval=interval)
    p = {"w": jnp.asarray(np.random.RandomState(0).randn(8, 8), jnp.float32)}
    tx = build_optimizer(cfg)
    st = tx.init(p)
    series = []
    for step in range(2 * interval + 2):
        g = _grads_like(p, step)
        p, st, tel = _chain_step(tx, p, g, st, 1e-3)
        assert "shampoo_staleness" in tel
        series.append(int(tel["shampoo_staleness"]))
    assert series == [s % interval for s in range(len(series))]
    # interval=1 refreshes every step: staleness is identically zero
    cfg1 = dataclasses.replace(cfg, shampoo_interval=1)
    tx1 = build_optimizer(cfg1)
    st1 = tx1.init(p)
    for step in range(3):
        p, st1, tel = _chain_step(tx1, p, _grads_like(p, step), st1, 1e-3)
        assert int(tel["shampoo_staleness"]) == 0


def test_adam_chain_has_no_staleness_row():
    cfg = OptimizerConfig(lr=1e-2, weight_decay=0.0, grad_clip=0.0)
    p = _toy_params()
    tx = build_optimizer(cfg)
    _, _, tel = _chain_step(tx, p, _grads_like(p, 1), tx.init(p), 1e-3)
    assert "shampoo_staleness" not in tel


# ---------------------------------------------------------------------------
# runtime per-leaf LR scale (the recovery controller's backoff surface)
# ---------------------------------------------------------------------------

def test_scale_by_lr_runtime_leaf_vector():
    tx = tx_lib.scale_by_lr()
    p = _toy_params()
    u = jax.tree_util.tree_map(jnp.ones_like, p)
    n_leaves = len(jax.tree_util.tree_leaves(u))
    # absent key: the legacy single-scalar trace
    out, _, _ = tx.update(u, tx.init(p), p, {"lr": jnp.float32(2.0)})
    for leaf in jax.tree_util.tree_leaves(out):
        np.testing.assert_allclose(np.asarray(leaf), 2.0)
    # with the vector: each leaf additionally scaled by its entry, in
    # tree_leaves order
    scales = jnp.asarray(np.linspace(0.1, 1.0, n_leaves), jnp.float32)
    out, _, _ = tx.update(u, tx.init(p), p,
                          {"lr": jnp.float32(2.0), "leaf_lr_scale": scales})
    for i, leaf in enumerate(jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(np.asarray(leaf), 2.0 * float(scales[i]),
                                   rtol=1e-6)


def test_clip_reports_raw_and_clipped_norms():
    """Satellite regression: `grad_norm` is the raw pre-clip global norm
    (what the noise regulators consume), `grad_norm_clipped` the post-clip
    value — under persistent clipping the clipped norm saturates at the
    limit while the raw norm still varies."""
    tx = tx_lib.clip_global_norm(1.0)
    p = _toy_params()
    raws, clippeds = [], []
    for scale in (4.0, 8.0, 16.0):
        g = jax.tree_util.tree_map(lambda x: scale * jnp.ones_like(x), p)
        _, _, tel = tx.update(g, {}, p, {"clip_scale": jnp.float32(1.0)})
        raws.append(float(tel["grad_norm"]))
        clippeds.append(float(tel["grad_norm_clipped"]))
    assert raws[0] < raws[1] < raws[2]          # raw norm tracks the input
    for c in clippeds:
        assert c == pytest.approx(1.0, rel=1e-5)   # clipped saturates
