"""Flash-decode kernel contracts.

* interpret-mode kernel vs the jnp decode oracle across per-slot ragged
  lengths, GQA group sizes (MQA/GQA/MHA) and uneven cache tails;
* partial-softmax (o, m, l) parity — the triple the sharded flash-decoding
  merge consumes — plus a host-side shard merge of kernel partials against
  the full-cache reference;
* ``decode_attention`` backend dispatch: kernel vs reference on both the
  per-slot-pos (engine) and scalar-pos (legacy) paths;
* the one shared masking convention ("pos = count of valid entries")
  across ``decode_attention``, ``reference_decode`` and the kernel — the
  parity test that would have caught a one-token-stale cache read.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.distributed.collectives import reference_decode
from repro.kernels.flash_decode.ops import flash_decode, flash_decode_partials
from repro.kernels.flash_decode.ref import (decode_attention_reference,
                                            decode_partials_reference)
from repro.models import attention as attn_mod
from repro.models import layers as L


# ---------------------------------------------------------------------------
# kernel vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,d,block_k", [
    (3, 128, 4, 2, 32, 64),    # GQA, two kv blocks
    (2, 96, 8, 8, 16, 32),     # MHA, three blocks
    (4, 80, 4, 1, 64, 32),     # MQA, uneven tail (80 % 32 != 0 -> padded)
    (1, 48, 6, 3, 16, 128),    # block_k > S clamps to one block
    (2, 200, 2, 2, 8, 64),     # uneven tail + tiny heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_reference(b, s, h, kv, d, block_k, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 4)
    q = jax.random.normal(ks[0], (b, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d)).astype(dtype)
    # ragged per-slot lengths including the 1 and S endpoints
    lengths = jnp.asarray(
        np.concatenate([[1, s], np.random.default_rng(0).integers(
            1, s + 1, size=max(b - 2, 0))])[:b], jnp.int32)
    out = flash_decode(q, k, v, lengths, block_k=block_k, interpret=True)
    ref = decode_attention_reference(q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32), lengths)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_decode_partials_match_reference():
    """(o, m, l) — the merge currency of flash_decode_sharded — agree
    between kernel and oracle under multi-block accumulation."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    b, s, h, kv, d = 3, 96, 4, 2, 16
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    lengths = jnp.asarray([7, 96, 33], jnp.int32)
    got = flash_decode_partials(q, k, v, lengths, block_k=32, interpret=True)
    want = decode_partials_reference(q, k, v, lengths)
    for name, a, r in zip(("o", "m", "l"), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), atol=1e-5,
                                   rtol=1e-5, err_msg=name)


def test_flash_decode_zero_length_slot_is_inert():
    """A retired/empty slot (lengths == 0) yields exactly-zero context and
    (m, l) = (NEG_INF, 0) partials that drop out of a shard merge."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 16))
    k = jax.random.normal(ks[1], (2, 32, 2, 16))
    v = jax.random.normal(ks[2], (2, 32, 2, 16))
    lengths = jnp.asarray([0, 9], jnp.int32)
    o, m, l = flash_decode_partials(q, k, v, lengths, block_k=16,
                                    interpret=True)
    assert float(jnp.abs(o[0]).max()) == 0.0
    assert float(l[0].max()) == 0.0
    assert float(m[0].max()) < -1e29
    out = flash_decode(q, k, v, lengths, block_k=16, interpret=True)
    assert float(jnp.abs(out[0]).max()) == 0.0


def test_sharded_merge_consumes_kernel_partials():
    """Host-side replay of the flash_decode_sharded merge over kernel
    partials (one per sequence shard) reproduces the full-cache reference
    — the unified masking semantics the ISSUE asks for."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, h, kv, d, n_shards = 2, 128, 4, 2, 16, 4
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    pos = 71  # count of valid entries: shards 0-1 full, 2 ragged, 3 empty
    s_loc = s // n_shards
    parts = []
    for i in range(n_shards):
        lengths = jnp.full((b,), np.clip(pos - i * s_loc, 0, s_loc),
                           jnp.int32)
        parts.append(flash_decode_partials(
            q, k[:, i * s_loc:(i + 1) * s_loc], v[:, i * s_loc:(i + 1) * s_loc],
            lengths, block_k=16, interpret=True))
    gm = jnp.stack([m for _, m, _ in parts]).max(axis=0)
    l_tot = sum(l * jnp.exp(m - gm) for _, m, l in parts)
    o_tot = sum(o * jnp.exp(m - gm)[..., None] for o, m, _ in parts)
    merged = (o_tot / jnp.maximum(l_tot[..., None], 1e-30)).reshape(b, h, d)
    ref = reference_decode(q[:, None], k, v, jnp.int32(pos))[:, 0]
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# decode_attention backend dispatch + the shared mask convention
# ---------------------------------------------------------------------------

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8)


def _attn_params(cfg, seed=0):
    return L.init_params(jax.random.PRNGKey(seed),
                         attn_mod.attention_def(cfg))


@pytest.mark.parametrize("per_slot", [True, False])
def test_decode_attention_kernel_backend_matches_reference(per_slot):
    b, s_max = 3, 48
    params = _attn_params(CFG)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (b, 1, CFG.d_model))
    # garbage beyond each row's depth must stay masked on both backends
    cache_k = jax.random.normal(ks[1], (b, s_max, 2, 8))
    cache_v = jax.random.normal(ks[2], (b, s_max, 2, 8))
    pos = jnp.asarray([0, 11, 40], jnp.int32) if per_slot else jnp.int32(11)
    outs = {}
    for backend in ("reference", "kernel_interpret"):
        cfg = CFG.replace(decode_backend=backend)
        outs[backend] = attn_mod.decode_attention(params, x, cfg, cache_k,
                                                  cache_v, pos)
    for a, r in zip(outs["kernel_interpret"], outs["reference"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), atol=1e-5,
                                   rtol=1e-5)


def test_decode_mask_convention_counts_the_written_token():
    """One convention everywhere: pos = count of valid entries.  The token
    written by the decode step itself is entry ``pos`` of the cache and
    must be attended (arange < pos + 1); a stale convention (arange < pos)
    reads the cache one token behind and shifts the output."""
    b, s_max, p = 2, 32, 9
    params = _attn_params(CFG)
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.normal(ks[0], (b, 1, CFG.d_model))
    cache_k = jnp.zeros((b, s_max, 2, 8))
    cache_v = jnp.zeros((b, s_max, 2, 8))
    prefix = jax.random.normal(ks[1], (b, p, 2, 8))
    cache_k = cache_k.at[:, :p].set(prefix)
    cache_v = cache_v.at[:, :p].set(
        jax.random.normal(ks[2], (b, p, 2, 8)))
    pos = jnp.full((b,), p, jnp.int32)  # rows decode at position p
    out, new_k, new_v = attn_mod.decode_attention(params, x, CFG, cache_k,
                                                  cache_v, pos)
    # oracle: reference_decode over the *updated* cache with count = p + 1
    q, _, _ = attn_mod._project_qkv(params, x, CFG, pos[:, None])
    ctx = reference_decode(q, new_k, new_v, pos + 1)
    want = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-5)
    # the stale count (p) excludes the just-written token -> different out
    stale_ctx = reference_decode(q, new_k, new_v, pos)
    stale = jnp.einsum("bshk,hkd->bsd", stale_ctx, params["wo"])
    assert float(jnp.abs(np.asarray(out) - np.asarray(stale)).max()) > 1e-4
    # and the new entries really are this step's k/v at row p
    _, k_new, v_new = attn_mod._project_qkv(params, x, CFG, pos[:, None])
    np.testing.assert_allclose(np.asarray(new_k[:, p]), np.asarray(k_new[:, 0]),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("s,block_kv,causal", [
    (544, 512, True),    # divisor path: 272 divides 544 (used to assert)
    (149, 64, True),     # prime length: pad + dead-key masking
    (149, 64, False),    # non-causal padding needs the explicit key mask
    (96, 512, True),     # block_kv > sk clamps
])
def test_blockwise_attention_non_divisible_block_kv(s, block_kv, causal):
    """Lengths that don't divide block_kv (odd buckets, primes) must scan
    exactly — largest in-range divisor or pad+mask — and match full
    softmax."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    b, h, kv, d = 1, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = attn_mod.blockwise_attention(q, k, v, causal=causal,
                                       block_kv=block_kv)
    # dense reference
    g = h // kv
    qg = q.reshape(b, s, kv, g, d) / math.sqrt(d)
    sc = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k)
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    pr = jax.nn.softmax(sc, axis=-1)
    ref = jnp.einsum("bkgqj,bjkd->bkgqd", pr, v)
    ref = ref.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
