"""MoE dispatch correctness: scatter-based routing vs a per-token loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models.layers import init_params
from repro.models.moe import capacity, moe_ffn, moe_ffn_def


def _setup(capacity_factor=8.0):
    cfg = reduced(get_arch("deepseek-moe-16b").model).replace(
        capacity_factor=capacity_factor, n_shared_experts=0)
    defs = moe_ffn_def(cfg)
    params = init_params(jax.random.PRNGKey(0), defs)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return cfg, params, x


def _oracle(params, x, cfg):
    """Per-token dense loop (no capacity drops)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for t in range(xt.shape[0]):
        acc = jnp.zeros(d)
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(xt[t] @ params["w_gate"][e]) * (
                xt[t] @ params["w_up"][e])
            acc = acc + gate[t, j] * (h @ params["w_down"][e])
        outs.append(acc)
    return jnp.stack(outs).reshape(b, s, d)


def test_moe_matches_per_token_oracle():
    cfg, params, x = _setup(capacity_factor=8.0)  # no drops
    y, aux = moe_ffn(params, x, cfg)
    ref = _oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
    assert float(aux["dropped_frac"]) == 0.0


def test_capacity_drops_are_bounded_and_reported():
    cfg, params, x = _setup(capacity_factor=0.5)
    y, aux = moe_ffn(params, x, cfg)
    assert 0.0 < float(aux["dropped_frac"]) < 1.0
    assert np.isfinite(np.asarray(y)).all()


def test_capacity_formula():
    cfg, _, _ = _setup(capacity_factor=1.25)
    t = 64
    c = capacity(t, cfg)
    assert c == int(np.ceil(t * cfg.top_k / cfg.n_experts
                            * cfg.capacity_factor))


def test_load_balance_loss_uniform_is_one():
    """For a perfectly uniform router, E * sum(f_e * p_e) -> top_k-normalized
    value around 1.0."""
    cfg, params, x = _setup()
    # force uniform router
    params = dict(params, router=jnp.zeros_like(params["router"]))
    y, aux = moe_ffn(params, x, cfg)
    assert float(aux["load_balance"]) == pytest.approx(1.0, rel=0.05)


def test_moe_gradients_flow_to_experts():
    cfg, params, x = _setup()

    def loss(p):
        y, aux = moe_ffn(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux["load_balance"]

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0
