"""Divergence-aware recovery: detector classification, the snapshot ring,
the intervention regulator, and end-to-end rollback under injected faults.

The end-to-end tests drive the real trainer with the real fault injector —
nothing here monkeypatches the recovery path itself; faults go in through
``FaultInjector`` exactly as the chaos benchmark injects them.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import OptimizerConfig, SLWConfig, TrainConfig
from repro.core.recovery import (DivergenceDetector, DivergenceError,
                                 RecoveryConfig, RecoveryRegulator, StateRing)
from repro.core.regulators import StepPlan, StepTelemetry
from repro.distributed.fault_injection import FaultInjector, parse_faults
from repro.distributed.fault_tolerance import RetryPolicy, TrainSupervisor
from repro.launch.train import Trainer, train


def _tc(steps=20, seq=64, batch=4, lr=2e-3, ckpt_dir="", interval=0,
        vocab=128):
    cfg = reduced(get_arch("gpt2-117m").model).replace(vocab_size=vocab)
    return TrainConfig(
        model=cfg,
        optimizer=OptimizerConfig(
            lr=lr, min_lr=1e-5, schedule="token_cosine",
            warmup_steps=4, warmup_tokens=4 * batch * seq,
            total_steps=steps, total_tokens=steps * batch * seq),
        slw=SLWConfig(enabled=True, pacing="linear", start_seq_len=8,
                      duration_steps=steps // 2, round_multiple=8,
                      max_buckets=4),
        seq_len=seq, global_batch=batch, remat="none",
        eval_interval=0, checkpoint_interval=interval,
        checkpoint_dir=ckpt_dir)


def _tele(step, loss=2.0, ratio=1.0, grad=1.0, var=1e-6):
    return StepTelemetry(step=step, loss=loss, loss_ratio=ratio,
                         grad_norm=grad, var_max=var)


# ---------------------------------------------------------------------------
# detector
# ---------------------------------------------------------------------------

def test_detector_nan_fires_unconditionally():
    det = DivergenceDetector(RecoveryConfig(grace_steps=100,
                                            cooldown_steps=100))
    ev = det.update(_tele(0, loss=float("nan")))
    assert ev is not None and ev.kind == "nan_loss"
    ev = det.update(_tele(1, grad=float("inf")))
    assert ev is not None and ev.kind == "nan_grad"
    # NaN pierces even an active cooldown
    det.begin_cooldown()
    ev = det.update(_tele(2, loss=float("inf")))
    assert ev is not None and ev.kind == "nan_loss"


def test_detector_spike_respects_grace_and_cooldown():
    cfg = RecoveryConfig(spike_ratio=3.0, grace_steps=3, cooldown_steps=2)
    det = DivergenceDetector(cfg)
    for i in range(3):  # grace: a huge ratio does not fire yet
        assert det.update(_tele(i, ratio=50.0)) is None
    ev = det.update(_tele(3, ratio=50.0))
    assert ev is not None and ev.kind == "loss_spike"
    det.begin_cooldown()
    assert det.update(_tele(4, ratio=50.0)) is None  # cooldown 1
    assert det.update(_tele(5, ratio=50.0)) is None  # cooldown 2
    ev = det.update(_tele(6, ratio=50.0))
    assert ev is not None and ev.kind == "loss_spike"


def test_detector_var_excursion_needs_sustain():
    cfg = RecoveryConfig(var_gate=8.0, var_sustain=3, grace_steps=2)
    det = DivergenceDetector(cfg)
    for i in range(2):
        assert det.update(_tele(i, var=1.0)) is None
    base = det.var_trailing
    assert base > 0.0
    # two excursion steps: streak builds, no event, trailing frozen
    assert det.update(_tele(2, var=100.0)) is None
    assert det.update(_tele(3, var=100.0)) is None
    assert det.var_trailing == base  # the gate must not chase the spike
    ev = det.update(_tele(4, var=100.0))
    assert ev is not None and ev.kind == "var_excursion"
    # a clean sample resets the streak
    det2 = DivergenceDetector(cfg)
    for i in range(2):
        det2.update(_tele(i, var=1.0))
    det2.update(_tele(2, var=100.0))
    det2.update(_tele(3, var=1.0))   # streak broken
    det2.update(_tele(4, var=100.0))
    assert det2.update(_tele(5, var=100.0)) is None  # needs 3 again


# ---------------------------------------------------------------------------
# snapshot ring + intervention regulator
# ---------------------------------------------------------------------------

def test_state_ring_capacity_and_isolation():
    ring = StateRing(capacity=2)
    tr = Trainer(_tc(steps=2))
    for s in (0, 5, 10):
        tr.step = s
        ring.push(s, s * 100, tr.state, tr.controller_state(), tr._last)
    assert ring.steps == [5, 10]  # capacity 2, oldest evicted
    snap = ring.newest()
    restored = ring.materialize(snap)
    # materialize hands back fresh arrays each time — a restore that donates
    # its buffers to the train step must not poison the ring entry
    again = ring.materialize(snap)
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(again)):
        assert a is not b
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ring.drop_newest()
    assert ring.steps == [5]


def test_recovery_regulator_plan_and_state_roundtrip():
    cfg = RecoveryConfig(lr_backoff=0.5, lr_floor=0.1, skip_window_steps=4)
    reg = RecoveryRegulator((8, 16, 32, 64), cfg)
    plan = StepPlan(seq_len=64, batch_size=8, lr=1e-3, grad_clip_scale=1.0)
    out = reg.plan(_tele(0), dataclasses.replace(plan))
    assert out.lr == 1e-3 and out.seq_len == 64  # identity before rollback

    reg.deepen_lr()
    out = reg.plan(_tele(0), dataclasses.replace(plan))
    assert out.lr == pytest.approx(5e-4)
    assert out.grad_clip_scale == pytest.approx(0.5)
    for _ in range(10):
        reg.deepen_lr()
    assert reg.lr_scale == pytest.approx(0.1)  # floor holds

    reg2 = RecoveryRegulator((8, 16, 32, 64), cfg)
    reg2.clamp_seq()
    out = reg2.plan(_tele(0), dataclasses.replace(plan))
    assert out.seq_len == 32  # one rung down from 64
    reg2.clamp_seq()
    assert reg2.plan(_tele(0),
                     dataclasses.replace(plan)).seq_len == 16
    # a plan already below the clamp is untouched
    low = dataclasses.replace(plan, seq_len=8)
    assert reg2.plan(_tele(0), low).seq_len == 8

    reg2.skip_data()
    d = reg2.state_dict()
    reg3 = RecoveryRegulator((8, 16, 32, 64), cfg)
    reg3.load_state_dict(d)
    assert reg3.seq_drop == 2 and reg3.data_offset == 4
    assert reg3.lr_scale == reg2.lr_scale


def test_recovery_regulator_checkpoints_through_controller_state(tmp_path):
    d = str(tmp_path / "ck")
    tr = Trainer(_tc(steps=10, ckpt_dir=d, interval=5),
                 recovery=RecoveryConfig())
    reg = tr.stack["recovery"]
    reg.deepen_lr()
    reg.clamp_seq()
    reg.skip_data()
    tr.step = 5
    tr.save_checkpoint()
    tr2 = Trainer(_tc(steps=10, ckpt_dir=d, interval=5),
                  recovery=RecoveryConfig())
    assert tr2.resume() == 5
    reg2 = tr2.stack["recovery"]
    assert reg2.lr_scale == reg.lr_scale
    assert reg2.seq_drop == 1 and reg2.data_offset == reg.data_offset


# ---------------------------------------------------------------------------
# end-to-end rollback under injected faults
# ---------------------------------------------------------------------------

def test_nan_fault_recovers_and_completes():
    inj = FaultInjector(parse_faults("nan_grad@8"), seed=0)
    res = train(_tc(steps=20), quiet=True, recovery=RecoveryConfig(),
                fault_injector=inj)
    assert res.steps == 20 and not res.diverged
    assert res.rollbacks == 1
    assert res.faults_fired == ["nan_grad@8"]
    assert any(e.startswith("nan_loss@8") or e.startswith("nan_grad@8")
               for e in res.recovery_events)
    assert any(e.startswith("restored@") for e in res.recovery_events)
    assert math.isfinite(res.loss_history[-1])


@pytest.mark.slow
def test_spike_rollback_resumes_schedules_exactly():
    """With a no-op intervention (lr_backoff=1), the replayed steps after a
    rollback are bitwise identical to the clean run: the snapshot re-seats
    params + ControllerState + tracker exactly."""
    clean = train(_tc(steps=20), quiet=True)
    inj = FaultInjector(parse_faults("spike@10:64.0"), seed=0)
    cfg = RecoveryConfig(lr_backoff=1.0, lr_floor=1.0)
    res = train(_tc(steps=20), quiet=True, recovery=cfg, fault_injector=inj)
    assert res.steps == 20 and not res.diverged and res.rollbacks == 1
    assert "restored@10" in res.recovery_events
    # histories: 10 clean + 1 spiked + 10 replayed = 21 entries; the replay
    # tail must equal the clean run's steps 10..19 exactly
    assert len(res.seqlen_history) == 21
    assert res.seqlen_history[-10:] == clean.seqlen_history[10:]
    assert res.batch_history[-10:] == clean.batch_history[10:]
    assert res.lr_history[-10:] == clean.lr_history[10:]
    np.testing.assert_array_equal(np.asarray(res.loss_history[-10:]),
                                  np.asarray(clean.loss_history[10:]))


def test_persistent_divergence_exhausts_budget_and_stops():
    res = train(_tc(steps=20, lr=2000.0), quiet=True,
                recovery=RecoveryConfig(policy=RetryPolicy(max_retries=2)))
    assert res.diverged
    assert res.rollbacks == 2  # the budget, not one extra
    assert any(e.startswith("gave_up@") for e in res.recovery_events)


def test_escalate_raise_pairs_with_supervisor(tmp_path):
    """In-process exhaustion hands off to the process-level supervisor via
    DivergenceError; the two layers share one RetryPolicy shape."""
    d = str(tmp_path / "ck")
    pol = RetryPolicy(max_retries=1)
    sup = TrainSupervisor(policy=pol)

    def run(resume):
        train(_tc(steps=20, lr=2000.0, ckpt_dir=d, interval=5),
              resume=resume, quiet=True,
              recovery=RecoveryConfig(policy=pol, escalate="raise"))
        return "ok"

    with pytest.raises(DivergenceError):
        sup.run(run)
    assert sup.restarts == 2  # initial + 1 retry, then re-raise
    assert [f["attempt"] for f in sup.failures] == [1, 2]
    assert all("DivergenceError" in f["error"] for f in sup.failures)


@pytest.mark.slow
def test_escalation_ladder_engages_in_order():
    """Repeated rollbacks walk the ladder: LR backoff first, then the
    seq-len clamp, then the data-window skip."""
    inj = FaultInjector(
        parse_faults("nan_grad@6,nan_grad@9,nan_grad@12"), seed=0)
    tr = Trainer(_tc(steps=20),
                 recovery=RecoveryConfig(policy=RetryPolicy(max_retries=5)),
                 fault_injector=inj)
    res = tr.run()
    assert res.steps == 20 and not res.diverged
    assert res.rollbacks == 3
    assert len(res.faults_fired) == 3
    # three rollbacks walk the whole ladder: LR backoff every time (0.5^3),
    # seq clamp at rollbacks 2 and 3, the data skip at rollback 3
    reg = tr.stack["recovery"]
    assert reg.lr_scale == pytest.approx(0.125)
    assert reg.seq_drop == 2
    assert reg.data_offset == RecoveryConfig().skip_window_steps


# ---------------------------------------------------------------------------
# ring persistence across a drain (preemption survival)
# ---------------------------------------------------------------------------

def test_state_ring_survives_drain_and_resume(tmp_path):
    """A drained run spills the in-run rollback ring to disk next to the
    checkpoint; --recover resume refills it with the same restore points
    (steps, state arrays, telemetry) it had when the preemption landed."""
    import os

    d = str(tmp_path / "ck")
    tc = _tc(steps=30, ckpt_dir=d, interval=0)

    class StopAt:
        def on_run_start(self, tr):
            pass

        def on_step_start(self, tr):
            if tr.step >= 9:
                tr.request_drain()

        def on_step_end(self, tr, tele, plan, metrics):
            pass

        def on_run_end(self, tr):
            pass

        def close(self):
            pass

    tr = Trainer(tc, recovery=RecoveryConfig(snapshot_interval=3),
                 hooks=[StopAt()])
    res = tr.run()
    assert res.drained
    ring_dir = os.path.join(d, "ring")
    assert sorted(os.listdir(ring_dir)) == [
        f"step_{s:012d}" for s in tr.recovery.ring.steps]

    tr2 = Trainer(tc, recovery=RecoveryConfig(snapshot_interval=3))
    assert tr2.resume() == 9
    assert tr2.recovery.ring.steps == tr.recovery.ring.steps
    a = tr.recovery.ring.newest()
    b = tr2.recovery.ring.newest()
    assert b.tokens_seen == a.tokens_seen
    assert b.telemetry.step == a.telemetry.step
    assert b.telemetry.loss == pytest.approx(a.telemetry.loss)
    for x, y in zip(jax.tree_util.tree_leaves(a.state),
                    jax.tree_util.tree_leaves(b.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and the resumed run continues to completion from those restore points
    res2 = tr2.run()
    assert res2.steps == 30 and not res2.diverged


def test_state_ring_load_skips_corrupt_entry(tmp_path):
    """Ring restore is best-effort: a corrupt spilled snapshot is skipped,
    not fatal (the real checkpoint is the durable artifact)."""
    import os

    d = str(tmp_path / "ring")
    tc = _tc(steps=4)
    tr = Trainer(tc)
    ring = StateRing(capacity=3)
    for s in (2, 4):
        ring.push(s, s * 10, tr.state, tr.controller_state(), tr._last)
    ring.save(d)
    # corrupt the newest entry's payload
    inj = FaultInjector(seed=0)
    inj.corrupt_checkpoint(d, step=4)

    from repro.launch import steps as steps_lib
    like = steps_lib.abstract_train_state(tc.model, tc.optimizer)
    ring2 = StateRing(capacity=3)
    assert ring2.load(d, like) == 1
    assert ring2.steps == [2]
