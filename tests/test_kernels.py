"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, ssd, wkv6
from repro.kernels.flash_attention.ref import (attention_reference,
                                               attention_reference_gqa)
from repro.kernels.rwkv6.ref import wkv6_fwd_reference, wkv6_sequential
from repro.kernels.ssd.ref import ssd_fwd_reference

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOLS[dtype]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,d", [
    (2, 128, 4, 2, 64),
    (1, 256, 8, 8, 32),   # MHA
    (2, 192, 6, 2, 16),   # uneven blocks (padding path)
    (1, 64, 4, 1, 128),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, s, h, kv, d, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = jnp.repeat(k, g, 2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = jnp.repeat(v, g, 2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    ref = attention_reference(qf.astype(jnp.float32), kf.astype(jnp.float32),
                              vf.astype(jnp.float32), causal=causal)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("b,s,h,kv,d,causal", [
    (2, 128, 4, 2, 32, True),    # causal + GQA
    (1, 160, 4, 1, 16, True),    # padded tail (160 % 64 != 0) + MQA
    (2, 96, 6, 2, 16, False),    # non-causal + padding + GQA
    (1, 128, 4, 4, 32, True),    # MHA
])
def test_flash_attention_grads_match_reference(b, s, h, kv, d, causal):
    """dq/dk/dv of the custom_vjp path vs jax.grad of the dense oracle."""
    ks = jax.random.split(jax.random.PRNGKey(7 * s + h), 4)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    w = jax.random.normal(ks[3], (b, s, h, d))  # non-trivial cotangent

    def loss_flash(q, k, v):
        out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
        return jnp.sum(out * w)

    def loss_ref(q, k, v):
        return jnp.sum(attention_reference_gqa(q, k, v, causal=causal) * w)

    grads = jax.grad(loss_flash, (0, 1, 2))(q, k, v)
    grads_ref = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for name, g, gr in zip(("dq", "dk", "dv"), grads, grads_ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-4,
                                   rtol=1e-4, err_msg=name)


def test_flash_attention_grads_mixed_blocks():
    """block_q != block_k exercises the clamped causal index maps on both
    bwd kernels."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, kv, d = 1, 128, 2, 1, 16
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))

    def loss(fn):
        return lambda q, k, v: jnp.sum(jnp.tanh(fn(q, k, v)))

    fa = lambda q, k, v: flash_attention(q, k, v, causal=True, block_q=32,
                                         block_k=64, interpret=True)
    ref = lambda q, k, v: attention_reference_gqa(q, k, v, causal=True)
    g = jax.grad(loss(fa), (0, 1, 2))(q, k, v)
    gr = jax.grad(loss(ref), (0, 1, 2))(q, k, v)
    for a, b_ in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4,
                                   rtol=1e-4)


def test_flash_attention_lcm_padding():
    """s=96 with block_q=64, block_k=128 clamps to bk=96, which is not a
    multiple of bq — the padded length must round up to lcm(bq, bk)
    (this shape used to trip the kernel's divisibility assert)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 96, 2, 16))
    k = jax.random.normal(ks[1], (1, 96, 1, 16))
    v = jax.random.normal(ks[2], (1, 96, 1, 16))
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=128,
                          interpret=True)
    ref = attention_reference_gqa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


def test_train_step_flash_backend_matches_blockwise():
    """A real train step (jax.value_and_grad through the transformer) with
    attn_backend="flash_interpret" runs the Pallas fwd+bwd kernels and
    matches the blockwise backend's loss per step."""
    from repro.configs import get_arch, reduced
    from repro.configs.base import OptimizerConfig
    from repro.launch import steps as steps_lib
    from repro.models import model_zoo

    base = reduced(get_arch("gpt2-117m").model).replace(
        vocab_size=256, n_layers=1, max_seq_len=64)
    batch = model_zoo.make_train_batch(jax.random.PRNGKey(0), base, 2, 64)
    losses = {}
    for backend in ("blockwise", "flash_interpret"):
        cfg = base.replace(attn_backend=backend)
        model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
        state = steps_lib.init_train_state(jax.random.PRNGKey(1), cfg)
        step = jax.jit(steps_lib.make_train_step(model, OptimizerConfig()))
        per_step = []
        for _ in range(2):
            state, out = step(state, batch, jnp.float32(1e-3))
            per_step.append(float(out["loss"]))
        losses[backend] = per_step
        assert all(np.isfinite(l) for l in per_step), (backend, per_step)
    np.testing.assert_allclose(losses["flash_interpret"],
                               losses["blockwise"], atol=1e-3, rtol=1e-3)


def test_train_loop_flash_backend_no_nans():
    """A reduced GPT-2 `train()` run with the flash backend (interpret mode
    on this CPU container) completes without NaNs and its per-step losses
    match the blockwise backend to <=1e-3."""
    from repro.configs import get_arch, reduced
    from repro.configs.base import OptimizerConfig, SLWConfig, TrainConfig
    from repro.launch.train import train

    def tc(backend):
        cfg = reduced(get_arch("gpt2-117m").model).replace(
            vocab_size=256, n_layers=1, max_seq_len=64, attn_backend=backend)
        return TrainConfig(
            model=cfg,
            optimizer=OptimizerConfig(lr=1e-3, schedule="constant",
                                      total_steps=4, total_tokens=4 * 2 * 32),
            slw=SLWConfig(enabled=False),
            seq_len=32, global_batch=2, remat="none", eval_interval=0)

    res_flash = train(tc("flash_interpret"), quiet=True)
    res_block = train(tc("blockwise"), quiet=True)
    assert res_flash.steps == 4 and not res_flash.diverged
    assert all(np.isfinite(l) for l in res_flash.loss_history)
    np.testing.assert_allclose(res_flash.loss_history, res_block.loss_history,
                               atol=1e-3, rtol=1e-3)


def test_flash_backend_falls_back_off_tpu():
    """attn_backend="flash" must lower/compute on CPU (blockwise fallback),
    so full-scale presets stay dry-runnable on any backend."""
    from repro.configs import get_arch, reduced
    from repro.models import model_zoo

    cfg = reduced(get_arch("gpt2-117m").model).replace(
        vocab_size=256, n_layers=1, attn_backend="flash")
    model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
    params = model_zoo.init_params(jax.random.PRNGKey(0), cfg)
    batch = model_zoo.make_train_batch(jax.random.PRNGKey(2), cfg, 2, 32)
    loss, _ = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# shared backend/interpret resolution (kernels/__init__.py)
# ---------------------------------------------------------------------------

def test_resolve_interpret_defaults():
    """One shared rule for all three kernels: explicit flags pass through,
    None means compiled on TPU / interpret everywhere else."""
    from repro.kernels import on_tpu, resolve_interpret
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    assert resolve_interpret(None) == (not on_tpu())
    assert on_tpu() == (jax.default_backend() == "tpu")
    if jax.default_backend() != "tpu":  # this container: CPU
        assert resolve_interpret(None) is True


def test_resolve_backend_and_chunk_padding():
    from repro.kernels import chunk_padding, on_tpu, resolve_backend
    assert resolve_backend("reference", "ssm_backend") == (False, False)
    assert resolve_backend("kernel_interpret", "ssm_backend") == (True, True)
    use_kernel, interp = resolve_backend("kernel", "ssm_backend")
    assert use_kernel == on_tpu() and interp is False
    with pytest.raises(ValueError, match="rwkv_backend"):
        resolve_backend("flash", "rwkv_backend")
    assert chunk_padding(128, 32) == (32, 0)
    assert chunk_padding(100, 32) == (32, 28)   # uneven tail
    assert chunk_padding(48, 64) == (48, 0)     # chunk clamped to s


def test_unknown_mix_backends_raise():
    from repro.configs import get_arch, reduced
    from repro.models.mamba2 import ssd_mix
    from repro.models.rwkv6 import wkv6_mix
    z = jnp.zeros((1, 16, 2, 4))
    cfg = reduced(get_arch("zamba2-2.7b").model).replace(ssm_backend="nope")
    with pytest.raises(ValueError, match="ssm_backend"):
        ssd_mix(z, jnp.zeros((1, 16, 2)), jnp.zeros((2,)),
                jnp.zeros((1, 16, 4)), jnp.zeros((1, 16, 4)), cfg)
    cfg = reduced(get_arch("rwkv6-7b").model).replace(rwkv_backend="nope")
    with pytest.raises(ValueError, match="rwkv_backend"):
        wkv6_mix(z, z, z, z, jnp.zeros((2, 4)), cfg)


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,p,n,chunk", [
    (2, 3, 128, 16, 8, 32),
    (1, 2, 256, 32, 16, 64),
    (1, 1, 64, 64, 64, 64),  # single chunk
    (1, 2, 100, 16, 8, 32),  # uneven tail (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, h, s, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + p), 5)
    x = jax.random.normal(ks[0], (b, h, s, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bi = jax.random.normal(ks[3], (b, s, n)).astype(dtype)
    ci = jax.random.normal(ks[4], (b, s, n)).astype(dtype)
    y, st = ssd(x, dt, a, bi, ci, chunk=chunk, interpret=True)
    yr, sr = ssd_fwd_reference(x.astype(jnp.float32), dt, a,
                               bi.astype(jnp.float32),
                               ci.astype(jnp.float32), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=5 * _tol(dtype), rtol=5 * _tol(dtype))
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               atol=5 * _tol(dtype), rtol=5 * _tol(dtype))


# ---------------------------------------------------------------------------
# RWKV6 / WKV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,d,chunk", [
    (2, 3, 96, 16, 32),
    (1, 2, 128, 32, 16),
    (1, 1, 32, 64, 32),
    (1, 2, 50, 16, 16),  # uneven tail (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(b, h, s, d, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 5)
    r = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, h, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, h, s, d)).astype(dtype)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, d)) * 0.5)
    lw = lw.astype(jnp.float32)
    u = (jax.random.normal(ks[4], (h, d)) * 0.5).astype(jnp.float32)
    y, st = wkv6(r, k, v, lw, u, chunk=chunk, interpret=True)
    yr, sr = wkv6_sequential(r.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), lw, u)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=10 * _tol(dtype), rtol=10 * _tol(dtype))
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               atol=10 * _tol(dtype), rtol=10 * _tol(dtype))


def test_wkv6_chunked_matches_chunked_ref():
    """Kernel vs the model's own chunked formulation (not just sequential)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, h, s, d = 1, 2, 64, 16
    r, k, v = (jax.random.normal(ks[i], (b, h, s, d)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, d)) * 0.5)
    u = jax.random.normal(ks[4], (h, d)) * 0.5
    y, st = wkv6(r, k, v, lw, u, chunk=16, interpret=True)
    yr, sr = wkv6_fwd_reference(r, k, v, lw, u, chunk=32)  # different chunking
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# SSD / WKV6 gradients (custom_vjp through the Pallas reverse-scan kernels)
# ---------------------------------------------------------------------------

# per-dtype grad tolerances: f32 per the acceptance bar; bf16 inputs round
# the f32-accumulated cotangents back to 8-bit mantissas on output
GRAD_TOLS = {jnp.float32: 1e-4, jnp.bfloat16: 4e-2}

# (s, chunk): single-chunk, many-chunk, uneven tail, chunk clamped to s
SEQ_CHUNK_CASES = [(64, 64), (128, 32), (100, 32), (48, 64)]


@pytest.mark.parametrize("s,chunk", SEQ_CHUNK_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_grads_match_reference(s, chunk, dtype):
    """jax.grad of a scalar loss (with y *and* final-state cotangents)
    through the ssd custom_vjp vs. grad through the jnp chunked oracle."""
    b, h, p, n = 2, 2, 8, 4
    tol = GRAD_TOLS[dtype]
    ks = jax.random.split(jax.random.PRNGKey(3 * s + chunk), 7)
    x = jax.random.normal(ks[0], (b, h, s, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bi = jax.random.normal(ks[3], (b, s, n)).astype(dtype)
    ci = jax.random.normal(ks[4], (b, s, n)).astype(dtype)
    w = jax.random.normal(ks[5], (b, h, s, p))
    ws = jax.random.normal(ks[6], (b, h, n, p))

    def loss(fn):
        def _l(x, dt, a, bi, ci):
            y, st = fn(x, dt, a, bi, ci)
            return jnp.sum(y.astype(jnp.float32) * w) + jnp.sum(st * ws)
        return _l

    kern = lambda *t: ssd(*t, chunk=chunk, interpret=True)
    ref = lambda *t: ssd_fwd_reference(*t, chunk=chunk)
    gk = jax.grad(loss(kern), (0, 1, 2, 3, 4))(x, dt, a, bi, ci)
    gr = jax.grad(loss(ref), (0, 1, 2, 3, 4))(x, dt, a, bi, ci)
    for name, g, r in zip(("dx", "ddt", "da", "db", "dc"), gk, gr):
        assert g.dtype == r.dtype, name
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


@pytest.mark.parametrize("s,chunk", SEQ_CHUNK_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_grads_match_reference(s, chunk, dtype):
    """jax.grad through the wkv6 custom_vjp (dr/dk/dv/d_log_w/du) vs. grad
    through the jnp chunked oracle, same loss shape as the ssd test."""
    b, h, d = 2, 2, 8
    tol = GRAD_TOLS[dtype]
    ks = jax.random.split(jax.random.PRNGKey(5 * s + chunk), 7)
    r = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, h, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, h, s, d)).astype(dtype)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, d)) * 0.5)
    u = jax.random.normal(ks[4], (h, d)) * 0.5
    w = jax.random.normal(ks[5], (b, h, s, d))
    ws = jax.random.normal(ks[6], (b, h, d, d))

    def loss(fn):
        def _l(r, k, v, lw, u):
            y, st = fn(r, k, v, lw, u)
            return jnp.sum(y.astype(jnp.float32) * w) + jnp.sum(st * ws)
        return _l

    kern = lambda *t: wkv6(*t, chunk=chunk, interpret=True)
    ref = lambda *t: wkv6_fwd_reference(*t, chunk=chunk)
    gk = jax.grad(loss(kern), (0, 1, 2, 3, 4))(r, k, v, lw, u)
    gr = jax.grad(loss(ref), (0, 1, 2, 3, 4))(r, k, v, lw, u)
    for name, g, r_ in zip(("dr", "dk", "dv", "dlw", "du"), gk, gr):
        assert g.dtype == r_.dtype, name
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(r_, np.float32),
                                   atol=tol, rtol=tol, err_msg=name)


def test_wkv6_grads_match_sequential():
    """Independent oracle: grads through the step-by-step lax.scan
    recurrence (not the chunked formulation the kernel mirrors)."""
    b, h, s, d, chunk = 1, 2, 48, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(11), 6)
    r, k, v = (jax.random.normal(ks[i], (b, h, s, d)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, d)) * 0.5)
    u = jax.random.normal(ks[4], (h, d)) * 0.5
    w = jax.random.normal(ks[5], (b, h, s, d))

    def loss(fn):
        return lambda *t: jnp.sum(fn(*t)[0] * w)

    kern = lambda *t: wkv6(*t, chunk=chunk, interpret=True)
    gk = jax.grad(loss(kern), (0, 1, 2, 3, 4))(r, k, v, lw, u)
    gr = jax.grad(loss(wkv6_sequential), (0, 1, 2, 3, 4))(r, k, v, lw, u)
    for name, g, r_ in zip(("dr", "dk", "dv", "dlw", "du"), gk, gr):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r_), atol=1e-4,
                                   rtol=1e-4, err_msg=name)


# ---------------------------------------------------------------------------
# backend parity: ssm_backend / rwkv_backend through real model train steps
# ---------------------------------------------------------------------------

def _train_step_outputs(cfg, batch, steps=2):
    from repro.configs.base import OptimizerConfig
    from repro.launch import steps as steps_lib
    from repro.models import model_zoo
    model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
    state = steps_lib.init_train_state(jax.random.PRNGKey(1), cfg)
    step = jax.jit(steps_lib.make_train_step(model, OptimizerConfig()))
    out_hist = []
    for _ in range(steps):
        state, out = step(state, batch, jnp.float32(1e-3))
        out_hist.append((float(out["loss"]), float(out["grad_norm"])))
    return out_hist


def _backend_parity_case(arch, field, seq_len=48):
    """seq_len=48 is deliberately not a multiple of the reduced chunk
    sizes (32/16), so the kernel's uneven-tail padding runs in-model."""
    from repro.configs import get_arch, reduced
    from repro.models import model_zoo
    base = reduced(get_arch(arch).model).replace(
        vocab_size=256, max_seq_len=64, n_layers=2,
        **({"attn_every": 2} if arch == "zamba2-2.7b" else {}))
    batch = model_zoo.make_train_batch(jax.random.PRNGKey(0), base, 2,
                                       seq_len)
    outs = {}
    for backend in ("reference", "kernel_interpret"):
        cfg = base.replace(**{field: backend})
        outs[backend] = _train_step_outputs(cfg, batch)
        assert all(np.isfinite(x) for pair in outs[backend] for x in pair)
    np.testing.assert_allclose(outs["kernel_interpret"], outs["reference"],
                               atol=1e-4, rtol=1e-4)


def test_train_step_rwkv_kernel_backend_matches_reference():
    """RWKV6 train steps (loss + grad-norm) through the Pallas WKV fwd+bwd
    kernels match the reference backend."""
    _backend_parity_case("rwkv6-7b", "rwkv_backend")


def test_train_step_ssm_kernel_backend_matches_reference():
    """Zamba2 (Mamba-2 backbone) train steps through the Pallas SSD fwd+bwd
    kernels match the reference backend."""
    _backend_parity_case("zamba2-2.7b", "ssm_backend")


def test_mamba2_block_kernel_backend_grads_match_reference():
    """Block-level Mamba-2 parity: value and parameter gradients of a full
    mamba2_block agree between the reference scan and the kernel backend."""
    from repro.configs import get_arch, reduced
    from repro.models import layers as L
    from repro.models.mamba2 import mamba2_block, mamba2_def
    cfg = reduced(get_arch("zamba2-2.7b").model)
    lp = L.init_params(jax.random.PRNGKey(0), mamba2_def(cfg))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 40, cfg.d_model))

    def make_loss(c):
        w = jnp.cos(jnp.arange(x.size, dtype=jnp.float32)).reshape(x.shape)
        return lambda lp: jnp.sum(mamba2_block(lp, x, c) * w)

    vals, grads = {}, {}
    for backend in ("reference", "kernel_interpret"):
        c = cfg.replace(ssm_backend=backend)
        vals[backend], grads[backend] = jax.value_and_grad(make_loss(c))(lp)
    np.testing.assert_allclose(float(vals["kernel_interpret"]),
                               float(vals["reference"]), atol=1e-4, rtol=1e-4)
    flat_k = jax.tree_util.tree_leaves(grads["kernel_interpret"])
    flat_r = jax.tree_util.tree_leaves(grads["reference"])
    for g, r in zip(flat_k, flat_r):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=1e-4,
                                   rtol=1e-3)


def test_kernel_backends_fall_back_off_tpu():
    """ssm_backend/rwkv_backend="kernel" (the full-scale preset setting)
    must lower and compute on CPU via the reference fallback."""
    from repro.configs import get_arch, reduced
    from repro.models import model_zoo
    for arch in ("rwkv6-7b", "zamba2-2.7b"):
        cfg = reduced(get_arch(arch).model).replace(vocab_size=256,
                                                    n_layers=2, **(
            {"attn_every": 2} if arch == "zamba2-2.7b" else {}))
        assert "kernel" in (cfg.rwkv_backend, cfg.ssm_backend)  # inherited
        model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
        params = model_zoo.init_params(jax.random.PRNGKey(0), cfg)
        batch = model_zoo.make_train_batch(jax.random.PRNGKey(2), cfg, 2, 32)
        loss, _ = jax.jit(model.loss)(params, batch)
        assert np.isfinite(float(loss)), arch


def test_model_attention_blockwise_matches_flash_ref():
    """The model's blockwise-scan attention is itself validated against the
    kernel oracle (they must agree — it is the XLA fallback path)."""
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    b, s, h, kv, d = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = blockwise_attention(q, k, v, causal=True, block_kv=32)
    ref = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)
