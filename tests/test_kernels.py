"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, ssd, wkv6
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.rwkv6.ref import wkv6_fwd_reference, wkv6_sequential
from repro.kernels.ssd.ref import ssd_fwd_reference

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOLS[dtype]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,d", [
    (2, 128, 4, 2, 64),
    (1, 256, 8, 8, 32),   # MHA
    (2, 192, 6, 2, 16),   # uneven blocks (padding path)
    (1, 64, 4, 1, 128),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, s, h, kv, d, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    g = h // kv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = jnp.repeat(k, g, 2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = jnp.repeat(v, g, 2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    ref = attention_reference(qf.astype(jnp.float32), kf.astype(jnp.float32),
                              vf.astype(jnp.float32), causal=causal)
    ref = ref.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,p,n,chunk", [
    (2, 3, 128, 16, 8, 32),
    (1, 2, 256, 32, 16, 64),
    (1, 1, 64, 64, 64, 64),  # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, h, s, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + p), 5)
    x = jax.random.normal(ks[0], (b, h, s, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bi = jax.random.normal(ks[3], (b, s, n)).astype(dtype)
    ci = jax.random.normal(ks[4], (b, s, n)).astype(dtype)
    y, st = ssd(x, dt, a, bi, ci, chunk=chunk, interpret=True)
    yr, sr = ssd_fwd_reference(x.astype(jnp.float32), dt, a,
                               bi.astype(jnp.float32),
                               ci.astype(jnp.float32), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=5 * _tol(dtype), rtol=5 * _tol(dtype))
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               atol=5 * _tol(dtype), rtol=5 * _tol(dtype))


# ---------------------------------------------------------------------------
# RWKV6 / WKV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,d,chunk", [
    (2, 3, 96, 16, 32),
    (1, 2, 128, 32, 16),
    (1, 1, 32, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(b, h, s, d, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 5)
    r = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, h, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, h, s, d)).astype(dtype)
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, d)) * 0.5)
    lw = lw.astype(jnp.float32)
    u = (jax.random.normal(ks[4], (h, d)) * 0.5).astype(jnp.float32)
    y, st = wkv6(r, k, v, lw, u, chunk=chunk, interpret=True)
    yr, sr = wkv6_sequential(r.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), lw, u)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=10 * _tol(dtype), rtol=10 * _tol(dtype))
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               atol=10 * _tol(dtype), rtol=10 * _tol(dtype))


def test_wkv6_chunked_matches_chunked_ref():
    """Kernel vs the model's own chunked formulation (not just sequential)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, h, s, d = 1, 2, 64, 16
    r, k, v = (jax.random.normal(ks[i], (b, h, s, d)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, h, s, d)) * 0.5)
    u = jax.random.normal(ks[4], (h, d)) * 0.5
    y, st = wkv6(r, k, v, lw, u, chunk=16, interpret=True)
    yr, sr = wkv6_fwd_reference(r, k, v, lw, u, chunk=32)  # different chunking
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=1e-4,
                               rtol=1e-4)


def test_model_attention_blockwise_matches_flash_ref():
    """The model's blockwise-scan attention is itself validated against the
    kernel oracle (they must agree — it is the XLA fallback path)."""
    from repro.models.attention import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    b, s, h, kv, d = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = blockwise_attention(q, k, v, causal=True, block_kv=32)
    ref = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)
