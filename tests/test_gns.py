"""The gradient-noise-scale subsystem (repro.gns): estimator math against
the analytic noise scale, the direction-sketch precursor, the measured
critical-batch regulator, recovery's per-leaf/precursor surfaces, and the
end-to-end trainer wiring (including the gns-off bitwise default path and
the --metrics-jsonl per-leaf round-trip)."""
import dataclasses
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.configs.base import (GNSConfig, OptimizerConfig, RegulatorSpec,
                                SLWConfig, TrainConfig)
from repro.core.recovery import RecoveryConfig, RecoveryRegulator
from repro.core.regulators import (ControllerState, StepPlan, StepTelemetry,
                                   build_stack)
from repro.core.telemetry import read_metrics_jsonl
from repro.data import DataPipeline, SyntheticCorpus
from repro.distributed.fault_injection import FaultInjector
from repro.distributed.fault_tolerance import RetryPolicy
from repro.gns import GNSEstimator, gns_estimates
from repro.gns.precursor import GradientPrecursor
from repro.gns.regulator import CriticalBatchRegulator
from repro.launch import steps as steps_lib
from repro.launch.train import MetricsJsonlHook, train
from repro.models import model_zoo


# ---------------------------------------------------------------------------
# estimator math
# ---------------------------------------------------------------------------

def test_gns_estimates_invert_expectations_exactly():
    # feed the *expected* values of the biased norm pair — the unbiased
    # formulas must return the underlying (|G|^2, tr(Sigma)) exactly
    g_sq_true, tr_true, b, B = 2.0, 48.0, 4, 32
    small_sq = g_sq_true + tr_true / b
    big_sq = g_sq_true + tr_true / B
    g_sq, tr = gns_estimates(small_sq, big_sq, b, B)
    assert g_sq == pytest.approx(g_sq_true)
    assert tr == pytest.approx(tr_true)
    # elementwise on vectors too
    g_sq, tr = gns_estimates(np.array([small_sq, small_sq]),
                             np.array([big_sq, big_sq]), b, B)
    assert np.allclose(g_sq, g_sq_true) and np.allclose(tr, tr_true)


def test_estimator_matches_analytic_noise_scale():
    """Acceptance criterion: on a synthetic problem with known gradient
    mean/covariance (g = mu + sigma*eps, B_noise = n*sigma^2/|mu|^2) the
    EMA estimate lands within tolerance of the analytic value."""
    rng = np.random.RandomState(0)
    n, sigma, big, k = 128, 0.5, 64, 8
    mu = rng.randn(n)
    mu /= np.linalg.norm(mu)              # |G|^2 = 1
    true_b_noise = n * sigma ** 2
    est = GNSEstimator(ema_window=64, warmup_obs=8)
    for _ in range(300):
        samples = mu + sigma * rng.randn(big, n)
        shard_means = samples.reshape(k, big // k, n).mean(axis=1)
        est.update(float(np.mean(np.sum(shard_means ** 2, axis=1))),
                   float(np.sum(samples.mean(axis=0) ** 2)),
                   big // k, big)
    assert est.ready
    assert abs(est.b_noise - true_b_noise) / true_b_noise < 0.15
    # the efficiency curve rides the estimate: monotone in B, -> 1
    effs = [est.efficiency(b) for b in (1, 8, 64, 512, 1e6)]
    assert all(a < b for a, b in zip(effs, effs[1:]))
    assert effs[-1] == pytest.approx(1.0, abs=1e-3)
    assert est.critical_batch() == pytest.approx(est.b_noise)


def test_estimator_per_leaf_vectors_recompose_global_ratio():
    est = GNSEstimator(ema_window=8, warmup_obs=2)
    # two leaves with expected pairs for (g_sq, tr) = (1, 10) and (3, 2)
    b, B = 2, 16
    small = np.array([1 + 10 / b, 3 + 2 / b])
    big = np.array([1 + 10 / B, 3 + 2 / B])
    for _ in range(4):
        est.update(small, big, b, B)
    leaf = est.leaf_b_noise
    assert leaf is not None and leaf.shape == (2,)
    assert np.allclose(leaf, [10.0, 2.0 / 3.0])
    assert est.b_noise == pytest.approx((10 + 2) / (1 + 3))


def test_estimator_state_roundtrip_resumes_ema_exactly():
    rng = np.random.RandomState(1)
    a = GNSEstimator(ema_window=16, warmup_obs=4)
    for _ in range(10):
        s = float(rng.rand() + 1.0)
        a.update(s, s * 0.5, 4, 32)
    b = GNSEstimator(ema_window=16, warmup_obs=4)
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    assert b.ready == a.ready and b.b_noise == pytest.approx(a.b_noise)
    for _ in range(5):  # continued updates stay in lockstep
        s = float(rng.rand() + 1.0)
        a.update(s, s * 0.5, 4, 32)
        b.update(s, s * 0.5, 4, 32)
    assert b.b_noise == pytest.approx(a.b_noise)


def test_estimator_ignores_degenerate_observations():
    est = GNSEstimator(ema_window=8, warmup_obs=1)
    est.update(1.0, 1.0, 8, 8)              # b == B: no system to solve
    est.update(float("nan"), 1.0, 4, 32)    # non-finite
    assert est.n_obs == 0 and not est.ready


# ---------------------------------------------------------------------------
# precursor (synthetic sketch streams)
# ---------------------------------------------------------------------------

def _pre_cfg(**kw):
    base = dict(enabled=True, precursor_window=6, precursor_dim=16,
                precursor_lags=2, precursor_gate=0.8, precursor_rise=0.25,
                precursor_grace=4, precursor_cooldown_steps=4)
    base.update(kw)
    return GNSConfig(**base)


_LABELS = ("blk0/attn", "blk0/mlp", "pos_embed")


def _noise_sketch(rng, n_leaves=3, d=16):
    return rng.randn(n_leaves, d)


def test_precursor_fires_on_rising_correlation_and_cools_down():
    rng = np.random.RandomState(0)
    pre = GradientPrecursor(_pre_cfg())
    for step in range(12):   # healthy: near-orthogonal directions
        assert pre.observe(step, _noise_sketch(rng), _LABELS) is None
    # leaf 1's direction freezes (the post-spike Adam state): its lagged
    # autocorrelation goes to ~1 while the others stay ambient
    frozen = rng.randn(16)
    events = []
    for step in range(12, 24):
        sk = _noise_sketch(rng)
        sk[1] = frozen + 0.05 * rng.randn(16)
        ev = pre.observe(step, sk, _LABELS)
        if ev is not None:
            events.append(ev)
    assert events, "precursor never fired on a frozen leaf direction"
    assert events[0].leaf == "blk0/mlp"
    assert events[0].score > 0.8 and events[0].score > events[0].baseline
    # refire cooldown: one sustained excursion != an event stream
    steps_between = [e.step for e in events]
    assert all(b - a > pre.cfg.precursor_cooldown_steps
               for a, b in zip(steps_between, steps_between[1:]))


def test_precursor_silent_on_noise():
    rng = np.random.RandomState(7)
    pre = GradientPrecursor(_pre_cfg())
    for step in range(60):
        assert pre.observe(step, _noise_sketch(rng), _LABELS) is None


def test_precursor_grace_absorbs_persistently_correlated_leaf():
    """A leaf that is direction-correlated from step 0 (positional
    embeddings under a fixed-format corpus) must be absorbed into the
    baseline during grace, not fired on at grace expiry."""
    rng = np.random.RandomState(3)
    pre = GradientPrecursor(_pre_cfg())
    fixed = rng.randn(16)
    for step in range(40):
        sk = _noise_sketch(rng)
        sk[2] = fixed + 0.05 * rng.randn(16)
        assert pre.observe(step, sk, _LABELS) is None, \
            f"fired on an always-correlated leaf at step {step}"
    # ...but the baseline it learned is honest: trailing[2] is high
    assert pre.trailing[2] > 0.8


def test_precursor_nan_sketch_clears_direction_history():
    rng = np.random.RandomState(5)
    pre = GradientPrecursor(_pre_cfg())
    for step in range(8):
        pre.observe(step, _noise_sketch(rng), _LABELS)
    assert len(pre.ring) > 0
    bad = _noise_sketch(rng)
    bad[0, 0] = float("nan")
    assert pre.observe(8, bad, _LABELS) is None
    assert len(pre.ring) == 0   # poisoned history dropped, then refills
    for step in range(9, 15):
        pre.observe(step, _noise_sketch(rng), _LABELS)
    assert len(pre.ring) > 0


# ---------------------------------------------------------------------------
# critical-batch regulator on synthetic telemetry
# ---------------------------------------------------------------------------

def _gns_tele(step, small, big, b=2.0, B=8.0):
    return StepTelemetry(step=step, gns_small_sq=small, gns_big_sq=big,
                         gns_b_small=b, gns_b_big=B)


def test_critical_batch_grows_under_noise_holds_when_flat():
    cfg = GNSConfig(enabled=True, min_batch=2, headroom=2.0, growth=2.0,
                    ema_window=4, warmup_obs=2)
    reg = CriticalBatchRegulator(cfg, full_batch=32, dp_size=2)
    assert reg.batch == 2
    # noise-dominated telemetry: B_noise >> batch -> monotone growth to cap
    seen = [reg.batch]
    for step in range(12):
        reg.observe(_gns_tele(step, small=100.0, big=25.5), 0)
        seen.append(reg.batch)
    assert all(b2 >= b1 for b1, b2 in zip(seen, seen[1:]))
    assert all(b % 2 == 0 for b in seen)
    assert seen[-1] == 32
    # zero-noise telemetry (S_small == S_big -> tr(Sigma)=0): batch holds
    reg2 = CriticalBatchRegulator(cfg, full_batch=32, dp_size=2)
    for step in range(12):
        reg2.observe(_gns_tele(step, small=10.0, big=10.0), 0)
    assert reg2.batch == 2


def test_critical_batch_prefers_per_leaf_vectors():
    cfg = GNSConfig(enabled=True, min_batch=2, headroom=2.0, growth=2.0,
                    ema_window=4, warmup_obs=2)
    reg = CriticalBatchRegulator(cfg, full_batch=16, dp_size=1)
    tele = dataclasses.replace(
        _gns_tele(0, small=200.0, big=51.0),
        per_leaf={"gns_small_sq": np.array([100.0, 100.0], np.float32),
                  "gns_big_sq": np.array([25.5, 25.5], np.float32)},
        leaf_labels=("a", "b"))
    for _ in range(6):
        reg.observe(tele, 0)
    assert reg.est.leaf_b_noise is not None          # fed the vectors
    assert reg.est.leaf_b_noise.shape == (2,)
    assert reg.batch > 2                             # and still grew


def test_critical_batch_state_roundtrip():
    cfg = GNSConfig(enabled=True, min_batch=2, headroom=2.0, growth=2.0,
                    ema_window=4, warmup_obs=2)
    a = CriticalBatchRegulator(cfg, full_batch=32, dp_size=2)
    for step in range(5):
        a.observe(_gns_tele(step, small=100.0, big=25.5), 0)
    b = CriticalBatchRegulator(cfg, full_batch=32, dp_size=2)
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    assert b.batch == a.batch
    assert b.est.b_noise == pytest.approx(a.est.b_noise)
    p1 = a.plan(StepTelemetry(), StepPlan(seq_len=8, batch_size=32, lr=1.0))
    p2 = b.plan(StepTelemetry(), StepPlan(seq_len=8, batch_size=32, lr=1.0))
    assert p1.batch_size == p2.batch_size


# ---------------------------------------------------------------------------
# recovery surfaces: per-leaf LR backoff + precursor cool-down
# ---------------------------------------------------------------------------

def _rr():
    return RecoveryRegulator(ladder=(8, 16, 32),
                             cfg=RecoveryConfig(lr_backoff=0.5, lr_floor=0.1))


def test_deepen_lr_blamed_leaf_before_global():
    reg = _rr()
    assert reg.leaf_lr_vector(("a", "b")) is None    # inactive -> None
    reg.deepen_lr("b")
    assert reg.lr_scale == 1.0                       # global untouched
    vec = reg.leaf_lr_vector(("a", "b"))
    assert vec is not None and vec.dtype == np.float32
    assert list(vec) == [1.0, 0.5]
    reg.deepen_lr("b")
    reg.deepen_lr("b")
    reg.deepen_lr("b")
    assert reg.leaf_lr_scales["b"] == pytest.approx(0.1)   # floor holds
    reg.deepen_lr()                                  # no blame -> global
    assert reg.lr_scale == 0.5
    plan = reg.plan(StepTelemetry(), StepPlan(seq_len=32, batch_size=8,
                                              lr=1.0))
    assert plan.lr == pytest.approx(0.5)


def test_precursor_cooldown_is_temporary_and_merges_most_severe():
    reg = _rr()
    reg.precursor_cooldown(0.5, 3)
    reg.precursor_cooldown(0.8, 2)   # weaker: scale keeps 0.5, ttl keeps 3
    assert reg.cool_scale == 0.5 and reg.cool_ttl == 3
    plan = reg.plan(StepTelemetry(), StepPlan(seq_len=32, batch_size=8,
                                              lr=1.0))
    assert plan.lr == pytest.approx(0.5)
    for _ in range(3):
        reg.observe(StepTelemetry(), 0)
    assert reg.cool_ttl == 0 and reg.cool_scale == 1.0
    plan = reg.plan(StepTelemetry(), StepPlan(seq_len=32, batch_size=8,
                                              lr=1.0))
    assert plan.lr == pytest.approx(1.0)             # cool-down expired


def test_recovery_state_roundtrip_including_new_keys():
    reg = _rr()
    reg.deepen_lr("blk0")
    reg.precursor_cooldown(0.25, 5)
    reg.deepen_lr()
    d = json.loads(json.dumps(reg.state_dict()))
    reg2 = _rr()
    reg2.load_state_dict(d)
    assert reg2.state_dict() == reg.state_dict()
    # pre-PR-9 checkpoints (3 legacy keys) still load, new surfaces idle
    reg3 = _rr()
    reg3.load_state_dict({"lr_scale": 0.5, "seq_drop": 1, "data_offset": 4})
    assert reg3.leaf_lr_scales == {} and reg3.cool_ttl == 0
    assert reg3.cool_scale == 1.0


# ---------------------------------------------------------------------------
# train-step wiring
# ---------------------------------------------------------------------------

_MODEL_CFG = None


def _model_cfg():
    global _MODEL_CFG
    if _MODEL_CFG is None:
        _MODEL_CFG = reduced(get_arch("gpt2-117m").model).replace(
            vocab_size=128)
    return _MODEL_CFG


def _step_fixture(gns, seq=32, batch=8):
    cfg = _model_cfg()
    opt = OptimizerConfig(lr=1e-3, schedule="constant", grad_clip=1.0)
    model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
    fn = jax.jit(steps_lib.make_train_step(model, opt, gns=gns),
                 donate_argnums=(0,))
    state = steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=seq, seed=0)
    b = DataPipeline(corpus, batch, model_cfg=cfg).batch_at(0)
    return fn, state, b


def test_gns_off_step_is_bitwise_identical_to_legacy():
    """Acceptance criterion: the default path (gns disabled) must produce
    exactly the legacy step — same metrics, same params — whether the
    config is absent or present-but-disabled."""
    outs = []
    for gns in (None, GNSConfig(enabled=False)):
        fn, state, batch = _step_fixture(gns)
        state, metrics = fn(state, batch, np.float32(1e-3), np.float32(1.0))
        outs.append((jax.device_get(state["params"]), jax.device_get(metrics)))
    (p0, m0), (p1, m1) = outs
    assert set(m0) == set(m1)
    assert not any(k.startswith("gns") for k in m0)
    for k in m0:
        np.testing.assert_array_equal(np.asarray(m0[k]), np.asarray(m1[k]))
    for a, b in zip(jax.tree_util.tree_leaves(p0),
                    jax.tree_util.tree_leaves(p1)):
        np.testing.assert_array_equal(a, b)


def test_gns_step_emits_consistent_measurement():
    fn, state, batch = _step_fixture(GNSConfig(enabled=True, shards=4,
                                               precursor_window=12))
    base_fn, base_state, _ = _step_fixture(None)
    state, m = fn(state, batch, np.float32(1e-3), np.float32(1.0))
    base_state, bm = base_fn(base_state, batch, np.float32(1e-3),
                             np.float32(1.0))
    # scalar pair present, finite, and shard-consistent (B=8, k=4 -> b=2)
    assert float(m["gns_b_big"]) == 8.0 and float(m["gns_b_small"]) == 2.0
    small, big = float(m["gns_small_sq"]), float(m["gns_big_sq"])
    assert np.isfinite(small) and np.isfinite(big)
    assert small >= big > 0.0     # shard means are noisier than the mean
    # per-leaf vectors sum to the global pair; sketch has the fixed shape
    leaf_small = np.asarray(m["leaf_gns_small_sq"])
    n_leaves = leaf_small.shape[0]
    assert float(np.sum(leaf_small)) == pytest.approx(small, rel=1e-5)
    assert np.asarray(m["leaf_gns_sketch"]).shape == (n_leaves, 16)
    # measuring must not change what is learned: the combined gradient is
    # the token-weighted shard mean, so the realized loss matches the
    # single-pass step closely
    assert float(m["loss"]) == pytest.approx(float(bm["loss"]), rel=1e-4)


def test_gns_sketch_shape_tracks_precursor_dim():
    gns = GNSConfig(enabled=True, shards=2, precursor_window=6,
                    precursor_dim=8)
    fn, state, batch = _step_fixture(gns)
    _, m = fn(state, batch, np.float32(1e-3), np.float32(1.0))
    assert np.asarray(m["leaf_gns_sketch"]).shape[1] == 8


# ---------------------------------------------------------------------------
# end-to-end: trainer wiring, jsonl round-trip, composed checkpoint/resume
# ---------------------------------------------------------------------------

def _e2e_tc(steps=16, seq=64, batch=8, gns=None, regulators=(), ckpt_dir="",
            slw=False, interval=0):
    cfg = _model_cfg()
    return TrainConfig(
        model=cfg,
        optimizer=OptimizerConfig(
            lr=1e-3, min_lr=1e-5, schedule="token_cosine", warmup_steps=4,
            warmup_tokens=4 * batch * seq, total_steps=steps,
            total_tokens=steps * batch * seq),
        slw=SLWConfig(enabled=slw, pacing="linear", start_seq_len=8,
                      duration_steps=steps // 2, round_multiple=8,
                      max_buckets=4),
        regulators=regulators,
        gns=gns or GNSConfig(),
        seq_len=seq, global_batch=batch, remat="none", eval_interval=0,
        checkpoint_interval=interval, checkpoint_dir=ckpt_dir)


def test_metrics_jsonl_per_leaf_roundtrip(tmp_path):
    """Satellite: the --metrics-jsonl stream carries the one-time
    leaf_labels header plus per-step per-leaf vectors, and
    read_metrics_jsonl (the parse-back bench_gns reuses) restores them."""
    path = str(tmp_path / "metrics.jsonl")
    gns = GNSConfig(enabled=True, shards=4, precursor_window=6)
    res = train(_e2e_tc(steps=8, gns=gns), quiet=True,
                hooks=[MetricsJsonlHook(path)])
    assert res.steps == 8
    labels, rows = read_metrics_jsonl(path)
    assert len(rows) == 8
    assert labels and all(isinstance(l, str) for l in labels)
    # the header is written exactly once
    with open(path) as f:
        raw = [json.loads(line) for line in f]
    assert sum("leaf_labels" in r for r in raw) == 1
    for r in rows:
        assert {"gns_small_sq", "gns_big_sq", "gns_b_small",
                "gns_b_big"} <= set(r)
        pl = r["per_leaf"]
        assert pl["gns_small_sq"].shape == (len(labels),)
        assert pl["gns_small_sq"].dtype == np.float32
        assert pl["gns_sketch"].shape == (len(labels), gns.precursor_dim)
    # and the streamed scalars replay into the same estimate the live
    # regulator would have formed
    est = GNSEstimator(ema_window=8, warmup_obs=2)
    for r in rows:
        est.update(r["gns_small_sq"], r["gns_big_sq"],
                   r["gns_b_small"], r["gns_b_big"])
    assert est.ready and np.isfinite(est.b_noise)


def test_metrics_jsonl_default_rows_unchanged_without_gns(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    train(_e2e_tc(steps=4), quiet=True, hooks=[MetricsJsonlHook(path)])
    labels, rows = read_metrics_jsonl(path)
    assert labels == () and len(rows) == 4
    for r in rows:
        assert not any(k.startswith("gns_") for k in r)
        assert "per_leaf" not in r


def test_critical_batch_composes_with_slw_through_resume(tmp_path):
    """Acceptance criterion: CriticalBatchRegulator + SLW + token-wise LR
    through a mid-warmup checkpoint/restore — the resumed run continues
    the batch/seq/LR trajectory instead of restarting any schedule."""
    gns = GNSConfig(enabled=True, shards=4, precursor_window=0,
                    warmup_obs=2, ema_window=8)
    regs = (RegulatorSpec(kind="seqlen"), RegulatorSpec(kind="lr"),
            RegulatorSpec(kind="critical_batch"))

    def tc(d):
        # one config for every run (schedule constants must not depend on
        # the run length — the interrupted run is cut short via max_steps,
        # not a different schedule)
        return _e2e_tc(steps=24, gns=gns, regulators=regs, slw=True,
                       ckpt_dir=str(tmp_path / d), interval=8)

    full = train(tc("full"), quiet=True)
    assert full.steps == 24 and not full.diverged
    # the measured warmup actually engaged: batch started below full and
    # is monotone non-decreasing
    assert full.batch_history[0] < 8
    assert all(b2 >= b1 for b1, b2 in
               zip(full.batch_history, full.batch_history[1:]))

    interrupted = train(tc("part"), max_steps=16, quiet=True)
    resumed = train(tc("part"), resume=True, quiet=True)
    assert resumed.restored_from_step == 16
    assert resumed.steps == 24
    # every schedule continued: the resumed trajectory matches the
    # uninterrupted run step for step (batch from the restored estimator
    # EMAs, seqlen from SLW, lr from the token-wise schedule)
    tail = slice(16, 24)
    assert resumed.batch_history == full.batch_history[tail]
    assert resumed.seqlen_history == full.seqlen_history[tail]
    assert np.allclose(resumed.lr_history, full.lr_history[tail])
    assert interrupted.batch_history == full.batch_history[:16]


def test_gns_off_trainer_has_no_gns_surface(tmp_path):
    res = train(_e2e_tc(steps=4), quiet=True)
    assert res.precursor_events == []


@pytest.mark.slow
def test_precursor_leads_detector_on_injected_fault_matrix():
    """The bench scenario as a regression test: a sub-threshold episode at
    12 then an overt spike at 22 — the precursor must fire from measured
    gradient directions strictly before the detector, and a clean arm
    stays silent."""
    from benchmarks.common import bench_config
    steps = 32

    def tc():
        return dataclasses.replace(
            bench_config(slw=False, steps=steps, lr=1e-3),
            gns=GNSConfig(enabled=True, shards=4))

    rec = RecoveryConfig(policy=RetryPolicy(max_retries=3))
    res = train(tc(), quiet=True, recovery=rec,
                fault_injector=FaultInjector.from_cli(
                    "spike@12:2.0,spike@22:32.0", seed=0))
    assert res.steps == steps
    assert res.precursor_events, "precursor silent on the fault matrix"
    assert res.recovery_events, "detector never fired"
    pre_step = int(res.precursor_events[0].split("@")[1].split("(")[0])
    det_step = int(res.recovery_events[0].split("@")[1].split("(")[0])
    assert 12 < pre_step < det_step   # fired in the window, before the spike

    clean = train(tc(), quiet=True, recovery=rec)
    assert clean.precursor_events == [] and clean.rollbacks == 0


def test_build_stack_critical_batch_kind():
    tc = _e2e_tc(gns=GNSConfig(enabled=True),
                 regulators=(RegulatorSpec(kind="lr"),
                             RegulatorSpec(kind="critical_batch")))
    stack = build_stack(tc, dp_size=2)
    assert "critical_batch" in stack
    assert stack["critical_batch"].dp_size == 2
    # round-trips through ControllerState with the rest of the stack
    cs = ControllerState.from_host(json.loads(json.dumps(
        stack.controller_state(3, 3 * 512, {}).to_host())))
    stack2 = build_stack(tc, dp_size=2)
    stack2.load_controller_state(cs)
    assert stack2["critical_batch"].state_dict() == \
        stack["critical_batch"].state_dict()
