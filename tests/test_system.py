"""System-level behaviour: the paper's recipe end to end (reduced versions;
the full stability comparisons live in benchmarks/).

Whole module is `slow` tier: each test is a real multi-bucket training run
(minutes on the 1-core container).  Run with `pytest -m slow`.
"""
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import get_arch, reduced
from repro.configs.base import (BatchWarmupConfig, OptimizerConfig, SLWConfig,
                                TrainConfig)
from repro.launch.train import train


def _tc(slw: bool, steps=24, lr=2e-3, pacing="linear", batch_warmup=False,
        schedule="token_cosine"):
    cfg = reduced(get_arch("gpt2-117m").model).replace(vocab_size=256)
    seq, batch = 128, 8
    return TrainConfig(
        model=cfg,
        optimizer=OptimizerConfig(
            lr=lr, min_lr=1e-5, schedule=schedule, warmup_steps=6,
            warmup_tokens=6 * batch * seq, total_steps=steps,
            total_tokens=steps * batch * seq),
        slw=SLWConfig(enabled=slw, pacing=pacing, start_seq_len=8,
                      duration_steps=steps // 2, round_multiple=8,
                      max_buckets=5),
        batch_warmup=BatchWarmupConfig(
            enabled=batch_warmup, start_batch=2,
            warmup_tokens=steps * batch * seq // 4),
        seq_len=seq, global_batch=batch, remat="none", eval_interval=10)


def test_slw_recipe_end_to_end():
    """Full recipe: pacing + truncation + token-wise LR + token budget."""
    res = train(_tc(slw=True), quiet=True)
    assert res.steps == 24
    # token budget respected: SLW saw fewer tokens than steps*batch*seq
    assert res.tokens < 24 * 8 * 128
    # seqlen ramps to full
    assert res.seqlen_history[0] < res.seqlen_history[-1] == 128
    # validation perplexity is finite and recorded at full length
    assert all(np.isfinite(p) for _, p in res.val_ppl_history)


def test_baseline_and_related_work_arms_run():
    """All four arms of Table 1 execute: baseline, SLW, Shortformer
    (two_stage), batch-size warmup."""
    for kwargs in (dict(slw=False),
                   dict(slw=True),
                   dict(slw=True, pacing="two_stage"),
                   dict(slw=False, batch_warmup=True)):
        res = train(_tc(**kwargs), quiet=True)
        assert res.steps == 24, kwargs
        assert np.isfinite(res.loss_history[-1]) or res.diverged


def test_variance_telemetry_recorded_every_step():
    res = train(_tc(slw=True), quiet=True)
    assert len(res.var_max_history) == res.steps
    assert len(res.var_l1_history) == res.steps
    assert all(v >= 0 for v in res.var_max_history)
    # Adam variance accumulates from zero: max element grows early
    assert res.var_max_history[5] >= res.var_max_history[0]


def test_token_budget_termination():
    """Same 157B-token-style budget semantics: stop on tokens, not steps."""
    import dataclasses
    tc = _tc(slw=True, steps=1000)
    budget = 10 * 8 * 128
    tc = dataclasses.replace(tc, optimizer=OptimizerConfig(
        lr=1e-3, schedule="token_cosine", warmup_tokens=100,
        total_steps=10**6, total_tokens=budget))
    res = train(tc, quiet=True)
    assert res.tokens >= budget
    # SLW needs more steps than a full-length run for the same token budget
    assert res.steps > 10
