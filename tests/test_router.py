"""Disaggregated serving stack contracts (EngineCore / Replica / Router).

* router parity: greedy outputs of the Router over N replicas — any
  admission policy, paged + dense fleets, with and without disaggregated
  prefill/decode roles — are tokenwise identical to a single
  legacy-config engine on the same request set (the ISSUE acceptance
  criterion, pinned for gpt2 + rwkv6);
* admission policies: fcfs delegates to ``Scheduler.next_admission``
  verbatim; shortest-prompt-first orders by prompt length with aging;
  budget-packing caps the round footprint; none of them starves a
  request under sustained load, and a reserve-blocked head leaves the
  queue untouched;
* slot migration: a mid-flight request moved between replicas (dense and
  paged, either direction) continues its token stream identically;
* metrics JSONL: per-step rows stream through ``--metrics-jsonl`` and
  parse back with ``core.telemetry.read_metrics_jsonl``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model, init_params
from repro.serve import (InferenceEngine, QueueFull, Replica, Request, Router,
                         SamplingParams, Scheduler, SchedulerConfig,
                         make_replicas)
from repro.serve.policies import (POLICIES, BudgetPackingPolicy, FCFSPolicy,
                                  ShortestPromptFirstPolicy, make_policy)


def _build(arch, **overrides):
    cfg = reduced(get_arch(arch).model)
    if overrides:
        cfg = cfg.replace(**overrides)
    model = build_model(cfg, dtype=jnp.float32, remat="none")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _mixed_requests(cfg, n=8, seed=3, sampling=SamplingParams()):
    """Prompt lens spanning two+ ladder buckets, distinct max_tokens."""
    rng = np.random.default_rng(seed)
    shapes = [(7, 5), (20, 9), (33, 3), (12, 7), (40, 4), (9, 8), (25, 6),
              (16, 2)][:n]
    return [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=plen)),
                    max_tokens=mt, sampling=sampling)
            for i, (plen, mt) in enumerate(shapes)]


def _single_engine_oracle(model, params, reqs, cache_len=64):
    """The single legacy-config engine the acceptance criterion names."""
    sched = SchedulerConfig(n_slots=3, cache_len=cache_len,
                            min_prompt_bucket=8, round_multiple=16,
                            max_buckets=4)
    return InferenceEngine(model, params, sched).run(reqs)


def _assert_parity(results, oracle):
    for a, b in zip(results, oracle):
        assert a.uid == b.uid
        assert a.tokens == b.tokens, f"uid {a.uid}"
        assert a.finish_reason == b.finish_reason


BASE = dict(n_slots=2, cache_len=64, min_prompt_bucket=8, round_multiple=16,
            max_buckets=4)


# -- router parity -----------------------------------------------------------

@pytest.mark.parametrize("arch", ["gpt2-117m", "rwkv6-7b"])
@pytest.mark.parametrize("route", ["least-loaded", "round-robin"])
def test_router_parity_two_replicas(arch, route):
    cfg, model, params = _build(arch)
    router = Router(make_replicas(model, params, SchedulerConfig(**BASE), 2),
                    route=route)
    reqs = _mixed_requests(cfg)
    results = router.run(reqs)
    _assert_parity(results, _single_engine_oracle(model, params, reqs))
    # both replicas actually served, everything drained
    assert router.stats.total_routed == len(reqs)
    assert len(router.stats.routed) == 2
    assert router.stats.shed == 0 and not router.busy
    for rep in router.replicas:
        assert sorted(rep.scheduler.free) == [0, 1]


def test_router_mixed_policies_paged_and_dense_parity():
    """A heterogeneous fleet — dense fcfs, paged budget-packing, dense
    shortest-prompt-first — still matches the single-engine oracle."""
    cfg, model, params = _build("gpt2-117m")
    dense = SchedulerConfig(**BASE)
    reps = [
        Replica(model, params, dense, name="dense-fcfs"),
        Replica(model, params,
                dataclasses.replace(dense, paged=True, page_size=16,
                                    policy="budget-packing",
                                    prefill_batch=2),
                name="paged-budget"),
        Replica(model, params,
                dataclasses.replace(dense, policy="shortest-prompt-first",
                                    prefill_batch=2),
                name="dense-spf"),
    ]
    router = Router(reps, route="round-robin")
    reqs = _mixed_requests(cfg)
    results = router.run(reqs)
    _assert_parity(results, _single_engine_oracle(model, params, reqs))
    assert router.stats.total_routed == len(reqs)
    # the paged replica's pool drained back to empty
    assert reps[1].core.state.alloc.pages_in_use == 0


@pytest.mark.parametrize("arch", ["gpt2-117m",
                                  pytest.param("rwkv6-7b",
                                               marks=pytest.mark.slow)])
def test_router_disaggregated_parity(arch):
    """Prefill-role → decode-role handoff (gather/insert_many path) is
    tokenwise invisible; the slow arm runs the recurrent backbone over a
    paged decode side."""
    cfg, model, params = _build(arch)
    paged = arch != "gpt2-117m"
    base = dataclasses.replace(SchedulerConfig(**BASE), paged=paged,
                               page_size=16)
    reps = make_replicas(model, params, base, 2, disaggregate=True)
    for rep in reps:
        assert rep.role == "decode"
        assert rep.prefill_replica is not None
        assert rep.prefill_core is rep.prefill_replica.core
        assert rep.prefill_core is not rep.core
        assert rep.prefill_core.cache is None  # prefill side owns no slots
    router = Router(reps)
    reqs = _mixed_requests(cfg)
    results = router.run(reqs)
    _assert_parity(results, _single_engine_oracle(model, params, reqs))
    # the prefill partners did the prefill device work
    assert sum(r.prefill_replica.stats.prefill_tokens for r in reps) \
        == sum(r.prompt_len for r in reqs)


def test_router_rejects_prefill_role_and_duplicate_uids():
    cfg, model, params = _build("gpt2-117m")
    pre = Replica(model, params, SchedulerConfig(**BASE), role="prefill")
    with pytest.raises(ValueError, match="prefill"):
        Router([pre])
    rep = Replica(model, params, SchedulerConfig(**BASE))
    r = _mixed_requests(cfg, n=1)[0]
    with pytest.raises(ValueError, match="duplicated"):
        Router([rep]).run([r, r])


def test_router_spill_and_shed():
    """A full replica spills to the next; all-full sheds explicitly."""
    cfg, model, params = _build("gpt2-117m")
    cfg_b = dataclasses.replace(SchedulerConfig(**BASE), max_pending=1)
    reps = make_replicas(model, params, cfg_b, 2)
    router = Router(reps, route="round-robin")
    reqs = _mixed_requests(cfg, n=4)
    reps[0].scheduler.submit(reqs[0])  # replica0's queue is now full
    assert router.submit(reqs[1])      # rr=0: bounces off replica0 -> spill
    assert router.stats.spilled == 1
    assert router.stats.routed == {"replica1": 1}
    assert router.submit(reqs[2]) is False  # rr=1: both queues full
    assert router.stats.shed == 1
    assert router.stats.total_routed == 1
    # drain so nothing is left half-admitted
    while router.busy:
        router.pump()


# -- admission policies ------------------------------------------------------

def _scheduler_with(reqs, **overrides):
    cfg = SchedulerConfig(**dict(BASE, **overrides))
    sch = Scheduler(cfg)
    for r in reqs:
        sch.submit(r)
    return sch


def _req(uid, plen, mt=4):
    return Request(uid=uid, tokens=(1,) * plen, max_tokens=mt)


def test_fcfs_policy_is_next_admission():
    reqs = [_req(0, 20), _req(1, 7), _req(2, 23), _req(3, 9)]
    a = _scheduler_with(reqs, prefill_batch=2)
    b = _scheduler_with(reqs, prefill_batch=2)
    picked = FCFSPolicy().select(a, 2)
    direct = b.next_admission(2)
    assert picked == direct
    assert list(a.pending) == list(b.pending)
    assert a.free == b.free


def test_shortest_prompt_first_orders_and_packs():
    sch = _scheduler_with([_req(0, 33), _req(1, 7), _req(2, 9), _req(3, 20)],
                          prefill_batch=2)
    pol = ShortestPromptFirstPolicy()
    picked = pol.select(sch, 2)
    # head = uid1 (len 7) and uid2 (len 9) shares its split (both < bucket 8
    # -> split 1? no: 7 -> split 1, 9 -> split 8) — only same-split packs
    assert picked[0][1].uid == 1
    assert all(r.prompt_len <= 9 for _, r in picked)


def test_shortest_prompt_first_ages_long_prompts():
    """A long prompt cannot be starved by a stream of short arrivals."""
    pol = ShortestPromptFirstPolicy(age_limit=3)
    cfg = SchedulerConfig(**BASE)
    sch = Scheduler(cfg)
    sch.submit(_req(999, 40))
    uid = 0
    rounds = 0
    admitted = set()
    while 999 not in admitted:
        rounds += 1
        assert rounds < 20, "long prompt starved"
        for _ in range(2):  # sustained short-arrival load
            sch.submit(_req(uid, 6))
            uid += 1
        for slot, r in pol.select(sch, 1):
            admitted.add(r.uid)
            sch.free.append(slot)  # instant finish
    assert rounds <= pol.age_limit + 2


def test_budget_packing_caps_round_footprint():
    # same split (all quantize to bucket 16), need = plen + max_tokens
    reqs = [_req(0, 17, 8), _req(1, 18, 8), _req(2, 19, 8), _req(3, 20, 8)]
    sch = _scheduler_with(reqs, prefill_batch=4, n_slots=4)
    picked = BudgetPackingPolicy(budget=55).select(sch, 4)
    # head (25) + uid1 (26) = 51 fits; adding uid2 (27) would blow 55
    assert [r.uid for _, r in picked] == [0, 1]
    assert [r.uid for r in sch.pending] == [2, 3]
    # a roomy budget packs the lot
    sch2 = _scheduler_with(reqs, prefill_batch=4, n_slots=4)
    picked2 = BudgetPackingPolicy(budget=1000).select(sch2, 4)
    assert [r.uid for _, r in picked2] == [0, 1, 2, 3]


@pytest.mark.parametrize("policy_name", POLICIES)
def test_no_starvation_under_sustained_load(policy_name):
    """Property: under each policy, every pending request is eventually
    admitted even with a sustained stream of fresh competing arrivals."""
    cfg = SchedulerConfig(**dict(BASE, prefill_batch=2, policy=policy_name,
                                 pack_budget=64))
    sch = Scheduler(cfg)
    pol = make_policy(cfg)
    rng = np.random.default_rng(0)
    watched = [_req(1000 + i, int(p))
               for i, p in enumerate([40, 6, 23, 11])]
    for r in watched:
        sch.submit(r)
    admitted = set()
    uid = 0
    rounds = 0
    while not all(r.uid in admitted for r in watched):
        rounds += 1
        assert rounds < 300, f"{policy_name}: starved " \
            f"{[r.uid for r in watched if r.uid not in admitted]}"
        if rng.random() < 0.8:  # sustained load
            sch.submit(_req(uid, int(rng.integers(5, 30))))
            uid += 1
        for slot, r in pol.select(sch, cfg.prefill_batch):
            admitted.add(r.uid)
            sch.free.append(slot)  # instant finish


@pytest.mark.parametrize("policy_name", POLICIES)
def test_blocked_head_leaves_queue_untouched(policy_name):
    """Paged reserve gate: a head the pool cannot cover waits in place."""
    cfg = SchedulerConfig(**dict(BASE, policy=policy_name, pack_budget=64))
    sch = Scheduler(cfg)
    for r in [_req(0, 20), _req(1, 7)]:
        sch.submit(r)
    pol = make_policy(cfg)
    before_pending = [r.uid for r in sch.pending]
    before_free = list(sch.free)
    assert pol.select(sch, 2, reserve=lambda slot, req: False) == []
    assert [r.uid for r in sch.pending] == before_pending
    assert sch.free == before_free


# -- slot migration ----------------------------------------------------------

@pytest.mark.parametrize("paged_src,paged_dst", [(False, False),
                                                 (False, True),
                                                 (True, False)])
def test_slot_migration_mid_flight(paged_src, paged_dst):
    """A request moved between replicas mid-stream finishes with exactly
    the tokens it would have produced in place."""
    cfg, model, params = _build("gpt2-117m")
    mk = lambda paged: Replica(
        model, params,
        dataclasses.replace(SchedulerConfig(**BASE), paged=paged,
                            page_size=16))
    src, dst = mk(paged_src), mk(paged_dst)
    req = _mixed_requests(cfg, n=2)[1]  # 20-token prompt, 9 generations
    [expect] = InferenceEngine(model, params,
                               SchedulerConfig(**BASE)).run([req])
    src.scheduler.submit(req)
    src.pump()  # admit + first fused step
    src.pump()  # one more step mid-flight
    [slot] = list(src.scheduler.active)
    dst_slot = src.migrate_slot_to(slot, dst)
    assert not src.scheduler.busy
    assert dst_slot in dst.scheduler.active
    if paged_src:
        assert src.core.state.alloc.pages_in_use == 0  # pages returned
    while dst.scheduler.busy:
        dst.pump()
    [res] = dst.take_finished()
    assert res.uid == req.uid
    assert res.tokens == expect.tokens
    assert res.finish_reason == expect.finish_reason


# -- metrics JSONL -----------------------------------------------------------

def test_metrics_jsonl_roundtrip(tmp_path):
    from repro.core.telemetry import read_metrics_jsonl
    from repro.launch.serve import serve_router
    path = tmp_path / "serve_metrics.jsonl"
    out = serve_router("gpt2-117m", True, n_slots=2, prompt_len=24,
                       gen_tokens=6, n_requests=5, replicas=2,
                       policy="budget-packing", metrics_jsonl=str(path),
                       quiet=True)
    _labels, rows = read_metrics_jsonl(str(path))
    step_rows = [r for r in rows if "decode_step" in r]
    total_steps = sum(rep.stats.decode_steps
                      for rep in out["router"].replicas)
    assert len(step_rows) == total_steps > 0
    for r in step_rows:
        assert {"replica", "step_s", "active", "queue_depth", "free_slots",
                "p95_s"} <= set(r)
    [summary] = [r for r in rows if r.get("summary")]
    assert summary["aggregate"]["generated_tokens"] \
        == sum(len(res.tokens) for res in out["results"])
