"""Paged KV caches (serve/paging.py) + the slot-lifecycle bug burn-down.

* paged-vs-dense engine parity: greedy continuous batching over a paged
  pool is tokenwise identical to the dense slot cache while the pool is
  strictly smaller than the dense allocation (the ISSUE acceptance
  criterion; gpt2 fast + zamba2 hybrid slow-marked, rwkv6 pins that
  recurrent O(1) leaves page as a no-op);
* allocator: property test over random reserve/allocate/free sequences —
  no page is ever leaked or double-owned; gather -> evict -> insert
  round-trips bit-exactly through the page pool;
* admission: page exhaustion makes requests wait, then admits after frees;
* paged flash-decode kernel vs the gather-then-dense oracle;
* regressions: free-slot ``pos`` no longer advances during fused decode,
  per-slot writes clamp at ``cache_len``, ``Scheduler.abort`` preserves
  partial results, ``EngineStats.step_times`` is a bounded ring.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.kernels import flash_decode_paged, flash_decode_paged_partials
from repro.kernels.flash_decode.ref import (
    decode_attention_reference, gather_pages,
    paged_decode_attention_reference, paged_decode_partials_reference)
from repro.models import build_model, init_params
from repro.serve import (InferenceEngine, PageAllocator, PagedDecodeState,
                         PageExhausted, Request, SamplingParams, Scheduler,
                         SchedulerConfig, SlotDecodeState, cache_nbytes)
from repro.serve.engine import STEP_TIME_WINDOW, EngineStats
from repro.serve.paging import pages_for
from repro.serve.types import GenerationResult


def _build(arch):
    cfg = reduced(get_arch(arch).model)
    model = build_model(cfg, dtype=jnp.float32, remat="none")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _mixed_requests(cfg, n=8, seed=3):
    rng = np.random.default_rng(seed)
    shapes = [(7, 5), (20, 9), (33, 3), (12, 7), (40, 4), (9, 8), (25, 6),
              (16, 2)][:n]
    return [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, size=plen)),
                    max_tokens=mt)
            for i, (plen, mt) in enumerate(shapes)]


DENSE = SchedulerConfig(n_slots=3, cache_len=64, min_prompt_bucket=8,
                        round_multiple=16, max_buckets=4)
# 7 * 16 = 112 pool tokens < 3 * 64 = 192 dense tokens, and small enough
# that admissions must wait on pages mid-run (acceptance criterion: parity
# while n_pages * page_size < n_slots * cache_len)
PAGED = dataclasses.replace(DENSE, paged=True, page_size=16, n_pages=7)

PARITY_ARCHS = ["gpt2-117m", "rwkv6-7b",
                pytest.param("zamba2-2.7b", marks=pytest.mark.slow)]


# -- engine parity -----------------------------------------------------------
@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_paged_engine_matches_dense(arch):
    cfg, model, params = _build(arch)
    assert PAGED.resolved_n_pages * PAGED.page_size \
        < DENSE.n_slots * DENSE.cache_len
    reqs = _mixed_requests(cfg)
    dense = InferenceEngine(model, params, cfg=DENSE)
    d_res = dense.run(reqs)
    paged = InferenceEngine(model, params, cfg=PAGED)
    p_res = paged.run(reqs)
    for d, p in zip(d_res, p_res):
        assert p.uid == d.uid
        assert p.tokens == d.tokens, f"uid {d.uid}"
        assert p.finish_reason == d.finish_reason
    # every page returned to the free list once the workload drained
    paged.state.alloc.check()
    assert paged.state.alloc.pages_in_use == 0
    assert sorted(paged.scheduler.free) == list(range(PAGED.n_slots))
    # the paged KV pool is resident-smaller than the dense slot rows
    seq_leaves = {"k", "v", "attn_k", "attn_v"}
    dkv = {k: v for k, v in dense.cache.items() if k in seq_leaves}
    pkv = {k: v for k, v in paged.cache.items() if k in seq_leaves}
    if dkv:  # rwkv6 has no attention KV: paging is a structural no-op
        assert cache_nbytes(pkv) < cache_nbytes(dkv)


def test_paged_engine_stop_token_and_reuse():
    """Stop tokens retire paged slots early (pages freed before the length
    budget), and the engine is reusable after a paged run."""
    cfg, model, params = _build("gpt2-117m")
    dense = InferenceEngine(model, params, cfg=DENSE)
    paged = InferenceEngine(model, params, cfg=PAGED)
    rng = np.random.default_rng(0)
    base = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, size=9))
    oracle = dense.run([Request(uid=0, tokens=base, max_tokens=6)])[0].tokens
    stop = oracle[1]
    reqs = [Request(uid=0, tokens=base, max_tokens=6,
                    sampling=SamplingParams(stop_token=stop)),
            Request(uid=1, tokens=base[:5], max_tokens=1),
            Request(uid=2, tokens=base, max_tokens=6)]
    res = paged.run(reqs)
    assert res[0].tokens == oracle[:2]
    assert res[0].finish_reason == "stop_token"
    assert res[1].n_generated == 1
    assert res[2].tokens == oracle
    paged.state.alloc.check()
    assert paged.state.alloc.pages_in_use == 0
    # reuse: a second run on the same engine stays exact
    res2 = paged.run([Request(uid=7, tokens=base, max_tokens=6)])
    assert res2[0].tokens == oracle


# -- allocator ---------------------------------------------------------------
def test_allocator_random_ops_never_leak():
    """Random reserve/allocate/grow/free sequences keep the ownership
    invariants: every page on the free list xor owned by exactly one slot,
    committed <= n_pages, table rows dense-prefix + -1 tail."""
    rng = np.random.default_rng(0)
    alloc = PageAllocator(n_pages=13, page_size=4, n_slots=5,
                          pages_per_slot=4)
    live = {}  # slot -> reserved pages
    for _ in range(500):
        op = rng.integers(0, 4)
        slot = int(rng.integers(0, 5))
        if op == 0 and slot not in live:
            need = int(rng.integers(1, 5))
            if alloc.reserve(slot, need):
                live[slot] = need
        elif op == 1 and slot in live:
            # allocate up to the reservation: must never raise
            n_tok = int(rng.integers(1, live[slot] * 4 + 1))
            alloc.allocate(slot, n_tok)
        elif op == 2 and slot in live:
            alloc.free_slot(slot)
            del live[slot]
        elif op == 3 and slot in live:
            # idempotent: re-allocating a covered range is a no-op
            before = int(alloc.owned[slot])
            alloc.allocate(slot, before * 4)
            assert int(alloc.owned[slot]) == before
        alloc.check()
    for slot in list(live):
        alloc.free_slot(slot)
    alloc.check()
    assert alloc.pages_in_use == 0


def test_allocator_exhaustion_is_explicit():
    alloc = PageAllocator(n_pages=3, page_size=4, n_slots=2,
                          pages_per_slot=4)
    assert alloc.reserve(0, 3)
    alloc.allocate(0, 12)
    # no reservation and the pool is committed -> explicit fault, not a
    # silent overwrite of someone else's page
    with pytest.raises(PageExhausted):
        alloc.allocate(1, 1)
    assert not alloc.reserve(1, 5)  # > pages_per_slot can never be honored
    # growing past the page table is a fault even with pool headroom
    roomy = PageAllocator(n_pages=5, page_size=4, n_slots=2,
                          pages_per_slot=2)
    assert roomy.reserve(0, 2)
    with pytest.raises(PageExhausted):
        roomy.allocate(0, 9)  # needs 3 pages, table holds 2


def test_pages_for():
    assert pages_for(0, 16) == 0
    assert pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2


# -- state round-trip --------------------------------------------------------
def test_paged_gather_evict_insert_roundtrip():
    """gather -> evict -> insert through the page pool is bit-exact, and
    the re-inserted slot may land on different physical pages."""
    cfg, model, params = _build("gpt2-117m")
    state = PagedDecodeState(model, page_size=8, n_pages=10)
    cache = state.init_slots(3, 32)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 13)),
                       jnp.int32)
    _, row = model.prefill(params, {"tokens": toks}, cache_len=32)
    assert state.alloc.reserve(1, pages_for(32, 8))
    cache = state.insert(cache, 1, row)
    got = state.gather(cache, 1)
    assert set(got.keys()) == set(row.keys())  # model-format: no active leaf
    np.testing.assert_array_equal(np.asarray(got["k"]), np.asarray(row["k"]))
    np.testing.assert_array_equal(np.asarray(got["v"]), np.asarray(row["v"]))
    assert int(got["pos"]) == 13
    cache = state.evict(cache, 1)
    state.alloc.check()
    assert state.alloc.pages_in_use == 0
    assert bool((np.asarray(cache["page_table"]) == -1).all())
    # churn the free list so slot 1 lands on different pages, then re-insert
    assert state.alloc.reserve(0, 2)
    state.alloc.allocate(0, 16)
    assert state.alloc.reserve(1, pages_for(32, 8))
    cache = dict(cache, page_table=jnp.asarray(state.alloc.table))
    cache = state.insert(cache, 1, got)
    again = state.gather(cache, 1)
    np.testing.assert_array_equal(np.asarray(again["k"]),
                                  np.asarray(row["k"]))
    assert int(again["pos"]) == 13


# -- admission under page pressure ------------------------------------------
def test_page_exhaustion_blocks_then_admits():
    """Strict FCFS under page pressure: a blocked queue head returns [] with
    the queue untouched, and admission resumes once an evict frees pages."""
    cfg, model, params = _build("gpt2-117m")
    sched_cfg = SchedulerConfig(n_slots=2, cache_len=32, page_size=16,
                                n_pages=3, paged=True, min_prompt_bucket=8,
                                round_multiple=8, max_buckets=2)
    state = PagedDecodeState(model, page_size=16, n_pages=3)
    cache = state.init_slots(2, 32)
    sched = Scheduler(sched_cfg)
    # each request needs 2 pages; the 3-page pool holds only one at a time
    r0 = Request(uid=0, tokens=(1,) * 10, max_tokens=10)
    r1 = Request(uid=1, tokens=(2,) * 10, max_tokens=10)
    sched.submit(r0)
    sched.submit(r1)
    adm = sched.next_admission(reserve=state.try_reserve)
    assert [r.uid for _, r in adm] == [0]
    slot0 = adm[0][0]
    # head blocked: nothing admitted, r1 still queued in order
    assert sched.next_admission(reserve=state.try_reserve) == []
    assert [r.uid for r in sched.pending] == [1]
    # a free slot exists, but no pages -- it must wait, not admit
    assert sched.free
    state.alloc.free_slot(slot0)  # r0 retires
    sched.free.append(slot0)
    adm = sched.next_admission(reserve=state.try_reserve)
    assert [r.uid for _, r in adm] == [1]
    state.alloc.check()


def test_paged_engine_oversubscribed_completes():
    """End-to-end: pool smaller than the slot capacity forces waiting, yet
    every request completes with exact dense parity (nothing starves)."""
    cfg, model, params = _build("gpt2-117m")
    base = SchedulerConfig(n_slots=2, cache_len=32, min_prompt_bucket=8,
                           round_multiple=8, max_buckets=2)
    tight = dataclasses.replace(base, paged=True, page_size=16, n_pages=3)
    reqs = [Request(uid=i, tokens=tuple(range(3 + i, 13 + i)), max_tokens=9)
            for i in range(4)]
    d_res = InferenceEngine(model, params, cfg=base).run(reqs)
    eng = InferenceEngine(model, params, cfg=tight)
    # the 3-page pool can hold only one 2-page request at a time
    seen = []
    orig = eng.state.decode

    def spy(params_, cache_, toks_):
        seen.append(int(eng.state._host_active.sum()))
        return orig(params_, cache_, toks_)

    eng.state.decode = spy
    p_res = eng.run(reqs)
    eng.state.decode = orig
    assert max(seen) == 1  # pages, not slots, were the binding constraint
    for d, p in zip(d_res, p_res):
        assert p.tokens == d.tokens and p.finish_reason == "length"
    eng.state.alloc.check()
    assert eng.state.alloc.pages_in_use == 0


def test_scheduler_rejects_undersized_pool():
    with pytest.raises(ValueError):
        Scheduler(SchedulerConfig(n_slots=2, cache_len=64, paged=True,
                                  page_size=16, n_pages=3))


# -- paged flash-decode kernel ----------------------------------------------
def _paged_fixture(seed=0, b=5, h=8, kvh=4, d=16, ps=8, n_pages=12, mp=4):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k_pool = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, d)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(n_pages, ps, kvh, d)), jnp.float32)
    # ragged ownership incl. a full slot and an empty slot
    table = np.full((b, mp), -1, np.int32)
    lengths = np.asarray([5, 8, 19, 32, 0], np.int32)
    free = list(range(n_pages))[::-1]
    for i, ln in enumerate(lengths):
        for j in range(pages_for(int(ln), ps)):
            table[i, j] = free.pop()
    return q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(lengths)


def test_paged_kernel_matches_reference():
    q, k_pool, v_pool, table, lengths = _paged_fixture()
    out = flash_decode_paged(q, k_pool, v_pool, table, lengths,
                             interpret=True)
    ref = paged_decode_attention_reference(q, k_pool, v_pool, table, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    o, m, l = flash_decode_paged_partials(q, k_pool, v_pool, table, lengths,
                                          interpret=True)
    ro, rm, rl = paged_decode_partials_reference(q, k_pool, v_pool, table,
                                                 lengths)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ro),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(rm),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(rl),
                               rtol=2e-5, atol=2e-5)


def test_paged_reference_matches_dense_on_gathered_cache():
    """The paged oracle is the dense oracle over the gathered cache — pins
    gather_pages (zeroed unowned pages, position-ordered reassembly)."""
    q, k_pool, v_pool, table, lengths = _paged_fixture(seed=1)
    kc, vc = gather_pages(k_pool, table), gather_pages(v_pool, table)
    dense = decode_attention_reference(q, kc, vc, lengths)
    paged = paged_decode_attention_reference(q, k_pool, v_pool, table,
                                             lengths)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               rtol=1e-6, atol=1e-6)


# -- regression: slot-lifecycle bugs ----------------------------------------
def test_free_slot_pos_frozen_during_fused_decode():
    """Bugfix: fused decode used to advance ``pos`` for every slot — free
    and evicted slots included — so long-lived engines pushed empty slots'
    write indices past cache_len and re-inserts wrote out of bounds."""
    cfg, model, params = _build("gpt2-117m")
    state = SlotDecodeState(model)
    cache = state.init_slots(3, 16)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 5)),
                       jnp.int32)
    _, row = model.prefill(params, {"tokens": toks}, cache_len=16)
    cache = state.insert(cache, 1, row)
    for _ in range(3):
        _, cache = state.decode(params, cache,
                                jnp.zeros((3, 1), jnp.int32))
    pos = np.asarray(cache["pos"])
    assert pos[1] == 8  # the occupied slot advanced 5 -> 8
    assert pos[0] == 0 and pos[2] == 0  # free slots frozen
    # evicted slots freeze too (active cleared on evict)
    cache = state.evict(cache, 1)
    _, cache = state.decode(params, cache, jnp.zeros((3, 1), jnp.int32))
    assert (np.asarray(cache["pos"]) == 0).all()


def test_decode_write_clamped_at_cache_len():
    """Bugfix: per-slot decode writes past ``cache_len`` now drop instead
    of wrapping/clobbering; ``pos`` saturates at the capacity."""
    cfg, model, params = _build("gpt2-117m")
    state = SlotDecodeState(model)
    cache = state.init_slots(1, 8)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 6)),
                       jnp.int32)
    _, row = model.prefill(params, {"tokens": toks}, cache_len=8)
    cache = state.insert(cache, 0, row)
    step = jnp.zeros((1, 1), jnp.int32)
    _, cache = state.decode(params, cache, step)  # pos 6 -> 7
    _, cache = state.decode(params, cache, step)  # pos 7 -> 8 (full)
    k_full = np.asarray(cache["k"]).copy()
    _, cache = state.decode(params, cache, step)  # past capacity
    assert int(np.asarray(cache["pos"])[0]) == 8  # saturated, not 9
    np.testing.assert_array_equal(np.asarray(cache["k"]), k_full)


def test_abort_preserves_partial_result():
    """Bugfix: aborting an activated slot used to fabricate a fresh empty
    result, silently dropping tokens already streamed via on_token."""
    sched = Scheduler(SchedulerConfig(n_slots=2, cache_len=32))
    req = Request(uid=9, tokens=(1, 2, 3), max_tokens=8)
    sched.submit(req)
    [(slot, r)] = sched.next_admission()
    st = sched.activate(slot, r, first_token=11, prefill_s=0.0)
    st.result.tokens.extend([12, 13])
    res = sched.abort(slot, r)
    assert res.tokens == [11, 12, 13]
    assert res.finish_reason == "error"
    assert slot in sched.free and not sched.active
    # never-activated abort still yields an (empty) error result
    res2 = sched.abort(sched.free[-1], req)
    assert res2.tokens == [] and res2.finish_reason == "error"


def test_step_times_bounded_ring_and_percentile():
    """Bugfix: ``step_times`` grew one float per fused step forever; it is
    now a bounded ring with exact percentiles for short runs."""
    stats = EngineStats()
    assert stats.latency_percentile(50) == 0.0
    for v in (1.0, 2.0, 3.0, 4.0):
        stats.step_times.append(v)
    assert stats.latency_percentile(50) == 2.5
    assert stats.latency_percentile(100) == 4.0
    for i in range(STEP_TIME_WINDOW * 2):
        stats.step_times.append(float(i))
    assert len(stats.step_times) == STEP_TIME_WINDOW
    # trailing-window percentile: min of the ring is the oldest survivor
    assert stats.latency_percentile(0) == float(STEP_TIME_WINDOW)
