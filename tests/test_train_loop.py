"""End-to-end training loop: learning, SLW mechanics, the composed
regulator recipe, fault tolerance."""
import dataclasses
import math
import os

import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import (BatchWarmupConfig, OptimizerConfig, SLWConfig,
                                TrainConfig)
from repro.core import pacing
from repro.core.batch_warmup import BatchWarmup
from repro.distributed.fault_tolerance import (DrainSignal, StepWatchdog,
                                               TrainSupervisor)
from repro.launch.train import Trainer, train
from repro.optim import lr_at


def _tc(steps=40, slw=True, lr=2e-3, seq=128, batch=8, ckpt_dir="",
        pacing="linear", mode="truncate", vocab=256, buckets=5):
    cfg = reduced(get_arch("gpt2-117m").model).replace(vocab_size=vocab)
    return TrainConfig(
        model=cfg,
        optimizer=OptimizerConfig(
            lr=lr, min_lr=1e-5, schedule="token_cosine",
            warmup_steps=8, warmup_tokens=8 * batch * seq,
            total_steps=steps, total_tokens=steps * batch * seq),
        slw=SLWConfig(enabled=slw, pacing=pacing, start_seq_len=8,
                      duration_steps=steps // 2, round_multiple=8,
                      max_buckets=buckets, mode=mode),
        seq_len=seq, global_batch=batch, remat="none",
        eval_interval=0, checkpoint_interval=10, checkpoint_dir=ckpt_dir)


def test_loss_decreases_and_buckets_bounded():
    res = train(_tc(steps=24, buckets=4), quiet=True)
    assert res.steps == 24
    assert not res.diverged
    first = np.mean(res.loss_history[:5])
    last = np.mean(res.loss_history[-5:])
    assert last < first  # learning
    assert res.n_compiles <= 4 + 1  # bounded by the bucket ladder
    # seqlen schedule is monotone and reaches full length
    assert res.seqlen_history[-1] == 128
    assert res.seqlen_history[0] <= 16


@pytest.mark.slow
def test_token_accounting_truncate_vs_repack():
    r_trunc = train(_tc(steps=20, mode="truncate"), quiet=True)
    r_pack = train(_tc(steps=20, mode="repack"), quiet=True)
    assert r_pack.tokens > r_trunc.tokens  # repack drops nothing


@pytest.mark.slow
def test_checkpoint_resume_exact(tmp_path):
    d = str(tmp_path / "ck")
    tc = _tc(steps=30, ckpt_dir=d)
    full = train(tc, quiet=True)
    # restart from step-20 checkpoint and finish
    tc2 = _tc(steps=30, ckpt_dir=d)
    part = train(tc2, resume=True, quiet=True)
    assert part.restored_from_step == 30  # the final checkpoint
    assert part.steps == 30  # nothing left to do


@pytest.mark.slow
def test_resume_mid_run_continues_schedule(tmp_path):
    d = str(tmp_path / "ck")
    tc = _tc(steps=18, ckpt_dir=d)
    r1 = train(tc, quiet=True)  # checkpoints at 10 and at end (18)
    tc_more = _tc(steps=36, ckpt_dir=d)
    r2 = train(tc_more, resume=True, quiet=True)
    assert r2.restored_from_step == 18
    assert r2.steps == 36
    # curriculum resumed, not restarted: first seqlen after resume >= before
    assert r2.seqlen_history[0] >= r1.seqlen_history[-1]


@pytest.mark.slow
def test_supervisor_recovers_from_injected_failure(tmp_path):
    d = str(tmp_path / "ck")
    sup = TrainSupervisor(max_restarts=2)

    def run(resume: bool) -> str:
        res = train(_tc(steps=25, ckpt_dir=d), resume=resume,
                    fail_at_step=None if resume else 15, quiet=True)
        return f"ok:{res.steps}"

    out = sup.run(run)
    assert out == "ok:25"
    assert sup.restarts == 1


def _composed_tc(steps, ckpt_dir="", seq=128, batch=8):
    """SLW + batch warmup + token-wise LR warmup, all at once — the paper's
    joint recipe, expressible since the regulator control plane."""
    tc = _tc(steps=steps, seq=seq, batch=batch, ckpt_dir=ckpt_dir)
    # schedule constants must not depend on `steps`, so the 8-step and
    # 16-step configs describe the *same* trajectory
    return dataclasses.replace(
        tc,
        slw=dataclasses.replace(tc.slw, duration_steps=12),
        batch_warmup=BatchWarmupConfig(enabled=True, start_batch=2,
                                       warmup_tokens=1000),
        checkpoint_interval=8)


def _predict_composed(tc, n_steps, dp_size=1):
    """Per-step (seqlen, batch, lr) from each schedule computed standalone
    (the primitive modules, not the stack) — the oracle the composed run
    must match."""
    ladder = pacing.bucket_ladder(tc.slw, tc.seq_len)
    bw = BatchWarmup(tc.batch_warmup, tc.global_batch, dp_size=dp_size)
    tokens, rows = 0, []
    for i in range(n_steps):
        s = pacing.seqlen_at(tc.slw, i, tc.seq_len,
                             tc.optimizer.warmup_steps, ladder)
        b = bw.batch_for_tokens(tokens)
        rows.append((s, b, lr_at(tc.optimizer, i, tokens)))
        tokens += s * b
    return rows


def test_composed_recipe_matches_individual_regulators(tmp_path):
    """Acceptance: one TrainConfig runs SLW + batch warmup + token-wise LR
    simultaneously; the per-step (seqlen, batch, lr) trajectory equals the
    individual schedules' standalone predictions, across a mid-warmup
    checkpoint/restore, with dp-size batch quantization engaged."""
    d = str(tmp_path / "ck")
    steps, dp = 16, 2
    pred = _predict_composed(_composed_tc(steps), steps, dp_size=dp)

    r1 = train(_composed_tc(8, ckpt_dir=d), quiet=True, dp_size=dp)
    assert r1.steps == 8  # mid-warmup: both schedules still ramping
    assert r1.seqlen_history[-1] < 128 and r1.batch_history[-1] < 8
    r2 = train(_composed_tc(steps, ckpt_dir=d), resume=True, quiet=True,
               dp_size=dp)
    assert r2.restored_from_step == 8

    seqs = r1.seqlen_history + r2.seqlen_history
    batches = r1.batch_history + r2.batch_history
    lrs = r1.lr_history + r2.lr_history
    assert seqs == [p[0] for p in pred]
    assert batches == [p[1] for p in pred]
    assert all(b % dp == 0 for b in batches)  # paper's §5.1 dp constraint
    for got, (_, _, want) in zip(lrs, pred):
        assert got == pytest.approx(want, rel=1e-6)
    assert r2.tokens == sum(s * b for s, b, _ in pred)


def test_variance_gated_resume_roundtrip(tmp_path):
    """gate_level/var_trailing round-trip through ControllerState: a restart
    mid-warmup resumes the variance-gated curriculum at the same bucket."""
    d = str(tmp_path / "ck")
    tc = _tc(steps=10, pacing="variance_gated", ckpt_dir=d)
    tr1 = Trainer(tc, quiet=True)
    res1 = tr1.run()
    assert res1.steps == 10
    saved = dataclasses.asdict(tr1.stack["seqlen"].curriculum.state)
    assert saved["gate_level"] > 0  # the gate actually advanced
    assert saved["var_trailing"] > 0.0

    tc2 = _tc(steps=20, pacing="variance_gated", ckpt_dir=d)
    tr2 = Trainer(tc2, quiet=True)
    assert tr2.resume() == 10
    restored = dataclasses.asdict(tr2.stack["seqlen"].curriculum.state)
    assert restored == saved
    assert tr2.stack["seqlen"].curriculum.seqlen_for_step() == \
        tr1.stack["seqlen"].curriculum.seqlen_for_step()  # same bucket
    res2 = tr2.run()
    assert res2.steps == 20
    assert res2.seqlen_history[0] >= res1.seqlen_history[-1]


def test_drain_checkpoints_and_exits(tmp_path):
    d = str(tmp_path / "ck")
    drain = DrainSignal(install=False)
    calls = {"n": 0}

    def cb(step, metrics):
        calls["n"] += 1
        if step == 7:
            drain.trigger()

    res = train(_tc(steps=100, ckpt_dir=d), drain=drain, callback=cb,
                quiet=True)
    assert res.drained
    assert res.steps == 8
    from repro.checkpoint import latest_step
    assert latest_step(d) == 8  # checkpointed on the way out


def test_custom_hooks_extend_defaults():
    """Passing hooks= must not silently drop the drain/callback/eval
    concerns — extras append after the default hook set."""
    from repro.launch.train import (CheckpointHook, DrainHook, EvalHook,
                                    TelemetryHook, Trainer, TrainerHook,
                                    WatchdogHook)

    class Extra(TrainerHook):
        pass

    extra = Extra()
    tr = Trainer(_tc(steps=1), hooks=[extra])
    kinds = [type(h) for h in tr.hooks]
    assert kinds == [DrainHook, WatchdogHook, TelemetryHook, EvalHook,
                     CheckpointHook, Extra]
    assert tr.hooks[-1] is extra


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=16, factor=2.0)
    import time
    for i in range(12):
        wd.start()
        if i == 10:
            time.sleep(0.05)
        else:
            time.sleep(0.001)
        wd.stop()
    assert len(wd.straggler_steps) >= 1
    assert wd.summary()["stragglers"] >= 1


@pytest.mark.slow
def test_variance_gated_pacing_runs():
    res = train(_tc(steps=20, pacing="variance_gated"), quiet=True)
    assert res.steps == 20
    assert not res.diverged


def test_watchdog_stop_without_start_is_noop():
    """Hook orders that skip start (drain/early-stop paths) used to crash
    on a None _t0; now the unmatched stop records nothing."""
    wd = StepWatchdog()
    assert wd.stop() is False
    assert wd.durations == []
    wd.start()
    assert wd.stop() is False  # first sample: no straggler baseline yet
    assert len(wd.durations) == 1
    assert wd.stop() is False  # second unmatched stop: still a no-op
    assert len(wd.durations) == 1


def test_retry_policy_exponential_backoff_with_cap():
    from repro.distributed.fault_tolerance import RetryPolicy
    pol = RetryPolicy(max_retries=5, backoff_s=0.5, backoff_factor=2.0,
                      backoff_cap_s=3.0)
    assert [pol.delay(a) for a in (1, 2, 3, 4, 5)] == \
        [0.5, 1.0, 2.0, 3.0, 3.0]  # capped from attempt 4
    assert RetryPolicy(backoff_s=0.0).delay(3) == 0.0  # no-sleep default


def test_supervisor_records_failures_and_backs_off():
    import time as _time
    from repro.distributed.fault_tolerance import RetryPolicy
    sup = TrainSupervisor(policy=RetryPolicy(max_retries=2, backoff_s=0.05,
                                             backoff_factor=2.0))
    attempts = []

    def run(resume):
        attempts.append(resume)
        if len(attempts) < 3:
            raise RuntimeError(f"boom {len(attempts)}")
        return "ok"

    t0 = _time.time()
    assert sup.run(run) == "ok"
    elapsed = _time.time() - t0
    assert attempts == [False, True, True]
    assert sup.restarts == 2
    assert [f["attempt"] for f in sup.failures] == [1, 2]
    assert [f["error"] for f in sup.failures] == \
        ["RuntimeError: boom 1", "RuntimeError: boom 2"]
    assert all(t0 <= f["time"] <= t0 + elapsed for f in sup.failures)
    assert elapsed >= 0.05 + 0.1  # 0.05, then 0.05 * 2


def test_drain_signal_uninstall_restores_handlers():
    import signal
    prev_term = signal.getsignal(signal.SIGTERM)
    prev_int = signal.getsignal(signal.SIGINT)
    ds = DrainSignal(install=True)
    assert signal.getsignal(signal.SIGTERM) == ds._handler
    ds.uninstall()
    assert signal.getsignal(signal.SIGTERM) is prev_term
    assert signal.getsignal(signal.SIGINT) is prev_int
    ds.uninstall()  # idempotent
    assert signal.getsignal(signal.SIGTERM) is prev_term
    # the Trainer teardown path: DrainHook.close() uninstalls, so handlers
    # never leak across Trainer instances
    from repro.launch.train import DrainHook
    ds2 = DrainSignal(install=True)
    hook = DrainHook(ds2)
    assert signal.getsignal(signal.SIGTERM) == ds2._handler
    hook.close()
    assert signal.getsignal(signal.SIGTERM) is prev_term


def test_divergence_detection():
    """Absurd LR must trip the NaN/divergence path, like the paper's 40x-LR
    baseline (Fig. 5)."""
    res = train(_tc(steps=40, slw=False, lr=80.0), quiet=True,
                stop_on_nan=True)
    assert res.diverged or res.tracker_summary["max_loss_ratio"] > 2.0
