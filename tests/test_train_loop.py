"""End-to-end training loop: learning, SLW mechanics, fault tolerance."""
import math
import os

import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import (BatchWarmupConfig, OptimizerConfig, SLWConfig,
                                TrainConfig)
from repro.distributed.fault_tolerance import (DrainSignal, StepWatchdog,
                                               TrainSupervisor)
from repro.launch.train import train


def _tc(steps=40, slw=True, lr=2e-3, seq=128, batch=8, ckpt_dir="",
        pacing="linear", mode="truncate", vocab=256, buckets=5):
    cfg = reduced(get_arch("gpt2-117m").model).replace(vocab_size=vocab)
    return TrainConfig(
        model=cfg,
        optimizer=OptimizerConfig(
            lr=lr, min_lr=1e-5, schedule="token_cosine",
            warmup_steps=8, warmup_tokens=8 * batch * seq,
            total_steps=steps, total_tokens=steps * batch * seq),
        slw=SLWConfig(enabled=slw, pacing=pacing, start_seq_len=8,
                      duration_steps=steps // 2, round_multiple=8,
                      max_buckets=buckets, mode=mode),
        seq_len=seq, global_batch=batch, remat="none",
        eval_interval=0, checkpoint_interval=10, checkpoint_dir=ckpt_dir)


def test_loss_decreases_and_buckets_bounded():
    res = train(_tc(steps=24, buckets=4), quiet=True)
    assert res.steps == 24
    assert not res.diverged
    first = np.mean(res.loss_history[:5])
    last = np.mean(res.loss_history[-5:])
    assert last < first  # learning
    assert res.n_compiles <= 4 + 1  # bounded by the bucket ladder
    # seqlen schedule is monotone and reaches full length
    assert res.seqlen_history[-1] == 128
    assert res.seqlen_history[0] <= 16


@pytest.mark.slow
def test_token_accounting_truncate_vs_repack():
    r_trunc = train(_tc(steps=20, mode="truncate"), quiet=True)
    r_pack = train(_tc(steps=20, mode="repack"), quiet=True)
    assert r_pack.tokens > r_trunc.tokens  # repack drops nothing


@pytest.mark.slow
def test_checkpoint_resume_exact(tmp_path):
    d = str(tmp_path / "ck")
    tc = _tc(steps=30, ckpt_dir=d)
    full = train(tc, quiet=True)
    # restart from step-20 checkpoint and finish
    tc2 = _tc(steps=30, ckpt_dir=d)
    part = train(tc2, resume=True, quiet=True)
    assert part.restored_from_step == 30  # the final checkpoint
    assert part.steps == 30  # nothing left to do


@pytest.mark.slow
def test_resume_mid_run_continues_schedule(tmp_path):
    d = str(tmp_path / "ck")
    tc = _tc(steps=18, ckpt_dir=d)
    r1 = train(tc, quiet=True)  # checkpoints at 10 and at end (18)
    tc_more = _tc(steps=36, ckpt_dir=d)
    r2 = train(tc_more, resume=True, quiet=True)
    assert r2.restored_from_step == 18
    assert r2.steps == 36
    # curriculum resumed, not restarted: first seqlen after resume >= before
    assert r2.seqlen_history[0] >= r1.seqlen_history[-1]


@pytest.mark.slow
def test_supervisor_recovers_from_injected_failure(tmp_path):
    d = str(tmp_path / "ck")
    sup = TrainSupervisor(max_restarts=2)

    def run(resume: bool) -> str:
        res = train(_tc(steps=25, ckpt_dir=d), resume=resume,
                    fail_at_step=None if resume else 15, quiet=True)
        return f"ok:{res.steps}"

    out = sup.run(run)
    assert out == "ok:25"
    assert sup.restarts == 1


def test_drain_checkpoints_and_exits(tmp_path):
    d = str(tmp_path / "ck")
    drain = DrainSignal(install=False)
    calls = {"n": 0}

    def cb(step, metrics):
        calls["n"] += 1
        if step == 7:
            drain.trigger()

    res = train(_tc(steps=100, ckpt_dir=d), drain=drain, callback=cb,
                quiet=True)
    assert res.drained
    assert res.steps == 8
    from repro.checkpoint import latest_step
    assert latest_step(d) == 8  # checkpointed on the way out


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(window=16, factor=2.0)
    import time
    for i in range(12):
        wd.start()
        if i == 10:
            time.sleep(0.05)
        else:
            time.sleep(0.001)
        wd.stop()
    assert len(wd.straggler_steps) >= 1
    assert wd.summary()["stragglers"] >= 1


@pytest.mark.slow
def test_variance_gated_pacing_runs():
    res = train(_tc(steps=20, pacing="variance_gated"), quiet=True)
    assert res.steps == 20
    assert not res.diverged


def test_divergence_detection():
    """Absurd LR must trip the NaN/divergence path, like the paper's 40x-LR
    baseline (Fig. 5)."""
    res = train(_tc(steps=40, slw=False, lr=80.0), quiet=True,
                stop_on_nan=True)
    assert res.diverged or res.tracker_summary["max_loss_ratio"] > 2.0
