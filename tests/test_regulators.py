"""The composable regulator control plane: composition semantics, the
adaptive beyond-paper regulators, and the unified ControllerState."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import (BatchWarmupConfig, OptimizerConfig,
                                RegulatorSpec, SLWConfig, TrainConfig)
from repro.configs import get_arch, reduced
from repro.core import pacing
from repro.core.batch_warmup import BatchWarmup
from repro.core.regulators import (ControllerState, GradNoiseBatchRegulator,
                                   StepPlan, StepTelemetry,
                                   VarianceLRThrottle, auto_specs,
                                   build_stack, predict_trajectory)
from repro.optim import lr_at


def _tc(slw=True, batch_warmup=True, steps=40, seq=128, batch=8,
        regulators=()):
    cfg = reduced(get_arch("gpt2-117m").model).replace(vocab_size=256)
    return TrainConfig(
        model=cfg,
        optimizer=OptimizerConfig(
            lr=2e-3, min_lr=1e-5, schedule="token_cosine", warmup_steps=8,
            warmup_tokens=8 * batch * seq, total_steps=steps,
            total_tokens=steps * batch * seq),
        slw=SLWConfig(enabled=slw, start_seq_len=8, duration_steps=steps // 2,
                      round_multiple=8, max_buckets=5),
        batch_warmup=BatchWarmupConfig(enabled=batch_warmup, start_batch=2,
                                       warmup_tokens=steps * batch * seq // 4),
        regulators=regulators,
        seq_len=seq, global_batch=batch, remat="none", eval_interval=0)


# ---------------------------------------------------------------------------
# stack construction + composition
# ---------------------------------------------------------------------------

def test_auto_specs_compose_legacy_configs():
    kinds = [s.kind for s in auto_specs(_tc(slw=True, batch_warmup=True))]
    assert kinds == ["seqlen", "batch_warmup", "lr"]
    kinds = [s.kind for s in auto_specs(_tc(slw=False, batch_warmup=False))]
    assert kinds == ["lr"]


def test_explicit_specs_override_auto():
    tc = _tc(slw=True, batch_warmup=True,
             regulators=(RegulatorSpec(kind="lr"),))
    stack = build_stack(tc)
    assert "seqlen" not in stack and "lr" in stack


def test_unknown_kind_raises():
    tc = _tc(regulators=(RegulatorSpec(kind="nope"),))
    with pytest.raises(ValueError, match="unknown regulator"):
        build_stack(tc)


def test_composed_plan_matches_individual_schedules():
    """The stack's joint plan == each schedule computed standalone."""
    tc = _tc(slw=True, batch_warmup=True)
    stack = build_stack(tc, warmup_steps_hint=tc.optimizer.warmup_steps)
    bw = BatchWarmup(tc.batch_warmup, tc.global_batch)
    ladder = pacing.bucket_ladder(tc.slw, tc.seq_len)
    tokens = 0
    for step in range(30):
        plan = stack.plan(StepTelemetry(step=step, tokens_seen=tokens))
        assert plan.seq_len == pacing.seqlen_at(
            tc.slw, step, tc.seq_len, tc.optimizer.warmup_steps, ladder)
        assert plan.batch_size == bw.batch_for_tokens(tokens)
        assert plan.lr == pytest.approx(lr_at(tc.optimizer, step, tokens))
        t_step = plan.seq_len * plan.batch_size
        stack.observe(StepTelemetry(step=step, tokens_seen=tokens), t_step)
        tokens += t_step


def test_stack_apply_slices_batch_then_seq():
    tc = _tc()
    stack = build_stack(tc)
    b, s = 8, 128
    batch = {"tokens": np.arange(b * s, dtype=np.int32).reshape(b, s)}
    out, tokens = stack.apply(batch, StepPlan(seq_len=16, batch_size=4,
                                              lr=1e-3))
    assert out["tokens"].shape == (4, 16)
    assert tokens == 4 * 16


def test_predict_trajectory_is_open_loop_replay():
    tc = _tc()
    plans = predict_trajectory(tc, 60,
                               warmup_steps_hint=tc.optimizer.warmup_steps)
    assert len(plans) == 60
    # monotone warmup on both axes, reaching the full shape
    seqs = [p.seq_len for p in plans]
    batches = [p.batch_size for p in plans]
    assert seqs == sorted(seqs) and batches == sorted(batches)
    assert seqs[0] == 8 and seqs[-1] == tc.seq_len
    assert batches[-1] == tc.global_batch


def test_predict_trajectory_variance_gated_reaches_full():
    """Open-loop replay feeds calm telemetry, so the variance gate advances
    along its calm-run envelope instead of pinning the smallest bucket."""
    tc = _tc(batch_warmup=False)
    tc = dataclasses.replace(
        tc, slw=dataclasses.replace(tc.slw, pacing="variance_gated"))
    plans = predict_trajectory(tc, 60,
                               warmup_steps_hint=tc.optimizer.warmup_steps)
    seqs = [p.seq_len for p in plans]
    assert seqs == sorted(seqs)
    assert seqs[-1] == tc.seq_len  # gate advanced to full length


# ---------------------------------------------------------------------------
# dp_size quantization (the paper's §5.1 structural constraint)
# ---------------------------------------------------------------------------

def test_dp_size_wired_into_batch_warmup():
    tc = _tc(slw=False, batch_warmup=True, batch=32)
    tc = dataclasses.replace(
        tc, batch_warmup=BatchWarmupConfig(enabled=True, start_batch=4,
                                           warmup_tokens=10_000))
    stack = build_stack(tc, dp_size=8)
    assert stack["batch_warmup"].warmup.dp_size == 8
    for tokens in (0, 2_000, 5_000, 9_000, 50_000):
        plan = stack.plan(StepTelemetry(step=0, tokens_seen=tokens))
        assert plan.batch_size % 8 == 0


# ---------------------------------------------------------------------------
# adaptive regulators (beyond-paper scenario clients)
# ---------------------------------------------------------------------------

def test_grad_noise_batch_grows_only_under_noise():
    spec = RegulatorSpec(kind="grad_noise_batch", min_batch=4,
                         noise_window=4, noise_target=0.2, growth=2.0)
    reg = GradNoiseBatchRegulator(spec, full_batch=64, dp_size=4)
    assert reg.batch == 4
    # calm gradients: batch must hold
    for i in range(20):
        reg.observe(StepTelemetry(step=i, grad_norm=1.0), 0)
    assert reg.batch == 4
    # noisy gradients: batch grows, stays a dp multiple, caps at full
    for i in range(40):
        reg.observe(StepTelemetry(step=i, grad_norm=1.0 if i % 2 else 8.0), 0)
    assert reg.batch > 4
    assert reg.batch % 4 == 0
    assert reg.batch <= 64
    # NaN grad norms are ignored, not folded into the EMAs
    before = (reg.ema_g, reg.n_obs)
    reg.observe(StepTelemetry(step=99, grad_norm=float("nan")), 0)
    assert (reg.ema_g, reg.n_obs) == before


def test_var_lr_throttle_backs_off_and_recovers():
    spec = RegulatorSpec(kind="var_lr_throttle", gate=2.0, floor=0.1,
                         backoff=0.5, recovery=1.5)
    reg = VarianceLRThrottle(spec)
    plan = reg.plan(StepTelemetry(), StepPlan(seq_len=8, batch_size=8, lr=1.0))
    assert plan.lr == 1.0 and plan.grad_clip_scale == 1.0
    reg.observe(StepTelemetry(var_max=1.0), 0)  # seeds trailing
    reg.observe(StepTelemetry(var_max=100.0), 0)  # spike -> backoff
    assert reg.scale == 0.5
    for i in range(20):  # escalating spikes: floor holds
        reg.observe(StepTelemetry(var_max=100.0 * 10.0 ** i), 0)
    assert reg.scale == pytest.approx(0.1)
    trailing = reg.trailing
    for _ in range(50):  # calm again: full recovery, capped at 1
        reg.observe(StepTelemetry(var_max=trailing), 0)
    assert reg.scale == 1.0
    plan = reg.plan(StepTelemetry(), StepPlan(seq_len=8, batch_size=8, lr=2.0))
    assert plan.lr == 2.0


def test_throttle_multiplies_scheduled_lr_in_stack():
    tc = _tc(slw=False, batch_warmup=False,
             regulators=(RegulatorSpec(kind="lr"),
                         RegulatorSpec(kind="var_lr_throttle", backoff=0.5)))
    stack = build_stack(tc)
    stack["var_lr_throttle"].scale = 0.5
    tele = StepTelemetry(step=100, tokens_seen=10**6)
    plan = stack.plan(tele)
    assert plan.lr == pytest.approx(
        0.5 * lr_at(tc.optimizer, 100, 10**6))
    assert plan.grad_clip_scale == 0.5


# ---------------------------------------------------------------------------
# unified controller state
# ---------------------------------------------------------------------------

def test_controller_state_roundtrip_all_regulators():
    tc = _tc(regulators=(RegulatorSpec(kind="seqlen"),
                         RegulatorSpec(kind="batch_warmup"),
                         RegulatorSpec(kind="lr"),
                         RegulatorSpec(kind="grad_noise_batch"),
                         RegulatorSpec(kind="var_lr_throttle")))
    stack = build_stack(tc)
    # advance everything off its initial state
    for step in range(12):
        tele = StepTelemetry(step=step, tokens_seen=step * 256,
                             grad_norm=1.0 if step % 2 else 5.0,
                             var_max=1.0 if step % 3 else 50.0)
        stack.observe(tele, 256)
    cs = stack.controller_state(12, 12 * 256, {"min_loss": 3.0})
    # through the (JSON-able) host dict, like the checkpoint does
    import json
    cs2 = ControllerState.from_host(json.loads(json.dumps(cs.to_host())))
    stack2 = build_stack(tc)
    stack2.load_controller_state(cs2)
    assert cs2.step == 12 and cs2.tokens_seen == 12 * 256
    for name in ("seqlen", "batch_warmup", "lr", "grad_noise_batch",
                 "var_lr_throttle"):
        assert stack2[name].state_dict() == stack[name].state_dict()
    # the restored stack plans identically
    tele = StepTelemetry(step=12, tokens_seen=12 * 256)
    p1, p2 = stack.plan(tele), stack2.plan(tele)
    assert (p1.seq_len, p1.batch_size, p1.lr) == \
        (p2.seq_len, p2.batch_size, p2.lr)


def test_duplicate_regulator_names_rejected():
    tc = _tc(regulators=(RegulatorSpec(kind="lr"), RegulatorSpec(kind="lr")))
    with pytest.raises(ValueError, match="duplicate"):
        build_stack(tc)


def test_legacy_host_state_migrates():
    from repro.checkpoint import migrate_host_state
    legacy = {"step": 7, "tokens_seen": 4096,
              "curriculum": {"step": 7, "tokens_seen": 4096, "gate_level": 2,
                             "var_trailing": 0.5},
              "tracker": {"min_loss": 2.5}}
    host = migrate_host_state(legacy)
    cs = ControllerState.from_host(host["controller"])
    assert cs.step == 7 and cs.tokens_seen == 4096
    assert cs.regulators["seqlen"]["gate_level"] == 2
    assert cs.tracker["min_loss"] == 2.5
    # new-format dicts pass through untouched
    assert migrate_host_state(host) is host


def test_grad_noise_batch_reads_raw_preclip_norm():
    """Regression for the pre-clip contract: under persistent clipping the
    post-clip norm saturates at the limit (relative std ~0), which would
    permanently starve the growth trigger.  The regulator must consume the
    raw `grad_norm`, not `grad_norm_clipped`."""
    spec = RegulatorSpec(kind="grad_noise_batch", min_batch=4,
                         noise_window=4, noise_target=0.2, growth=2.0)
    reg = GradNoiseBatchRegulator(spec, full_batch=64, dp_size=4)
    for i in range(40):
        # every step clips: the clipped norm is pinned at the limit while
        # the raw norm is noisy — exactly the signal being regulated on
        reg.observe(StepTelemetry(step=i,
                                  grad_norm=1.0 if i % 2 else 8.0,
                                  grad_norm_clipped=1.0), 0)
    assert reg.batch > 4, \
        "regulator starved by the saturated post-clip norm"
