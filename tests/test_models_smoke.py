"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER, get_arch, reduced
from repro.configs.base import OptimizerConfig
from repro.launch import steps as steps_lib
from repro.models import build_model, init_params, make_train_batch
from repro.models.layers import round_up

# the slowest reduced configs (hybrid scan, the larger MoE, and the two
# frontend-stub archs whose dense path five other archs already cover) run
# in the slow tier; every family keeps a fast-tier representative
# (dense: smollm/qwen2/qwen3/phi3/gpt2*, moe: moonshot, rwkv: rwkv6)
_SLOW_ARCHS = {"zamba2-2.7b", "deepseek-moe-16b", "llava-next-mistral-7b",
               "musicgen-large"}


def _tiered(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
            for a in archs]


ALL_ARCHS = sorted(ASSIGNED) + sorted(PAPER)


@pytest.mark.parametrize("arch", _tiered(ALL_ARCHS))
def test_forward_and_train_step(arch):
    cfg = reduced(get_arch(arch).model)
    model = build_model(cfg, dtype=jnp.float32, remat="none")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, 2, 64, jnp.float32)

    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    # random-init loss should be near ln(vocab)
    assert abs(float(metrics["loss"]) - np.log(cfg.vocab_size)) < 1.5

    step_fn = jax.jit(steps_lib.make_train_step(model, OptimizerConfig(lr=1e-3)))
    state = steps_lib.init_train_state(jax.random.PRNGKey(0), cfg)
    new_state, out = step_fn(state, batch, np.float32(1e-3))
    assert np.isfinite(float(out["loss"]))
    assert np.isfinite(float(out["grad_norm"]))
    assert np.isfinite(float(out["var_max"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                        jax.tree_util.tree_leaves(new_state["params"])))
    assert moved, arch


@pytest.mark.parametrize("arch", _tiered(sorted(ASSIGNED)))
def test_serving_shapes(arch):
    cfg = reduced(get_arch(arch).model)
    model = build_model(cfg, dtype=jnp.float32, remat="none")
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, prompt, cache_len = 2, 16, 32
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, b, prompt,
                             jnp.float32)
    batch.pop("labels")
    logits, cache = model.prefill(params, batch, cache_len=cache_len)
    pv = round_up(cfg.vocab_size, 128)
    assert logits.shape == (b, pv)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode(params, cache, tok)
    assert logits2.shape == (b, pv)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    c = get_arch("zamba2-2.7b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size,
            c.ssm_state) == (54, 2560, 32, 10240, 32000, 64)
    c = get_arch("smollm-360m").model
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 960, 15, 5, 2560, 49152)
    c = get_arch("phi3-mini-3.8b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == \
        (32, 3072, 32, 8192, 32064)
    c = get_arch("qwen3-32b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.qk_norm) == (64, 5120, 64, 8, 25600, 151936, True)
    c = get_arch("qwen2-1.5b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.qkv_bias) == (28, 1536, 12, 2, 8960, 151936, True)
    c = get_arch("rwkv6-7b").model
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (32, 4096, 14336, 65536)
    c = get_arch("moonshot-v1-16b-a3b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size,
            c.n_experts, c.top_k) == (48, 2048, 16, 1408, 163840, 64, 6)
    c = get_arch("deepseek-moe-16b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size,
            c.n_experts, c.top_k, c.n_shared_experts) == \
        (28, 2048, 16, 1408, 102400, 64, 6, 2)
    c = get_arch("musicgen-large").model
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == \
        (48, 2048, 32, 8192, 2048)
    c = get_arch("llava-next-mistral-7b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4096, 32, 8, 14336, 32000)


def test_param_counts_in_published_ballpark():
    """Full configs should land near their nameplate parameter counts."""
    from repro.models import param_count
    expectations = {
        "smollm-360m": (0.30e9, 0.45e9),
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "qwen3-32b": (28e9, 36e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "rwkv6-7b": (6e9, 8.5e9),
        "deepseek-moe-16b": (14e9, 19e9),
        # the assigned config (48L x 64e x d_ff 1408) arithmetically gives
        # ~29B total / ~4.8B active; the "16b-a3b" label tracks the hf name,
        # the numbers here follow the assignment block exactly
        "moonshot-v1-16b-a3b": (25e9, 33e9),
        "zamba2-2.7b": (2.2e9, 3.4e9),
        "musicgen-large": (1.8e9, 2.9e9),
        "llava-next-mistral-7b": (6.4e9, 8e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = param_count(get_arch(arch).model)
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
