"""AdamW vs a hand-rolled reference; clipping; compression error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.optim import (adamw_update, clip_by_global_norm, ef_compress_tree,
                         global_norm, init_opt_state)


def test_adamw_matches_reference():
    cfg = OptimizerConfig(lr=1e-2, beta1=0.9, beta2=0.999, eps=1e-8,
                          weight_decay=0.01)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = init_opt_state(p)
    new_p, new_st, tel = adamw_update(p, g, st, jnp.float32(cfg.lr), cfg)

    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.001 * np.array([0.1, 0.2, -0.3]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    expect = (np.array([1.0, -2.0, 3.0])
              - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8)
                        + 0.01 * np.array([1.0, -2.0, 3.0])))
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-6)
    assert int(new_st["count"]) == 1
    assert float(tel["var_max"]) == pytest.approx(np.sqrt(v).max(), rel=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0)
    # below threshold: untouched
    g2 = {"a": jnp.array([0.3])}
    c2, n2 = clip_by_global_norm(g2, 1.0)
    assert float(c2["a"][0]) == pytest.approx(0.3)


def test_compression_error_feedback_accumulates():
    """sign+scale compression: the residue must be carried, so the *sum* of
    communicated values converges to the true sum over steps."""
    g = {"w": jnp.array([0.5, -0.01, 0.02, -0.8])}
    err = {"w": jnp.zeros(4)}
    sent = np.zeros(4)
    for _ in range(50):
        comp, decomp, err = ef_compress_tree(g, err)
        sent += np.asarray(decomp["w"])
    # EF bounds the accumulated error; sign+scale has a small persistent
    # bias for heterogeneous magnitudes — the average converges to within
    # ~scale/#steps-ish, not exactly
    np.testing.assert_allclose(sent / 50, np.asarray(g["w"]), atol=0.05)
