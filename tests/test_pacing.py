"""Property tests for the pacing functions.

Runs under hypothesis when it is installed; otherwise falls back to a
deterministic built-in case sweep over the same config domains (this
container does not ship hypothesis, and the invariants are cheap enough
to check on a few hundred sampled configs either way).
"""
import random

import pytest

from repro.configs.base import SLWConfig
from repro.core import pacing

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

FULLS = [256, 1024, 2048, 4096, 32768]
# 12 and 100 are deliberately not multiples of the rounding values: the
# ladder must keep the rounded-down arithmetic anchor below them
STARTS = [4, 8, 12, 16, 64, 100]
PACINGS = ["linear", "root", "two_stage"]
ROUNDS = [8, 128]


def _builtin_cases(n=96, seed=0):
    """Deterministic stand-in for the hypothesis strategy."""
    rng = random.Random(seed)
    cases = []
    for _ in range(n):
        full = rng.choice(FULLS)
        s0 = rng.choice(STARTS)
        cfg = SLWConfig(
            enabled=True,
            pacing=rng.choice(PACINGS),
            start_seq_len=min(s0, full),
            duration_steps=rng.randint(1, 50_000),
            round_multiple=rng.choice(ROUNDS),
            max_buckets=rng.randint(4, 64),
        )
        cases.append((cfg, full))
    return cases


# ---------------------------------------------------------------------------
# invariant bodies (shared by both drivers)
# ---------------------------------------------------------------------------

def _check_ladder_invariants(cfg, full):
    ladder = pacing.bucket_ladder(cfg, full)
    assert len(ladder) <= cfg.max_buckets + 8  # geometric prefix allowance
    assert ladder == tuple(sorted(set(ladder)))
    # round-*down* semantics: the smallest bucket never exceeds the
    # configured start, and sits no further below it than one multiple
    s0 = min(cfg.start_seq_len, full)
    floor = s0 if s0 < cfg.round_multiple else s0 - s0 % cfg.round_multiple
    assert floor <= ladder[0] <= s0
    assert ladder[-1] == full


def _check_seqlen_bounds(cfg, full, step):
    s = pacing.seqlen_at(cfg, step, full)
    assert cfg.start_seq_len <= s + cfg.round_multiple  # never far below s0
    assert s <= full


def _check_monotone_nondecreasing(cfg, full):
    if cfg.pacing == "two_stage":
        return  # discrete jump is monotone by construction, tested below
    ladder = pacing.bucket_ladder(cfg, full)
    prev = 0
    for t in range(0, cfg.duration_steps + 10,
                   max(cfg.duration_steps // 50, 1)):
        s = pacing.seqlen_at(cfg, t, full, ladder=ladder)
        assert s >= prev
        prev = s


def _check_reaches_full_length(cfg, full):
    assert pacing.seqlen_at(cfg, cfg.duration_steps + 1, full) == full


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @st.composite
    def slw_configs(draw):
        full = draw(st.sampled_from(FULLS))
        s0 = draw(st.sampled_from(STARTS))
        return SLWConfig(
            enabled=True,
            pacing=draw(st.sampled_from(PACINGS)),
            start_seq_len=min(s0, full),
            duration_steps=draw(st.integers(1, 50_000)),
            round_multiple=draw(st.sampled_from(ROUNDS)),
            max_buckets=draw(st.integers(4, 64)),
        ), full

    @given(slw_configs())
    @settings(max_examples=200, deadline=None)
    def test_ladder_invariants(cfg_full):
        _check_ladder_invariants(*cfg_full)

    @given(slw_configs(), st.integers(0, 100_000))
    @settings(max_examples=200, deadline=None)
    def test_seqlen_bounds(cfg_full, step):
        _check_seqlen_bounds(cfg_full[0], cfg_full[1], step)

    @given(slw_configs())
    @settings(max_examples=100, deadline=None)
    def test_monotone_nondecreasing(cfg_full):
        _check_monotone_nondecreasing(*cfg_full)

    @given(slw_configs())
    @settings(max_examples=100, deadline=None)
    def test_reaches_full_length_after_duration(cfg_full):
        _check_reaches_full_length(*cfg_full)

else:
    CASES = _builtin_cases()

    def test_ladder_invariants():
        for cfg, full in CASES:
            _check_ladder_invariants(cfg, full)

    def test_seqlen_bounds():
        rng = random.Random(1)
        for cfg, full in CASES:
            for step in (0, 1, cfg.duration_steps // 2, cfg.duration_steps,
                         cfg.duration_steps + 1, rng.randint(0, 100_000)):
                _check_seqlen_bounds(cfg, full, step)

    def test_monotone_nondecreasing():
        for cfg, full in CASES[:48]:
            _check_monotone_nondecreasing(cfg, full)

    def test_reaches_full_length_after_duration():
        for cfg, full in CASES:
            _check_reaches_full_length(cfg, full)


# ---------------------------------------------------------------------------
# exact-value tests (no property machinery)
# ---------------------------------------------------------------------------

def test_paper_linear_formula_exact():
    """seqlen_t = s0 + (s1-s0)*min(t/T,1), rounded down to the ladder."""
    cfg = SLWConfig(start_seq_len=8, duration_steps=100, round_multiple=8,
                    max_buckets=10_000)  # ladder fine enough to be exact-ish
    raw = pacing.raw_seqlen(cfg, 50, 1024)
    assert raw == pytest.approx(8 + (1024 - 8) * 0.5)
    s = pacing.seqlen_at(cfg, 50, 1024)
    assert s <= raw < s + 8 + 1  # round-down semantics


def test_non_multiple_start_keeps_rounded_anchor():
    """start_seq_len=12 with round_multiple=8: the ladder keeps the
    rounded-down anchor (8), so the earliest warmup steps never run
    *longer* than configured (the old filter deleted it, making the
    smallest bucket 16)."""
    cfg = SLWConfig(start_seq_len=12, duration_steps=100, round_multiple=8,
                    max_buckets=16)
    ladder = pacing.bucket_ladder(cfg, 256)
    assert ladder[0] == 8
    assert pacing.seqlen_at(cfg, 0, 256) <= 12


def test_two_stage_is_shortformer():
    cfg = SLWConfig(pacing="two_stage", two_stage_short_len=128,
                    duration_steps=1000)
    assert pacing.raw_seqlen(cfg, 999, 1024) == 128
    assert pacing.raw_seqlen(cfg, 1000, 1024) == 1024


def test_disabled_is_constant():
    cfg = SLWConfig(enabled=False)
    for t in (0, 10, 10_000):
        assert pacing.seqlen_at(cfg, t, 2048) == 2048
