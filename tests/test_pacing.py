"""Property tests for the pacing functions (hypothesis)."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.configs.base import SLWConfig
from repro.core import pacing


@st.composite
def slw_configs(draw):
    full = draw(st.sampled_from([256, 1024, 2048, 4096, 32768]))
    s0 = draw(st.sampled_from([4, 8, 16, 64]))
    return SLWConfig(
        enabled=True,
        pacing=draw(st.sampled_from(["linear", "root", "two_stage"])),
        start_seq_len=min(s0, full),
        duration_steps=draw(st.integers(1, 50_000)),
        round_multiple=draw(st.sampled_from([8, 128])),
        max_buckets=draw(st.integers(4, 64)),
    ), full


@given(slw_configs())
@settings(max_examples=200, deadline=None)
def test_ladder_invariants(cfg_full):
    cfg, full = cfg_full
    ladder = pacing.bucket_ladder(cfg, full)
    assert len(ladder) <= cfg.max_buckets + 8  # geometric prefix allowance
    assert ladder == tuple(sorted(set(ladder)))
    assert ladder[0] >= min(cfg.start_seq_len, full)
    assert ladder[-1] == full


@given(slw_configs(), st.integers(0, 100_000))
@settings(max_examples=200, deadline=None)
def test_seqlen_bounds(cfg_full, step):
    cfg, full = cfg_full
    s = pacing.seqlen_at(cfg, step, full)
    assert cfg.start_seq_len <= s + cfg.round_multiple  # never far below s0
    assert s <= full


@given(slw_configs())
@settings(max_examples=100, deadline=None)
def test_monotone_nondecreasing(cfg_full):
    cfg, full = cfg_full
    if cfg.pacing == "two_stage":
        return  # discrete jump is monotone by construction, tested below
    ladder = pacing.bucket_ladder(cfg, full)
    prev = 0
    for t in range(0, cfg.duration_steps + 10,
                   max(cfg.duration_steps // 50, 1)):
        s = pacing.seqlen_at(cfg, t, full, ladder=ladder)
        assert s >= prev
        prev = s


@given(slw_configs())
@settings(max_examples=100, deadline=None)
def test_reaches_full_length_after_duration(cfg_full):
    cfg, full = cfg_full
    assert pacing.seqlen_at(cfg, cfg.duration_steps + 1, full) == full


def test_paper_linear_formula_exact():
    """seqlen_t = s0 + (s1-s0)*min(t/T,1), rounded down to the ladder."""
    cfg = SLWConfig(start_seq_len=8, duration_steps=100, round_multiple=8,
                    max_buckets=10_000)  # ladder fine enough to be exact-ish
    raw = pacing.raw_seqlen(cfg, 50, 1024)
    assert raw == pytest.approx(8 + (1024 - 8) * 0.5)
    s = pacing.seqlen_at(cfg, 50, 1024)
    assert s <= raw < s + 8 + 1  # round-down semantics


def test_two_stage_is_shortformer():
    cfg = SLWConfig(pacing="two_stage", two_stage_short_len=128,
                    duration_steps=1000)
    assert pacing.raw_seqlen(cfg, 999, 1024) == 128
    assert pacing.raw_seqlen(cfg, 1000, 1024) == 1024


def test_disabled_is_constant():
    cfg = SLWConfig(enabled=False)
    for t in (0, 10, 10_000):
        assert pacing.seqlen_at(cfg, t, 2048) == 2048
