"""Loss-ratio tracker, variance telemetry, Pearson correlation (Table 3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LossRatioTracker, pearson, variance_stats
from repro.core.stability import momentum_stats


def test_loss_ratio_counts_spikes():
    tr = LossRatioTracker(spike_threshold=1.2)
    for loss in [5.0, 4.0, 3.0, 4.5, 2.9, 3.2]:
        tr.update(loss)
    s = tr.summary()
    # 4.5/3.0 = 1.5 spike; 3.2/2.9 = 1.10 not a spike
    assert s["spikes"] == 1
    assert s["max_loss_ratio"] == pytest.approx(1.5)
    assert s["steps"] == 6


def test_loss_ratio_state_roundtrip():
    tr = LossRatioTracker()
    for loss in [5.0, 4.0, 6.0]:
        tr.update(loss)
    tr2 = LossRatioTracker()
    tr2.load_state_dict(tr.state_dict())
    assert tr2.min_loss == tr.min_loss
    assert tr2.summary()["spikes"] == tr.summary()["spikes"]


def test_variance_stats_match_manual():
    v = {"a": jnp.array([4.0, 9.0]), "b": jnp.array([[16.0]])}
    s = variance_stats(v)
    assert float(s["var_l1"]) == pytest.approx(2 + 3 + 4)
    assert float(s["var_max"]) == pytest.approx(4.0)
    m = momentum_stats({"a": jnp.array([-1.0, 2.0])})
    assert float(m["mom_l1"]) == pytest.approx(3.0)


def test_pearson_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.normal(size=500)
    y = 0.3 * x + rng.normal(size=500)
    r, p = pearson(x, y)
    assert r == pytest.approx(np.corrcoef(x, y)[0, 1], abs=1e-6)
    assert p < 1e-6  # strongly significant


def test_pearson_null():
    rng = np.random.default_rng(1)
    x = rng.normal(size=200)
    y = rng.normal(size=200)
    r, p = pearson(x, y)
    assert abs(r) < 0.2
    assert p > 0.01
