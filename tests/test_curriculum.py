"""SLW curriculum controller: truncate/repack transforms + accounting."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import SLWConfig
from repro.core import SLWCurriculum, apply_seqlen
from repro.core.batch_warmup import BatchWarmup
from repro.configs.base import BatchWarmupConfig


def _batch(b=4, s=256):
    x = np.arange(b * s, dtype=np.int32).reshape(b, s)
    return {"tokens": x, "labels": x + 1}


def test_truncate_keeps_prefix():
    cfg = SLWConfig(start_seq_len=8, duration_steps=100, round_multiple=8)
    cur = SLWCurriculum(cfg, 256)
    out, tokens = cur.apply(_batch(), seqlen=64)
    assert out["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(out["tokens"], _batch()["tokens"][:, :64])
    assert tokens == 4 * 64


def test_repack_conserves_tokens():
    cfg = SLWConfig(start_seq_len=8, duration_steps=100, mode="repack")
    cur = SLWCurriculum(cfg, 256)
    out, tokens = cur.apply(_batch(), seqlen=64)
    assert out["tokens"].shape == (16, 64)  # 4 * 256//64
    assert tokens == 4 * 256  # nothing dropped
    # data preserved in order
    np.testing.assert_array_equal(out["tokens"].reshape(4, 256),
                                  _batch()["tokens"])


def test_full_length_is_identity():
    cfg = SLWConfig(start_seq_len=8, duration_steps=10)
    cur = SLWCurriculum(cfg, 256)
    cur.state.step = 10_000
    out, tokens = cur.apply(_batch())
    assert out["tokens"].shape == (4, 256)


def test_vision_prefix_not_truncated():
    cfg = SLWConfig(start_seq_len=8, duration_steps=100)
    cur = SLWCurriculum(cfg, 256, prefix_tokens=16)
    batch = dict(_batch(), patch_embeds=np.zeros((4, 16, 32), np.float32))
    out, tokens = cur.apply(batch, seqlen=64)
    assert out["patch_embeds"].shape == (4, 16, 32)  # untouched
    assert out["tokens"].shape == (4, 64)
    assert tokens == 4 * 64 + 4 * 16  # text + prefix tokens both counted


def test_token_accounting_and_state_roundtrip():
    cfg = SLWConfig(start_seq_len=8, duration_steps=100)
    cur = SLWCurriculum(cfg, 256)
    for _ in range(5):
        _, tokens = cur.apply(_batch())
        cur.step_complete(tokens)
    saved = cur.state_dict()
    cur2 = SLWCurriculum(cfg, 256)
    cur2.load_state_dict(saved)
    assert cur2.state.step == 5
    assert cur2.seqlen_for_step() == cur.seqlen_for_step()


def test_variance_gate_blocks_advance():
    cfg = SLWConfig(start_seq_len=8, duration_steps=10,
                    pacing="variance_gated", variance_gate=1.5)
    cur = SLWCurriculum(cfg, 256)
    lo = cur.seqlen_for_step()
    # spiking variance: gate should hold the level down
    for _ in range(20):
        cur.observe(1e9 * (1 + cur.state.step))
        cur.step_complete(32)
    held = cur.state.gate_level
    cur2 = SLWCurriculum(cfg, 256)
    for _ in range(20):
        cur2.observe(1.0)  # calm variance: advances every step
        cur2.step_complete(32)
    assert cur2.state.gate_level > held
    assert lo <= cur2.seqlen_for_step()


def test_apply_seqlen_standalone_matches_curriculum():
    """The standalone transform (what the trainer executes per StepPlan) is
    the same function the curriculum object delegates to."""
    cfg = SLWConfig(start_seq_len=8, duration_steps=100, mode="repack")
    cur = SLWCurriculum(cfg, 256)
    via_cur, t1 = cur.apply(_batch(), seqlen=64)
    direct, t2 = apply_seqlen(_batch(), 64, mode="repack")
    assert t1 == t2
    np.testing.assert_array_equal(via_cur["tokens"], direct["tokens"])
    with pytest.raises(ValueError, match="unknown SLW mode"):
        apply_seqlen(_batch(), 64, mode="bogus")


def test_batch_warmup_multiple_of_dp():
    bw = BatchWarmup(BatchWarmupConfig(enabled=True, start_batch=4,
                                       warmup_tokens=1000),
                     full_batch=32, dp_size=8)
    batch = _batch(b=32, s=16)
    out, tokens = bw.apply(batch, tokens_seen=500)
    assert out["tokens"].shape[0] % 8 == 0  # the paper's §5.1 constraint
    assert out["tokens"].shape[0] < 32
    out_full, _ = bw.apply(batch, tokens_seen=10_000)
    assert out_full["tokens"].shape[0] == 32
