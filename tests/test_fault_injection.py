"""Fault injector semantics + the crash/corruption recovery drills.

The headline drill is the issue's crash-mid-checkpoint satellite: kill the
writer between the tmp write and the atomic rename, verify ``latest_step``
never sees the partial directory, and verify a resume restores the prior
step with regulator schedules bitwise identical to an uninterrupted run.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step
from repro.configs import get_arch, reduced
from repro.configs.base import OptimizerConfig, SLWConfig, TrainConfig
from repro.distributed.fault_injection import (FaultInjector, InjectedCrash,
                                               parse_faults)
from repro.launch.train import Trainer, train


def _tc(steps=12, seq=64, batch=4, ckpt_dir="", interval=4):
    cfg = reduced(get_arch("gpt2-117m").model).replace(vocab_size=128)
    return TrainConfig(
        model=cfg,
        optimizer=OptimizerConfig(
            lr=2e-3, min_lr=1e-5, schedule="token_cosine",
            warmup_steps=4, warmup_tokens=4 * batch * seq,
            total_steps=steps, total_tokens=steps * batch * seq),
        slw=SLWConfig(enabled=True, pacing="linear", start_seq_len=8,
                      duration_steps=steps // 2, round_multiple=8,
                      max_buckets=4),
        seq_len=seq, global_batch=batch, remat="none",
        eval_interval=0, checkpoint_interval=interval,
        checkpoint_dir=ckpt_dir)


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------

def test_parse_faults_roundtrip():
    specs = parse_faults("nan_grad@12, spike@20:8.0,crash@30:post_tmp,"
                         "stall@8:0.25")
    assert [s.kind for s in specs] == ["nan_grad", "spike", "crash", "stall"]
    assert [s.step for s in specs] == [12, 20, 30, 8]
    assert specs[1].arg == "8.0" and specs[2].arg == "post_tmp"
    # str() round-trips through the parser
    assert parse_faults(",".join(str(s) for s in specs)) == specs
    assert parse_faults("") == ()


@pytest.mark.parametrize("bad", [
    "bogus@3",            # unknown kind
    "nan_grad@x",         # malformed step
    "nan_grad",           # missing step
    "crash@5:mid_write",  # unknown crash point
])
def test_parse_faults_rejects(bad):
    with pytest.raises(ValueError):
        parse_faults(bad)


# ---------------------------------------------------------------------------
# deterministic placement + fire-once
# ---------------------------------------------------------------------------

def _toy_state():
    return {"params": {"w": jnp.ones((4, 8)), "b": jnp.ones(16),
                       "count": jnp.int32(3)},
            "opt": {"m": jnp.zeros(5)}}


def test_poison_params_is_seeded_and_minimal():
    a = FaultInjector(seed=7).poison_params(_toy_state(), step=12)
    b = FaultInjector(seed=7).poison_params(_toy_state(), step=12)
    mask_a = [np.isnan(np.asarray(x, np.float64)).ravel()
              for x in jax.tree_util.tree_leaves(a["params"])]
    mask_b = [np.isnan(np.asarray(x, np.float64)).ravel()
              for x in jax.tree_util.tree_leaves(b["params"])]
    assert sum(m.sum() for m in mask_a) == 1  # exactly one element
    for ma, mb in zip(mask_a, mask_b):
        np.testing.assert_array_equal(ma, mb)  # same element both times
    c = FaultInjector(seed=8).poison_params(_toy_state(), step=12)
    mask_c = np.concatenate([np.isnan(np.asarray(x, np.float64)).ravel()
                             for x in jax.tree_util.tree_leaves(c["params"])])
    assert mask_c.sum() == 1
    # int leaves are never poisoned
    assert int(a["params"]["count"]) == 3


def test_scale_params_touches_only_params():
    out = FaultInjector().scale_params(_toy_state(), step=3, factor=4.0)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  4.0 * np.ones((4, 8)))
    np.testing.assert_array_equal(np.asarray(out["opt"]["m"]), np.zeros(5))


def test_pre_step_fires_each_spec_once():
    class Dummy:
        step = 5
        state = _toy_state()

    inj = FaultInjector(parse_faults("spike@5:2.0"), seed=0)
    tr = Dummy()
    inj.pre_step(tr)
    assert inj.fired == ["spike@5:2.0"]
    w1 = np.asarray(tr.state["params"]["w"]).copy()
    inj.pre_step(tr)  # replayed step index after a rollback: no re-fire
    assert inj.fired == ["spike@5:2.0"]
    np.testing.assert_array_equal(np.asarray(tr.state["params"]["w"]), w1)


def test_maybe_crash_matches_point_and_step():
    inj = FaultInjector(parse_faults("crash@30:post_rename"))
    inj.maybe_crash("post_tmp", 30)     # wrong point: no-op
    inj.maybe_crash("post_rename", 29)  # wrong step: no-op
    with pytest.raises(InjectedCrash):
        inj.maybe_crash("post_rename", 30)
    inj.maybe_crash("post_rename", 30)  # fire-once


# ---------------------------------------------------------------------------
# crash-mid-checkpoint (the issue's satellite drill)
# ---------------------------------------------------------------------------

def test_crash_between_tmp_and_rename_resumes_exactly(tmp_path):
    d_clean = str(tmp_path / "clean")
    d_crash = str(tmp_path / "crash")
    clean = train(_tc(ckpt_dir=d_clean), quiet=True)
    assert clean.steps == 12

    inj = FaultInjector(parse_faults("crash@8:post_tmp"), seed=0)
    with pytest.raises(InjectedCrash):
        train(_tc(ckpt_dir=d_crash), quiet=True, fault_injector=inj)
    # the partial tmp dir is on disk but latest_step never trusts it
    assert os.path.isdir(os.path.join(d_crash, "tmp.8"))
    assert latest_step(d_crash) == 4

    res = train(_tc(ckpt_dir=d_crash), resume=True, quiet=True)
    assert res.restored_from_step == 4
    assert res.steps == 12
    # regulator schedules resume bitwise identically to the clean run
    assert res.seqlen_history == clean.seqlen_history[4:]
    assert res.batch_history == clean.batch_history[4:]
    assert res.lr_history == clean.lr_history[4:]
    np.testing.assert_array_equal(np.asarray(res.loss_history),
                                  np.asarray(clean.loss_history[4:]))


def test_crash_after_rename_leaves_valid_checkpoint(tmp_path):
    d = str(tmp_path / "ck")
    inj = FaultInjector(parse_faults("crash@8:post_rename"), seed=0)
    with pytest.raises(InjectedCrash):
        train(_tc(ckpt_dir=d), quiet=True, fault_injector=inj)
    # the rename completed: step 8 is valid and restorable
    assert latest_step(d) == 8
    tr = Trainer(_tc(ckpt_dir=d))
    assert tr.resume() == 8


def test_bitflip_quarantines_and_falls_back(tmp_path):
    d = str(tmp_path / "ck")
    res = train(_tc(ckpt_dir=d), quiet=True)
    assert res.steps == 12
    mgr_steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                       if n.startswith("step_"))
    assert mgr_steps == [4, 8, 12]  # keep=3

    inj = FaultInjector(seed=3)
    target = inj.corrupt_checkpoint(d)  # newest (12)
    assert "step_000000000012" in target
    assert any(f.startswith("bitflip@12") for f in inj.fired)

    tr = Trainer(_tc(ckpt_dir=d))
    assert tr.resume() == 8  # fell back past the corrupt newest
    assert [q[0] for q in tr.ckpt.quarantined] == [12]
    assert os.path.isdir(os.path.join(d, "corrupt.step_000000000012"))
    assert not os.path.isdir(os.path.join(d, "step_000000000012"))


def test_corrupt_checkpoint_requires_a_checkpoint(tmp_path):
    with pytest.raises(ValueError):
        FaultInjector().corrupt_checkpoint(str(tmp_path))
