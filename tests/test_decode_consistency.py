"""Serving correctness: stepwise decode == prefill-at-each-prefix oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model, init_params

# one dense GQA arch each keeps decode covered in the fast tier (smollm
# cached-decode, gpt2 learned-pos); the remaining archs run in the slow
# tier — test_models_smoke still covers every family's forward+train by
# default
FAMILIES = ["smollm-360m",
            pytest.param("qwen2-1.5b", marks=pytest.mark.slow),
            pytest.param("deepseek-moe-16b", marks=pytest.mark.slow),
            pytest.param("rwkv6-7b", marks=pytest.mark.slow),
            pytest.param("zamba2-2.7b", marks=pytest.mark.slow),
            "gpt2-117m"]


def _check_decode_matches_prefill(cfg):
    model = build_model(cfg, dtype=jnp.float32, remat="none")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 15), 0,
                                cfg.vocab_size)
    logits, cache = model.prefill(params, {"tokens": tokens[:, :7]},
                                  cache_len=24)
    outs = [logits]
    for i in range(7, 14):
        logits, cache = model.decode(params, cache, tokens[:, i:i + 1])
        outs.append(logits)
    dec = jnp.stack(outs, 1)
    oracle = jnp.stack(
        [model.prefill(params, {"tokens": tokens[:, :t]}, cache_len=24)[0]
         for t in range(7, 15)], 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(oracle),
                               atol=3e-3, rtol=3e-3)


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_prefill(arch):
    cfg = reduced(get_arch(arch).model)
    if cfg.family == "moe":
        # consistency holds modulo capacity drops: decode rows (s=1) never
        # drop, prefill rows can — compare with a drop-free capacity
        cfg = cfg.replace(capacity_factor=8.0)
    _check_decode_matches_prefill(cfg)


# prefill through the Pallas kernels (interpret mode) vs the O(1) pure-jnp
# decode step: the cross-backend serving consistency contract.  The 7-token
# prompt and 1..8-token oracle prefixes are all shorter than the reduced
# chunk sizes, so the kernels' uneven-tail padding path runs throughout.
KERNEL_BACKED = [("rwkv6-7b", {"rwkv_backend": "kernel_interpret"}),
                 ("zamba2-2.7b", {"ssm_backend": "kernel_interpret"})]


@pytest.mark.parametrize("arch,overrides",
                         [pytest.param(a, o, id=f"{a}-{list(o)[0]}")
                          for a, o in KERNEL_BACKED])
def test_decode_matches_kernel_prefill(arch, overrides):
    cfg = reduced(get_arch(arch).model).replace(**overrides)
    _check_decode_matches_prefill(cfg)
