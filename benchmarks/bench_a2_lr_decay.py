"""Appendix A.2: token-wise vs step-wise LR decay under SLW.

Step-wise cosine decays too fast in token space for SLW (fewer tokens per
warmup step) and hurts final quality; token-wise decay matches the baseline
schedule exactly.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, bench_config, final_ppl, run_arm


def run(quick: bool = False) -> List[Row]:
    steps = 80 if quick else 200
    rows = []
    for sched in ("token_cosine", "step_cosine"):
        name, res, wall = run_arm(
            f"a2/slw_{sched}",
            bench_config(slw=True, lr=2e-2, steps=steps,
                         duration=steps // 2, schedule=sched))
        rows.append((name, wall / max(res.steps, 1) * 1e6,
                     f"final_ppl={final_ppl(res):.2f} "
                     f"final_lr={res.lr_history[-1]:.2e}"))
    return rows
