"""Table 4 / Fig. 5-6: the aggressive GPT-3 recipe — 10% data budget,
8x batch, very large LR.

Paper: at 40x LR the batch-warmup baseline diverges unrecoverably; SLW
trains stably at 40x and retains 99% quality with 10x less data.  The
bench-scale analogue drives LR into the divergence regime and compares:
baseline(huge LR), batch-warmup(huge LR), SLW(huge LR), and a reduced-LR
baseline (the paper's 30x arm).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import (BATCH, SEQ, Row, bench_config, final_ppl,
                               run_arm, stability_row)

HUGE_LR = 2.0      # the "40x" analogue at bench scale (blow-up regime)
REDUCED_LR = 0.5   # the "30x" fallback (spiking but trainable)


def run(quick: bool = False) -> List[Row]:
    steps = 60 if quick else 150
    budget = steps * BATCH * SEQ // 10 * 3  # tight data budget
    rows: List[Row] = []
    arms = [
        ("table4/bszwarmup_hugeLR",
         bench_config(slw=False, lr=HUGE_LR, steps=steps, batch_warmup=True,
                      total_tokens=budget)),
        ("table4/baseline_reducedLR",
         bench_config(slw=False, lr=REDUCED_LR, steps=steps,
                      total_tokens=budget)),
        ("table4/slw_hugeLR",
         bench_config(slw=True, lr=HUGE_LR, steps=steps,
                      duration=steps // 3, total_tokens=budget)),
        # the paper's actual joint recipe, expressible since the regulator
        # control plane: SLW + batch warmup + token-wise LR warmup at once
        ("table4/slw+bszwarmup_hugeLR",
         bench_config(slw=True, lr=HUGE_LR, steps=steps, batch_warmup=True,
                      duration=steps // 3, total_tokens=budget)),
    ]
    finals = {}
    for name, tc in arms:
        n, res, wall = run_arm(name, tc)
        finals[name] = res
        rows.append((name, wall / max(res.steps, 1) * 1e6,
                     f"diverged={res.diverged} "
                     f"spikes={res.tracker_summary['spikes']} "
                     f"max_ratio={res.tracker_summary['max_loss_ratio']:.2f} "
                     f"final_ppl={final_ppl(res):.1f}"))
    slw = finals["table4/slw_hugeLR"]
    base = finals["table4/baseline_reducedLR"]
    ok = (not slw.diverged) and (
        np.isnan(final_ppl(base)) or final_ppl(slw) <= 1.25 * final_ppl(base))
    rows.append(("table4/verdict", 0.0,
                 f"slw_stable_at_huge_lr={not slw.diverged} "
                 f"slw_quality_vs_reducedLR_baseline_ok={ok} "
                 f"(paper: 99% vs 95% accuracy retention)"))
    return rows
