"""Optimizer-chain benchmark: per-arm step time + optimizer-state memory vs
AdamW, chain-vs-legacy parity, and a table1-style stability arm where
AGC + the per-leaf variance throttle survive an aggressive-LR spike regime
that the plain-AdamW baseline does not.

Rows:
  optim/step_<arm>       us/step of the training loop under each chain arm
                         (adamw is the baseline; derived carries the state
                         memory in KiB and the ratio vs adamw)
  optim/parity           max |param delta| between the default chain and the
                         legacy fused clip+AdamW after a shared trajectory
                         (must be 0.0 — the acceptance contract)
  optim/stability_*      spike/divergence stats at aggressive LR: baseline
                         vs AGC + per-leaf var-throttle (the survival arm
                         self-gates in its derived column)
  optim/shampoo_staleness  steps since the last preconditioner eigh refresh
                         as reported by the chain's telemetry — must sweep
                         0..interval-1 and reset on refresh steps
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, bench_config, run_arm, stability_row
from repro.configs.base import OptimizerConfig, RegulatorSpec
from repro.core.regulators import auto_specs
from repro.optim import (adamw_update, apply_updates, build_optimizer,
                         clip_by_global_norm, init_opt_state)

AGGRESSIVE_LR = 0.5  # calibrated with bench_table1_stability


def _arm_cfg(steps: int, lr: float = 1e-3, **opt_kw):
    tc = bench_config(slw=False, lr=lr, steps=steps)
    return dataclasses.replace(
        tc, optimizer=dataclasses.replace(tc.optimizer, **opt_kw))


def _opt_state_kib(tc) -> float:
    from repro.launch import steps as steps_lib
    abs_state = steps_lib.abstract_train_state(tc.model, tc.optimizer)
    return sum(np.prod(x.shape) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(abs_state["opt"])) / 1024.0


def _parity_row(steps: int = 30) -> Row:
    """Max |param delta| chain vs legacy after a shared random trajectory."""
    cfg = OptimizerConfig(lr=3e-3, weight_decay=0.01, grad_clip=1.0)
    tx = build_optimizer(cfg)
    rng = np.random.RandomState(0)
    p = {"w": jnp.asarray(rng.randn(64, 64), jnp.float32),
         "b": jnp.asarray(rng.randn(64), jnp.float32)}
    pl, pc = p, p
    ol, oc = init_opt_state(p), tx.init(p)
    t0 = time.time()
    for s in range(steps):
        g = jax.tree_util.tree_map(
            lambda x: jnp.asarray(rng.randn(*x.shape), jnp.float32), p)
        clipped, _ = clip_by_global_norm(g, cfg.grad_clip)
        pl, ol, _ = adamw_update(pl, clipped, ol, jnp.float32(cfg.lr), cfg)
        u, oc, _ = tx.update(g, oc, pc, {"lr": jnp.float32(cfg.lr),
                                         "clip_scale": jnp.float32(1.0)})
        pc = apply_updates(pc, u)
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(pl),
                                jax.tree_util.tree_leaves(pc)))
    us = (time.time() - t0) / steps * 1e6
    ok = "OK" if delta == 0.0 else "FAIL"
    return ("optim/parity", us,
            f"max_param_delta={delta:.3g} over {steps} steps [{ok}]")


def _shampoo_staleness_row(steps: int = 12, interval: int = 5) -> Row:
    """Drive the shampoo chain and read back the ``shampoo_staleness``
    telemetry: steps since the last eigh refresh.  All blocks share the
    count-keyed refresh cadence, so the scalar must sweep 0..interval-1
    and snap back to 0 on every refresh step."""
    cfg = OptimizerConfig(optimizer="shampoo", shampoo_interval=interval)
    tx = build_optimizer(cfg)
    rng = np.random.RandomState(1)
    p = {"w": jnp.asarray(rng.randn(32, 32), jnp.float32)}
    st = tx.init(p)
    series = []
    t0 = time.time()
    for _ in range(steps):
        g = {"w": jnp.asarray(rng.randn(32, 32), jnp.float32)}
        u, st, tel = tx.update(g, st, p, {"lr": jnp.float32(1e-3),
                                          "clip_scale": jnp.float32(1.0)})
        p = apply_updates(p, u)
        series.append(int(tel["shampoo_staleness"]))
    us = (time.time() - t0) / steps * 1e6
    want = [s % interval for s in range(steps)]
    ok = "OK" if series == want else "FAIL"
    return ("optim/shampoo_staleness", us,
            f"interval={interval} max_staleness={max(series)} "
            f"series_head={series[:interval + 1]} [{ok}]")


def _with_throttle(tc):
    return dataclasses.replace(
        tc, regulators=auto_specs(tc)
        + (RegulatorSpec(kind="var_lr_throttle"),))


def run(quick: bool = False) -> List[Row]:
    steps = 40 if quick else 80
    rows: List[Row] = []

    # -- step time + state memory per chain arm ------------------------------
    arms = [
        ("adamw", {}),
        ("adamw_agc", {"agc_clip": 0.05}),
        ("adamw_per_leaf_tel", {"telemetry_level": "per_leaf"}),
        ("sm3", {"optimizer": "sm3"}),
        ("shampoo", {"optimizer": "shampoo", "shampoo_interval": 10}),
    ]
    base_kib = base_us = None
    for name, opt_kw in arms:
        tc = _arm_cfg(steps, **opt_kw)
        kib = _opt_state_kib(tc)
        _, res, wall = run_arm(name, tc)
        us = wall / max(res.steps, 1) * 1e6
        if name == "adamw":
            base_kib, base_us = kib, us
        rows.append((
            f"optim/step_{name}", us,
            f"opt_state={kib:.0f}KiB ({kib / base_kib:.2f}x adamw) "
            f"step={us / base_us:.2f}x adamw "
            f"final_loss={res.loss_history[-1]:.3f} "
            f"diverged={res.diverged}"))

    # -- chain-vs-legacy parity ----------------------------------------------
    rows.append(_parity_row())

    # -- shampoo preconditioner staleness ------------------------------------
    rows.append(_shampoo_staleness_row())

    # -- stability: AGC + per-leaf throttle vs baseline at aggressive LR -----
    base_tc = _arm_cfg(steps, lr=AGGRESSIVE_LR)
    guard_tc = _with_throttle(_arm_cfg(
        steps, lr=AGGRESSIVE_LR, agc_clip=0.05,
        telemetry_level="per_leaf"))
    _, res_b, wall_b = run_arm("stability_baseline", base_tc)
    rows.append(stability_row("optim/stability_baseline", res_b, wall_b))
    _, res_g, wall_g = run_arm("stability_agc_throttle", guard_tc)
    row = stability_row("optim/stability_agc_throttle", res_g, wall_g)
    # self-gate: the guarded arm must be strictly more stable than baseline
    b, g = res_b.tracker_summary, res_g.tracker_summary
    survived = (not res_g.diverged) and (
        res_b.diverged or g["spikes"] < b["spikes"])
    rows.append((row[0], row[1],
                 row[2] + f" survives_vs_baseline={survived}"))
    return rows
