"""Table 2: the cost-quality Pareto — token and wall-clock savings.

Two layers of evidence:

1. *Real tiny-scale Pareto*: tokens / wall-clock needed to reach the
   moderate-LR baseline's final validation perplexity, for SLW at the
   aggressive recipe — the direct analogue of Table 2's "earliest checkpoint
   better than baseline".

2. *Full-scale analytic wall-clock model* (GPT-2 1.5B, bsz 4K, seqlen 1K —
   the paper's most challenged case): per-step time as a function of the
   warmup sequence length, time(s) = a*s + b*s^2 from the transformer
   FLOP decomposition (the paper's §5.1 complexity argument), integrated
   over the pacing schedule -> the schedule-mechanical part of the paper's
   time saving, independent of convergence effects.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import (BATCH, SEQ, Row, bench_config, final_ppl,
                               run_arm)
from repro.configs import get_arch
from repro.core import pacing
from repro.configs.base import SLWConfig


def _step_time_model(cfg, batch: int, seqlen: int) -> float:
    """Relative per-step cost: linear (params) + quadratic (attention)."""
    n = 12 * cfg.n_layers * cfg.d_model ** 2 + 2 * cfg.vocab_size * cfg.d_model
    lin = 6.0 * n * batch * seqlen
    quad = 3.0 * 4.0 * cfg.n_layers * batch * seqlen ** 2 * cfg.d_model / 2
    return lin + quad


def run(quick: bool = False) -> List[Row]:
    rows: List[Row] = []
    steps = 80 if quick else 200

    # --- 1. tiny-scale real Pareto -----------------------------------------
    base_name, base, base_wall = run_arm(
        "table2/baseline_moderate",
        bench_config(slw=False, lr=6e-3, steps=steps))
    target = final_ppl(base)
    slw_name, slw, slw_wall = run_arm(
        "table2/slw_same_recipe",
        bench_config(slw=True, lr=6e-3, steps=int(steps * 1.3),
                     duration=steps // 3,
                     total_tokens=steps * BATCH * SEQ))
    # earliest eval point where SLW matches baseline quality
    hit_step, hit_tokens = None, None
    tok_per_step = np.cumsum(  # exact per-step plan from the control plane
        [s * b for s, b in zip(slw.seqlen_history, slw.batch_history)])
    for st, ppl in slw.val_ppl_history:
        if ppl <= target:
            hit_step = st
            hit_tokens = int(tok_per_step[min(st - 1, len(tok_per_step) - 1)])
            break
    base_tokens = base.tokens
    if hit_step is not None:
        tok_save = base_tokens / max(hit_tokens, 1)
        time_save = base_wall / (slw_wall * hit_step / max(slw.steps, 1))
        derived = (f"target_ppl={target:.1f} hit@step={hit_step} "
                   f"token_saving={tok_save:.2f}x time_saving={time_save:.2f}x"
                   f" (paper: up to 2.2x / 3.7x)")
    else:
        derived = (f"target_ppl={target:.1f} not reached in {slw.steps} steps"
                   f" (slw final={final_ppl(slw):.1f})")
    rows.append(("table2/pareto_tiny_scale",
                 slw_wall / max(slw.steps, 1) * 1e6, derived))

    # --- 2. full-scale analytic schedule model ------------------------------
    cfg = get_arch("gpt2-1.5b").model
    batch, full = 4096, 1024
    total_tokens = 157e9
    slw_cfg = SLWConfig(start_seq_len=64, duration_steps=45_000,
                        round_multiple=8, max_buckets=64)
    ladder = pacing.bucket_ladder(slw_cfg, full)
    t_full = _step_time_model(cfg, batch, full)

    # integrate the SLW schedule to the same token budget
    tokens = 0.0
    time_slw = 0.0
    step = 0
    while tokens < total_tokens:
        s = pacing.seqlen_at(slw_cfg, step, full, ladder=ladder)
        tokens += batch * s
        time_slw += _step_time_model(cfg, batch, s)
        step += 1
    steps_base = total_tokens / (batch * full)
    time_base = steps_base * t_full
    rows.append((
        "table2/schedule_mechanical_saving_1p5b", 0.0,
        f"same 157B tokens: SLW steps={step} vs base={steps_base:.0f}, "
        f"warmup compute saving={time_base / time_slw:.3f}x "
        f"(schedule-only; convergence gains per tiny-scale arm above)"))
    return rows
