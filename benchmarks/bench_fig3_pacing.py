"""Fig. 3 / Table 6: pacing-duration sweep + the low-cost tuning heuristic.

Sweeps T, detects "significant fluctuation" (>1.3x previous best val ppl)
in the early probe window, and checks the paper's claim that the longest
calm T is a good choice — without full trainings for tuning.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, bench_config, final_ppl, run_arm
from repro.configs.base import SLWConfig
from repro.core import significant_fluctuation, tune_slw


def run(quick: bool = False) -> List[Row]:
    steps = 80 if quick else 200
    warmup = 15
    lr = 6e-2
    rows: List[Row] = []
    sweep = [warmup, 3 * warmup, 6 * warmup] if quick else \
        [warmup, 2 * warmup, 4 * warmup, 8 * warmup]

    results = {}
    for t_dur in sweep:
        name, res, wall = run_arm(
            f"fig3/slw_T{t_dur}",
            bench_config(slw=True, lr=lr, steps=steps, duration=t_dur,
                         warmup_steps=warmup))
        probe_window = [p for st, p in res.val_ppl_history
                        if st <= 3 * warmup + 10]
        fluct = significant_fluctuation(probe_window)
        results[t_dur] = (res, fluct)
        rows.append((name, wall / max(res.steps, 1) * 1e6,
                     f"final_ppl={final_ppl(res):.1f} "
                     f"early_fluctuation={fluct} "
                     f"spikes={res.tracker_summary['spikes']}"))

    # the tuner itself, driven by short probes only
    def probe(slw_cfg: SLWConfig):
        tc = bench_config(slw=True, lr=lr, steps=3 * warmup,
                          warmup_steps=warmup)
        import dataclasses
        tc = dataclasses.replace(tc, slw=slw_cfg, eval_interval=5)
        from repro.launch.train import train
        res = train(tc, quiet=True, stop_on_nan=False)
        return [p for _, p in res.val_ppl_history]

    tuned = tune_slw(probe, SLWConfig(round_multiple=8, max_buckets=12),
                     warmup_steps=warmup, seqlen_s_grid=(8, 16, 32),
                     t_multiple_range=(1, 8))
    # open-loop replay of the tuned schedule through the regulator stack:
    # the exact warmup token cost, no training needed
    import dataclasses
    from repro.core.regulators import predict_trajectory
    tc_tuned = dataclasses.replace(
        bench_config(slw=True, lr=lr, steps=steps, warmup_steps=warmup),
        slw=SLWConfig(enabled=True, start_seq_len=tuned.seqlen_s,
                      duration_steps=tuned.duration, round_multiple=8,
                      max_buckets=12))
    plans = predict_trajectory(tc_tuned, tuned.duration)
    warmup_tokens = sum(p.batch_size * p.seq_len for p in plans)
    rows.append(("fig3/low_cost_tuner", 0.0,
                 f"chose seqlen_s={tuned.seqlen_s} T={tuned.duration} "
                 f"after {tuned.probe_runs} short probes "
                 f"(no full trainings); predicted warmup cost "
                 f"{warmup_tokens} tokens"))
    return rows
