"""Serving benchmark: continuous batching vs the static-batch baseline.

Workload: one prompt bucket, *ragged generation lengths* (the serving-side
face of the paper's sequence-length heterogeneity).  The static path
processes requests in arrival-order batches and every batch decodes until
its longest member finishes — short generations ride along as dead rows.
The engine evicts finished slots and backfills from the queue, so useful
decode tok/s is higher whenever generation lengths diverge.

Rows (``--json`` via benchmarks.run writes BENCH_serve.json):
  serve/engine_prefill      us per prompt token + prefill tok/s
  serve/engine_decode       us per useful token + tok/s + p50/p95 latency
  serve/static_decode       us per useful token + tok/s (legacy path)
  serve/continuous_vs_static  decode-throughput speedup (the gate: > 1x)
  serve/batched_prefill     (k, bucket) admission prefill; tokens must
                            match sequential admission exactly
  serve/decode_kernel_interpret  fused decode through the flash-decode
                            kernel (interpret mode on CPU — the timing is
                            plumbing, the parity column is the gate)
  serve/paged_decode        paged KV pool at dense-equivalent page count
                            (equal slot count, no admission waits): decode
                            tok/s vs the dense engine (gate: within 15%)
                            + exact-parity column
  serve/paged_memory        oversubscribed pool (pool tokens < dense slot
                            rows): resident KV bytes paged vs dense + the
                            throughput cost of waiting on pages
  serve/router_2x           Router over 2 replicas (half the slots each):
                            aggregate decode tok/s + routing split
                            (gate: exact parity with the single engine)
  serve/policy_spf          shortest-prompt-first admission, same workload
                            (parity gate; ordering is the only difference)
  serve/policy_budget       budget-packing admission at a binding budget
                            (parity gate)
  serve/disaggregated       prefill-role -> decode-role pair behind the
                            router (parity gate + handoff overhead)
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

import jax
import jax.numpy as jnp

import dataclasses

from benchmarks.common import BENCH_MODEL, Row
from repro.models import model_zoo
from repro.serve import (InferenceEngine, Request, Router, SchedulerConfig,
                         cache_nbytes, make_replicas)

PROMPT_LEN = 48
SLOTS = 4
# high-variance budgets: the continuous-batching case
GEN_CYCLE = (4, 28, 8, 24, 4, 16, 6, 28)


def _requests(vocab: int, n: int, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=tuple(int(t) for t in
                                 rng.integers(0, vocab, size=PROMPT_LEN)),
                    max_tokens=GEN_CYCLE[i % len(GEN_CYCLE)])
            for i in range(n)]


def _static_decode(model, params, reqs, cache_len: int):
    """Legacy static batching: arrival-order batches of SLOTS, each decoded
    until its longest generation finishes.  Returns (decode_s, useful)."""
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(lambda p, c, t: model.decode(p, c, t))
    decode_s, useful = 0.0, 0
    for i in range(0, len(reqs), SLOTS):
        batch = reqs[i:i + SLOTS]
        toks = jnp.asarray([r.tokens for r in batch], jnp.int32)
        logits, cache = prefill(params, {"tokens": toks})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        steps = max(r.max_tokens for r in batch) - 1
        t0 = time.time()
        for _ in range(steps):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        decode_s += time.time() - t0
        useful += sum(min(steps, r.max_tokens - 1) for r in batch)
    return decode_s, useful


def run(quick: bool = False) -> List[Row]:
    n_requests = 8 if quick else 16
    cfg = BENCH_MODEL
    model = model_zoo.build_model(cfg, dtype=jnp.float32, remat="none")
    params = model_zoo.init_params(jax.random.PRNGKey(0), cfg)
    cache_len = PROMPT_LEN + max(GEN_CYCLE)
    sched = SchedulerConfig(n_slots=SLOTS, cache_len=cache_len,
                            min_prompt_bucket=16, round_multiple=16,
                            max_buckets=6)
    reqs = _requests(cfg.vocab_size, n_requests)

    engine = InferenceEngine(model, params, sched)
    engine.run(_requests(cfg.vocab_size, 2, seed=1))  # compile warm-up
    engine.reset_stats()
    results = engine.run(reqs)
    s = engine.stats
    assert all(r.n_generated == q.max_tokens for r, q in zip(results, reqs))

    _static_decode(model, params, reqs[:SLOTS], cache_len)  # warm-up
    st_s, st_useful = _static_decode(model, params, reqs, cache_len)
    st_tok_s = st_useful / max(st_s, 1e-9)

    # batched-prefill arm: same workload, up to SLOTS same-bucket prompts
    # per (k, bucket) prefill call — tokens must match sequential admission
    sched_b = SchedulerConfig(n_slots=SLOTS, cache_len=cache_len,
                              min_prompt_bucket=16, round_multiple=16,
                              max_buckets=6, prefill_batch=SLOTS)
    eng_b = InferenceEngine(model, params, sched_b)
    # warm the full (k, bucket) shape set: backfill admissions see every
    # k in 1..SLOTS, so a 2-request warm-up would leave compiles in the
    # timed run
    eng_b.run(_requests(cfg.vocab_size, n_requests, seed=1))
    eng_b.reset_stats()
    res_b = eng_b.run(reqs)
    bp_match = all(a.tokens == b.tokens for a, b in zip(res_b, results))
    sb = eng_b.stats

    # decode-backend arm: the fused step through the flash-decode kernel
    # (interpret mode off-TPU, so a small request subset keeps this cheap)
    kmodel = model_zoo.build_model(cfg.replace(
        decode_backend="kernel_interpret"), dtype=jnp.float32, remat="none")
    eng_k = InferenceEngine(kmodel, params, sched)
    sub = reqs[:4]
    eng_k.run(_requests(cfg.vocab_size, 2, seed=2))  # compile warm-up
    eng_k.reset_stats()
    res_k = eng_k.run(sub)
    dk_match = all(a.tokens == b.tokens for a, b in zip(res_k, results[:4]))
    sk = eng_k.stats

    # paged arm 1: dense-equivalent pool (n_pages=0) — same admission
    # capacity as the dense engine, so decode tok/s is the apples-to-apples
    # indirection cost (the gate: within 15% of dense at equal slot count)
    sched_p = dataclasses.replace(sched, paged=True, page_size=16)
    eng_p = InferenceEngine(model, params, sched_p)
    eng_p.run(_requests(cfg.vocab_size, 2, seed=1))  # compile warm-up
    eng_p.reset_stats()
    res_p = eng_p.run(reqs)
    pg_match = all(a.tokens == b.tokens for a, b in zip(res_p, results))
    sp = eng_p.stats

    # paged arm 2: oversubscribed pool — 14 * 16 = 224 pool tokens vs
    # 4 * 76 = 304 dense; admission waits on pages, memory is the win
    sched_m = dataclasses.replace(sched, paged=True, page_size=16,
                                  n_pages=14)
    eng_m = InferenceEngine(model, params, sched_m)
    eng_m.run(_requests(cfg.vocab_size, 2, seed=1))
    eng_m.reset_stats()
    res_m = eng_m.run(reqs)
    pm_match = all(a.tokens == b.tokens for a, b in zip(res_m, results))
    sm = eng_m.stats
    dense_kv = cache_nbytes(engine.cache)
    paged_kv = cache_nbytes(eng_m.cache)

    # router arm: 2 replicas at half the slots each — same total width;
    # aggregate throughput + the routing split, parity is the gate
    sched_r = dataclasses.replace(sched, n_slots=SLOTS // 2)
    router = Router(make_replicas(model, params, sched_r, 2))
    router.run(_requests(cfg.vocab_size, 4, seed=1))  # compile warm-up
    for rep in router.replicas:
        rep.reset_stats()
    router.stats.routed.clear()
    t0 = time.time()
    res_r = router.run(reqs)
    rt_wall = time.time() - t0
    rt_match = all(a.tokens == b.tokens for a, b in zip(res_r, results))
    rt_decode_s = sum(rep.stats.decode_s for rep in router.replicas)
    rt_useful = sum(rep.stats.generated_tokens - rep.stats.admitted
                    for rep in router.replicas)
    rt_tok_s = rt_useful / max(rt_decode_s, 1e-9)

    # policy arms: admission *order* changes, per-request streams do not
    pol_rows: List[Row] = []
    for key, pol, pb in (("serve/policy_spf", "shortest-prompt-first",
                          SLOTS),
                         ("serve/policy_budget", "budget-packing", SLOTS)):
        sched_pol = dataclasses.replace(
            sched, policy=pol, prefill_batch=pb,
            # binding budget for the packing arm: two mid-size requests
            pack_budget=2 * (PROMPT_LEN + max(GEN_CYCLE)))
        eng_pol = InferenceEngine(model, params, sched_pol)
        eng_pol.run(_requests(cfg.vocab_size, n_requests, seed=1))
        eng_pol.reset_stats()
        res_pol = eng_pol.run(reqs)
        pol_match = all(a.tokens == b.tokens
                        for a, b in zip(res_pol, results))
        spol = eng_pol.stats
        pol_rows.append((key,
                         1e6 * spol.decode_s
                         / max(spol.generated_tokens - spol.admitted, 1),
                         f"tok_s={spol.decode_tok_s:.0f} "
                         f"steps={spol.decode_steps} "
                         f"parity={'exact' if pol_match else 'MISMATCH'}"))

    # disaggregation arm: one prefill-role + decode-role pair
    pair = Router(make_replicas(model, params, sched, 1, disaggregate=True))
    pair.run(_requests(cfg.vocab_size, 4, seed=1))  # compile warm-up
    dec = pair.replicas[0]
    dec.reset_stats()
    dec.prefill_replica.reset_stats()
    res_d = pair.run(reqs)
    dg_match = all(a.tokens == b.tokens for a, b in zip(res_d, results))
    sd = dec.stats

    speedup = s.decode_tok_s / max(st_tok_s, 1e-9)
    rows: List[Row] = [
        ("serve/engine_prefill", 1e6 * s.prefill_s / max(s.prefill_tokens, 1),
         f"tok_s={s.prefill_tok_s:.0f} prompts={n_requests} "
         f"buckets={len(engine.scheduler.ladder)}"),
        ("serve/engine_decode",
         1e6 * s.decode_s / max(s.generated_tokens - s.admitted, 1),
         f"tok_s={s.decode_tok_s:.0f} steps={s.decode_steps} "
         f"p50_ms={s.latency_percentile(50)*1e3:.1f} "
         f"p95_ms={s.latency_percentile(95)*1e3:.1f}"),
        ("serve/static_decode", 1e6 * st_s / max(st_useful, 1),
         f"tok_s={st_tok_s:.0f} batches={-(-n_requests // SLOTS)} "
         f"useful={st_useful}"),
        ("serve/continuous_vs_static", 0.0,
         f"decode_speedup={speedup:.2f}x slots={SLOTS} "
         f"requests={n_requests}"),
        ("serve/batched_prefill",
         1e6 * sb.prefill_s / max(sb.prefill_tokens, 1),
         f"tok_s={sb.prefill_tok_s:.0f} prefill_batch={SLOTS} "
         f"parity={'exact' if bp_match else 'MISMATCH'}"),
        ("serve/decode_kernel_interpret",
         1e6 * sk.decode_s / max(sk.generated_tokens - sk.admitted, 1),
         f"tok_s={sk.decode_tok_s:.0f} backend=kernel_interpret "
         f"requests={len(sub)} "
         f"parity={'exact' if dk_match else 'MISMATCH'}"),
        ("serve/paged_decode",
         1e6 * sp.decode_s / max(sp.generated_tokens - sp.admitted, 1),
         f"tok_s={sp.decode_tok_s:.0f} "
         f"vs_dense={sp.decode_tok_s / max(s.decode_tok_s, 1e-9):.2f}x "
         f"pages={sched_p.resolved_n_pages}x{sched_p.page_size} "
         f"parity={'exact' if pg_match else 'MISMATCH'}"),
        ("serve/paged_memory",
         1e6 * sm.decode_s / max(sm.generated_tokens - sm.admitted, 1),
         f"tok_s={sm.decode_tok_s:.0f} kv_bytes={paged_kv} "
         f"dense_bytes={dense_kv} "
         f"saving={1 - paged_kv / max(dense_kv, 1):.0%} "
         f"parity={'exact' if pm_match else 'MISMATCH'}"),
        ("serve/router_2x", 1e6 * rt_wall / max(rt_useful, 1),
         f"tok_s={rt_tok_s:.0f} replicas=2x{SLOTS // 2}slots "
         f"routed={'/'.join(str(v) for v in router.stats.routed.values())} "
         f"parity={'exact' if rt_match else 'MISMATCH'}"),
        *pol_rows,
        ("serve/disaggregated",
         1e6 * sd.decode_s / max(sd.generated_tokens - sd.admitted, 1),
         f"tok_s={sd.decode_tok_s:.0f} "
         f"prefill_tok_s={dec.prefill_replica.stats.prefill_tok_s:.0f} "
         f"parity={'exact' if dg_match else 'MISMATCH'}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
