"""Benchmark harness: one module per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,fig1,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("table1", "benchmarks.bench_table1_stability"),
    ("table2", "benchmarks.bench_table2_pareto"),
    ("fig1", "benchmarks.bench_fig1_variance"),
    ("fig2", "benchmarks.bench_fig2_mixed_seqlen"),
    ("fig3", "benchmarks.bench_fig3_pacing"),
    ("table4", "benchmarks.bench_table4_gpt3recipe"),
    ("a2", "benchmarks.bench_a2_lr_decay"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--only", default="",
                   help="comma-separated suite keys (default: all)")
    args = p.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    import importlib
    print("name,us_per_call,derived")
    failures = 0
    for key, module_name in SUITES:
        if only is not None and key not in only:
            continue
        try:
            mod = importlib.import_module(module_name)
            t0 = time.time()
            rows = mod.run(quick=args.quick)
            for name, us, derived in rows:
                print(f'{name},{us:.1f},"{derived}"', flush=True)
            print(f'_suite/{key},{(time.time()-t0)*1e6:.0f},"suite wall time"',
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f'{key}/ERROR,0,"{type(e).__name__}: {e}"', flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
