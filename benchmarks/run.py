"""Benchmark harness: one module per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
``--json PATH`` additionally writes the rows as a JSON baseline (e.g.
``--only kernels --json benchmarks/BENCH_kernels.json``) so the perf
trajectory is tracked in-repo from PR to PR.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,fig1,...]
      [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback

SUITES = [
    ("table1", "benchmarks.bench_table1_stability"),
    ("table2", "benchmarks.bench_table2_pareto"),
    ("fig1", "benchmarks.bench_fig1_variance"),
    ("fig2", "benchmarks.bench_fig2_mixed_seqlen"),
    ("fig3", "benchmarks.bench_fig3_pacing"),
    ("table4", "benchmarks.bench_table4_gpt3recipe"),
    ("a2", "benchmarks.bench_a2_lr_decay"),
    ("optim", "benchmarks.bench_optim"),
    ("kernels", "benchmarks.bench_kernels"),
    ("serve", "benchmarks.bench_serve"),
    ("roofline", "benchmarks.bench_roofline"),
    ("chaos", "benchmarks.bench_chaos"),
    ("gns", "benchmarks.bench_gns"),
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--only", default="",
                   help="comma-separated suite keys (default: all)")
    p.add_argument("--json", default="",
                   help="also write the rows to this path as a JSON baseline")
    args = p.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    import importlib
    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for key, module_name in SUITES:
        if only is not None and key not in only:
            continue
        try:
            mod = importlib.import_module(module_name)
            t0 = time.time()
            rows = mod.run(quick=args.quick)
            for name, us, derived in rows:
                print(f'{name},{us:.1f},"{derived}"', flush=True)
                all_rows.append({"name": name, "us_per_call": round(us, 1),
                                 "derived": derived})
            print(f'_suite/{key},{(time.time()-t0)*1e6:.0f},"suite wall time"',
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f'{key}/ERROR,0,"{type(e).__name__}: {e}"', flush=True)
    if args.json and failures:
        # never clobber a tracked baseline with a partial row set
        print(f'_json,{0:.1f},"skipped {args.json}: {failures} suite '
              f'failure(s)"', flush=True)
    elif args.json:
        import jax
        baseline = {
            "meta": {
                "backend": jax.default_backend(),
                "jax": jax.__version__,
                "python": platform.python_version(),
                "suites": sorted(only) if only else [k for k, _ in SUITES],
                "note": ("interpret-mode timings on CPU measure plumbing, "
                         "not TPU speed; derived columns carry max-err vs "
                         "the oracles and analytic TPU flops"),
            },
            "rows": all_rows,
        }
        with open(args.json, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f'_json,{0:.1f},"wrote {args.json}"', flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
