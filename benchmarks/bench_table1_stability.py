"""Table 1: loss-ratio instability across training recipes.

Arms (scaled-down replicas of the paper's cases):
  baseline @ moderate LR   (paper case 1/7: bsz512)
  baseline @ aggressive LR (paper case 3/9: bsz4K + 4x LR -> spikes)
  baseline @ aggressive LR + tighter grad clip (A.3.2: clipping insufficient)
  SLW @ aggressive LR      (paper case 4/10: spikes -> 0)
  Shortformer @ aggressive LR (case 11: spike at the stage switch)
  Batch-size warmup @ aggressive LR (case 12: no stability benefit)
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (Row, bench_config, run_arm, stability_row)
from repro.configs.base import RegulatorSpec
from repro.core.regulators import auto_specs

MODERATE_LR = 6e-3
# Calibrated on this container: fp32 + tiny params + global clip suppress
# spikes until LR ~0.3-0.8; 0.5 is the regime where the paper's phenomenology
# (frequent loss-ratio spikes, SLW suppressing them) reproduces.
AGGRESSIVE_LR = 0.5


def _with_throttle(tc):
    import dataclasses
    return dataclasses.replace(
        tc, regulators=auto_specs(tc)
        + (RegulatorSpec(kind="var_lr_throttle"),))


def run(quick: bool = False) -> List[Row]:
    steps = 80 if quick else 160
    dur = steps // 3
    arms = [
        ("table1/baseline_moderate",
         bench_config(slw=False, lr=MODERATE_LR, steps=steps)),
        ("table1/baseline_aggressive",
         bench_config(slw=False, lr=AGGRESSIVE_LR, steps=steps)),
        ("table1/baseline_aggressive_clip0.25",
         bench_config(slw=False, lr=AGGRESSIVE_LR, steps=steps,
                      grad_clip=0.25)),
        ("table1/slw_aggressive",
         bench_config(slw=True, lr=AGGRESSIVE_LR, steps=steps,
                      duration=steps // 2)),
        ("table1/shortformer_aggressive",
         bench_config(slw=True, lr=AGGRESSIVE_LR, steps=steps, duration=dur,
                      pacing="two_stage")),
        ("table1/bszwarmup_aggressive",
         bench_config(slw=False, lr=AGGRESSIVE_LR, steps=steps,
                      batch_warmup=True)),
        ("table1/slw_variance_gated",
         bench_config(slw=True, lr=AGGRESSIVE_LR, steps=steps, duration=dur,
                      pacing="variance_gated")),
        # beyond-paper: LR throttled by the Adam variance-max precursor
        # instead of (or on top of) the seqlen curriculum
        ("table1/baseline_var_lr_throttle",
         _with_throttle(bench_config(slw=False, lr=AGGRESSIVE_LR,
                                     steps=steps))),
    ]
    rows = []
    for name, tc in arms:
        rows.append(stability_row(*run_arm(name, tc)))
    return rows
