"""Chaos arm: the fault-injection matrix against the recovery stack.

Each scenario drives the reduced bench recipe (SLW enabled — the paper's
stabilizer is part of the system under test) through a deterministic
injected fault and *gates* on the outcome: the run must complete every
step, end with a finite loss, and stay within the rollback/restart budget.
A gate violation raises, so ``benchmarks.run`` records the suite failure
and exits nonzero — this is the CI chaos lane's pass/fail signal, not just
a timing table.

Scenarios (all seeded; two runs inject the identical fault):

* ``nan``     — NaN-poisoned parameter mid-run -> in-process rollback
* ``spike``   — finite loss explosion (params x32) -> rollback on the
                loss-ratio trigger
* ``crash``   — InjectedCrash between the checkpoint tmp-write and rename
                -> process-level supervisor restart from the prior step
* ``bitflip`` — flipped byte in the newest checkpoint payload -> quarantine
                + fallback restore on restart
"""
from __future__ import annotations

import math
import shutil
import tempfile
import time
from typing import List

from benchmarks.common import Row, bench_config
from repro.core.recovery import RecoveryConfig
from repro.distributed.fault_injection import (FaultInjector, InjectedCrash,
                                               parse_faults)
from repro.distributed.fault_tolerance import RetryPolicy, TrainSupervisor
from repro.launch.train import Trainer, train

ROLLBACK_BUDGET = 3


def _gate(name: str, ok: bool, detail: str) -> None:
    if not ok:
        raise AssertionError(f"chaos gate failed [{name}]: {detail}")


def _check_completed(name: str, res, steps: int) -> None:
    final = res.loss_history[-1] if res.loss_history else float("nan")
    _gate(name, res.steps == steps,
          f"completed {res.steps}/{steps} steps")
    _gate(name, not res.diverged, f"diverged (events={res.recovery_events})")
    _gate(name, math.isfinite(final), f"final loss {final}")
    _gate(name, res.rollbacks <= ROLLBACK_BUDGET,
          f"{res.rollbacks} rollbacks > budget {ROLLBACK_BUDGET}")


def _derived(res, wall_note: str = "") -> str:
    final = res.loss_history[-1] if res.loss_history else float("nan")
    return (f"rollbacks={res.rollbacks} faults={len(res.faults_fired)} "
            f"final_loss={final:.3f} diverged={res.diverged}{wall_note}")


def _recovery() -> RecoveryConfig:
    return RecoveryConfig(policy=RetryPolicy(max_retries=ROLLBACK_BUDGET))


def run(quick: bool = False) -> List[Row]:
    steps = 30 if quick else 60
    mid = steps // 2
    rows: List[Row] = []

    # -- in-process rollback scenarios --------------------------------------
    for key, spec in (("nan", f"nan_grad@{mid}"),
                      ("spike", f"spike@{mid}:32.0")):
        inj = FaultInjector(parse_faults(spec), seed=0)
        t0 = time.time()
        res = train(bench_config(slw=True, steps=steps), quiet=True,
                    recovery=_recovery(), fault_injector=inj)
        wall = time.time() - t0
        _check_completed(f"chaos/{key}", res, steps)
        _gate(f"chaos/{key}", res.rollbacks >= 1,
              f"fault {spec} fired={res.faults_fired} but no rollback")
        rows.append((f"chaos/{key}", wall / steps * 1e6, _derived(res)))

    # -- crash mid-checkpoint + supervisor restart --------------------------
    d = tempfile.mkdtemp(prefix="chaos_crash_")
    try:
        import dataclasses
        tc = dataclasses.replace(bench_config(slw=True, steps=steps),
                                 checkpoint_dir=d, checkpoint_interval=10)
        # the crash point fires from inside the checkpoint writer, so it
        # must land on a checkpoint step — the second one, so a valid
        # step_10 exists for the restart to restore from
        inj = FaultInjector(parse_faults("crash@20:post_tmp"), seed=0)
        sup = TrainSupervisor(policy=RetryPolicy(max_retries=2))
        out = {}

        def run_fn(resume: bool) -> str:
            out["res"] = train(tc, resume=resume, quiet=True,
                               recovery=_recovery(), fault_injector=inj)
            return "ok"

        t0 = time.time()
        try:
            sup.run(run_fn)
        except InjectedCrash as e:  # supervisor budget must absorb it
            _gate("chaos/crash", False, f"supervisor did not recover: {e}")
        wall = time.time() - t0
        res = out["res"]
        _check_completed("chaos/crash", res, steps)
        _gate("chaos/crash", sup.restarts == 1,
              f"{sup.restarts} restarts (want exactly 1)")
        _gate("chaos/crash", res.restored_from_step is not None,
              "restart did not restore a checkpoint")
        rows.append(("chaos/crash", wall / steps * 1e6,
                     _derived(res, f" restarts={sup.restarts} "
                                   f"resumed@{res.restored_from_step}")))
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # -- checkpoint bitflip + quarantine fallback ---------------------------
    d = tempfile.mkdtemp(prefix="chaos_bitflip_")
    try:
        import dataclasses
        half = dataclasses.replace(bench_config(slw=True, steps=mid),
                                   checkpoint_dir=d, checkpoint_interval=10)
        full = dataclasses.replace(bench_config(slw=True, steps=steps),
                                   checkpoint_dir=d, checkpoint_interval=10)
        t0 = time.time()
        first = train(half, quiet=True)
        _gate("chaos/bitflip", first.steps == mid,
              f"seed run stopped at {first.steps}")
        FaultInjector(seed=0).corrupt_checkpoint(d)  # newest payload
        tr = Trainer(full, recovery=_recovery())
        restored = tr.resume()
        res = tr.run()
        wall = time.time() - t0
        _check_completed("chaos/bitflip", res, steps)
        _gate("chaos/bitflip", len(tr.ckpt.quarantined) == 1,
              f"quarantined={tr.ckpt.quarantined}")
        _gate("chaos/bitflip", restored is not None and restored < mid,
              f"restored from {restored}, want a pre-corruption step")
        rows.append(("chaos/bitflip", wall / steps * 1e6,
                     _derived(res, f" quarantined=1 resumed@{restored}")))
    finally:
        shutil.rmtree(d, ignore_errors=True)

    return rows
