"""Chaos arm: the fault-injection matrix against the recovery stack.

Each scenario drives the reduced bench recipe (SLW enabled — the paper's
stabilizer is part of the system under test) through a deterministic
injected fault and *gates* on the outcome: the run must complete every
step, end with a finite loss, and stay within the rollback/restart budget.
A gate violation raises, so ``benchmarks.run`` records the suite failure
and exits nonzero — this is the CI chaos lane's pass/fail signal, not just
a timing table.

Scenarios (all seeded; two runs inject the identical fault):

* ``nan``     — NaN-poisoned parameter mid-run -> in-process rollback
* ``spike``   — finite loss explosion (params x32) -> rollback on the
                loss-ratio trigger
* ``crash``   — InjectedCrash between the checkpoint tmp-write and rename
                -> process-level supervisor restart from the prior step
* ``bitflip`` — flipped byte in the newest checkpoint payload -> quarantine
                + fallback restore on restart
* ``serve_slots`` — serving-side slot-fault matrix: admission-phase and
                consumer-callback faults injected across a 2-replica
                router under sustained bounded-queue ``try_submit`` load.
                Gates: every uid gets a result, faulted uids retire with
                ``finish_reason="error"``, clean uids match the
                fault-free oracle tokenwise (faults never leak across
                slots or replicas), shed counts stay bounded, and both
                replicas drain to all-slots-free.
"""
from __future__ import annotations

import math
import shutil
import tempfile
import time
from typing import List

from benchmarks.common import Row, bench_config
from repro.core.recovery import RecoveryConfig
from repro.distributed.fault_injection import (FaultInjector, InjectedCrash,
                                               parse_faults)
from repro.distributed.fault_tolerance import RetryPolicy, TrainSupervisor
from repro.launch.train import Trainer, train

ROLLBACK_BUDGET = 3


def _gate(name: str, ok: bool, detail: str) -> None:
    if not ok:
        raise AssertionError(f"chaos gate failed [{name}]: {detail}")


def _check_completed(name: str, res, steps: int) -> None:
    final = res.loss_history[-1] if res.loss_history else float("nan")
    _gate(name, res.steps == steps,
          f"completed {res.steps}/{steps} steps")
    _gate(name, not res.diverged, f"diverged (events={res.recovery_events})")
    _gate(name, math.isfinite(final), f"final loss {final}")
    _gate(name, res.rollbacks <= ROLLBACK_BUDGET,
          f"{res.rollbacks} rollbacks > budget {ROLLBACK_BUDGET}")


def _derived(res, wall_note: str = "") -> str:
    final = res.loss_history[-1] if res.loss_history else float("nan")
    return (f"rollbacks={res.rollbacks} faults={len(res.faults_fired)} "
            f"final_loss={final:.3f} diverged={res.diverged}{wall_note}")


def _recovery() -> RecoveryConfig:
    return RecoveryConfig(policy=RetryPolicy(max_retries=ROLLBACK_BUDGET))


def run(quick: bool = False) -> List[Row]:
    steps = 30 if quick else 60
    mid = steps // 2
    rows: List[Row] = []

    # -- in-process rollback scenarios --------------------------------------
    for key, spec in (("nan", f"nan_grad@{mid}"),
                      ("spike", f"spike@{mid}:32.0")):
        inj = FaultInjector(parse_faults(spec), seed=0)
        t0 = time.time()
        res = train(bench_config(slw=True, steps=steps), quiet=True,
                    recovery=_recovery(), fault_injector=inj)
        wall = time.time() - t0
        _check_completed(f"chaos/{key}", res, steps)
        _gate(f"chaos/{key}", res.rollbacks >= 1,
              f"fault {spec} fired={res.faults_fired} but no rollback")
        rows.append((f"chaos/{key}", wall / steps * 1e6, _derived(res)))

    # -- crash mid-checkpoint + supervisor restart --------------------------
    d = tempfile.mkdtemp(prefix="chaos_crash_")
    try:
        import dataclasses
        tc = dataclasses.replace(bench_config(slw=True, steps=steps),
                                 checkpoint_dir=d, checkpoint_interval=10)
        # the crash point fires from inside the checkpoint writer, so it
        # must land on a checkpoint step — the second one, so a valid
        # step_10 exists for the restart to restore from
        inj = FaultInjector(parse_faults("crash@20:post_tmp"), seed=0)
        sup = TrainSupervisor(policy=RetryPolicy(max_retries=2))
        out = {}

        def run_fn(resume: bool) -> str:
            out["res"] = train(tc, resume=resume, quiet=True,
                               recovery=_recovery(), fault_injector=inj)
            return "ok"

        t0 = time.time()
        try:
            sup.run(run_fn)
        except InjectedCrash as e:  # supervisor budget must absorb it
            _gate("chaos/crash", False, f"supervisor did not recover: {e}")
        wall = time.time() - t0
        res = out["res"]
        _check_completed("chaos/crash", res, steps)
        _gate("chaos/crash", sup.restarts == 1,
              f"{sup.restarts} restarts (want exactly 1)")
        _gate("chaos/crash", res.restored_from_step is not None,
              "restart did not restore a checkpoint")
        rows.append(("chaos/crash", wall / steps * 1e6,
                     _derived(res, f" restarts={sup.restarts} "
                                   f"resumed@{res.restored_from_step}")))
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # -- checkpoint bitflip + quarantine fallback ---------------------------
    d = tempfile.mkdtemp(prefix="chaos_bitflip_")
    try:
        import dataclasses
        half = dataclasses.replace(bench_config(slw=True, steps=mid),
                                   checkpoint_dir=d, checkpoint_interval=10)
        full = dataclasses.replace(bench_config(slw=True, steps=steps),
                                   checkpoint_dir=d, checkpoint_interval=10)
        t0 = time.time()
        first = train(half, quiet=True)
        _gate("chaos/bitflip", first.steps == mid,
              f"seed run stopped at {first.steps}")
        FaultInjector(seed=0).corrupt_checkpoint(d)  # newest payload
        tr = Trainer(full, recovery=_recovery())
        restored = tr.resume()
        res = tr.run()
        wall = time.time() - t0
        _check_completed("chaos/bitflip", res, steps)
        _gate("chaos/bitflip", len(tr.ckpt.quarantined) == 1,
              f"quarantined={tr.ckpt.quarantined}")
        _gate("chaos/bitflip", restored is not None and restored < mid,
              f"restored from {restored}, want a pre-corruption step")
        rows.append(("chaos/bitflip", wall / steps * 1e6,
                     _derived(res, f" quarantined=1 resumed@{restored}")))
    finally:
        shutil.rmtree(d, ignore_errors=True)

    rows.append(_serve_slots_row(quick))
    return rows


def _serve_slots_row(quick: bool) -> Row:
    """Serving slot-fault matrix under sustained ``try_submit`` load."""
    from collections import deque

    import numpy as np
    import jax
    import jax.numpy as jnp

    from benchmarks.common import BENCH_MODEL
    from repro.models import model_zoo
    from repro.serve import (InferenceEngine, Request, Router,
                             SchedulerConfig, make_replicas)

    n = 12 if quick else 24
    ADMIT_FAULT = 5   # uid % 5 == 0: _first_token raises at admission
    STREAM_FAULT = 7  # uid % 7 == 3: on_token consumer raises at token 2

    model = model_zoo.build_model(BENCH_MODEL, dtype=jnp.float32,
                                  remat="none")
    params = model_zoo.init_params(jax.random.PRNGKey(0), BENCH_MODEL)
    cfg = SchedulerConfig(n_slots=2, cache_len=48, min_prompt_bucket=8,
                          round_multiple=16, max_buckets=4, max_pending=2)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    tokens=tuple(int(t) for t in rng.integers(
                        0, BENCH_MODEL.vocab_size, size=10 + i % 7)),
                    max_tokens=4 + i % 5)
            for i in range(n)]

    # fault-free oracle first (the same fleet shape, no injection)
    oracle = InferenceEngine(
        model, params,
        SchedulerConfig(n_slots=2, cache_len=48, min_prompt_bucket=8,
                        round_multiple=16, max_buckets=4)).run(reqs)
    by_uid_oracle = {r.uid: r for r in oracle}

    router = Router(make_replicas(model, params, cfg, 2))
    for rep in router.replicas:
        orig = rep.core._first_token

        def failing(req, logits, _orig=orig):
            if req.uid % ADMIT_FAULT == 0:
                raise RuntimeError("injected admission fault")
            return _orig(req, logits)

        rep.core._first_token = failing

    counts: dict = {}

    def on_token(uid: int, tok: int) -> None:
        counts[uid] = counts.get(uid, 0) + 1
        if uid % STREAM_FAULT == 3 and uid % ADMIT_FAULT != 0 \
                and counts[uid] == 2:
            raise RuntimeError("injected consumer fault")

    backlog = deque(reqs)
    shed_attempts = 0
    done: dict = {}
    t0 = time.time()
    while backlog or router.busy:
        # sustained load: keep shoving the backlog head at the bounded
        # queues; a refused submit is an explicit shed, retried next tick
        while backlog:
            if router.submit(backlog[0]):
                backlog.popleft()
            else:
                shed_attempts += 1
                break
        router.pump(on_token)
        for res in router.take_finished():
            done[res.uid] = res
    wall = time.time() - t0
    for res in router.take_finished():
        done[res.uid] = res

    admit_faulted = {r.uid for r in reqs if r.uid % ADMIT_FAULT == 0}
    stream_faulted = {r.uid for r in reqs
                      if r.uid % STREAM_FAULT == 3
                      and r.uid not in admit_faulted}
    clean = {r.uid for r in reqs} - admit_faulted - stream_faulted

    _gate("chaos/serve_slots", set(done) == {r.uid for r in reqs},
          f"missing results for {sorted({r.uid for r in reqs} - set(done))}")
    for uid in admit_faulted | stream_faulted:
        _gate("chaos/serve_slots", done[uid].finish_reason == "error",
              f"uid {uid} faulted but finished "
              f"{done[uid].finish_reason!r}")
    for uid in admit_faulted:
        _gate("chaos/serve_slots", done[uid].tokens == [],
              f"uid {uid} failed admission yet has tokens")
    for uid in clean:
        _gate("chaos/serve_slots",
              done[uid].tokens == by_uid_oracle[uid].tokens,
              f"uid {uid} clean but diverged from the fault-free oracle "
              f"(fault leaked across slots/replicas)")
    # bounded shed: each refused attempt waits one pump tick, so attempts
    # can never exceed a few per request even under sustained pressure
    _gate("chaos/serve_slots", shed_attempts <= 4 * n,
          f"{shed_attempts} shed attempts for {n} requests")
    slot_errors = sum(rep.stats.slot_errors for rep in router.replicas)
    _gate("chaos/serve_slots",
          slot_errors == len(admit_faulted) + len(stream_faulted),
          f"slot_errors={slot_errors}, want "
          f"{len(admit_faulted) + len(stream_faulted)}")
    for rep in router.replicas:
        _gate("chaos/serve_slots", sorted(rep.scheduler.free) == [0, 1],
              f"{rep.name} leaked slots: free={rep.scheduler.free}")
        _gate("chaos/serve_slots", not rep.scheduler.busy,
              f"{rep.name} still busy after drain")
    jax.block_until_ready(router.replicas[0].core.cache)
    return ("chaos/serve_slots", wall / n * 1e6,
            f"uids={n} admit_faults={len(admit_faulted)} "
            f"stream_faults={len(stream_faulted)} shed={shed_attempts} "
            f"slot_errors={slot_errors} clean_parity=exact")
