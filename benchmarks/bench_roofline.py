"""Roofline table generator — reads the dry-run artifacts
(experiments/dryrun/*.json + *.measure.json) and emits the per-cell
three-term roofline (§Roofline of EXPERIMENTS.md).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import Row
from repro.roofline import build_report

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells(mesh: str = "single", tag: str = "") -> List[Dict]:
    cells = []
    suffix = f".{tag}" if tag else ""
    for path in sorted(glob.glob(os.path.join(
            DRYRUN_DIR, f"*__{mesh}{suffix}.json"))):
        if ".measure" in path:
            continue
        with open(path) as f:
            rec = json.load(f)
        mpath = path.replace(".json", ".measure.json") if not tag else \
            path.replace(f"{suffix}.json", f".measure{suffix}.json")
        measure = None
        if os.path.exists(mpath):
            with open(mpath) as f:
                measure = json.load(f)
        cells.append({"record": rec, "measure": measure})
    return cells


def table(mesh: str = "single", tag: str = "") -> List[Dict]:
    out = []
    for cell in load_cells(mesh, tag):
        rep = build_report(cell["record"], cell["measure"])
        row = rep.summary()
        row["measured"] = cell["measure"] is not None
        out.append(row)
    return out


def run(quick: bool = False) -> List[Row]:
    rows: List[Row] = []
    cells = table("single")
    if not cells:
        return [("roofline/no_dryrun_artifacts", 0.0,
                 "run: python -m repro.launch.dryrun first")]
    for c in cells:
        name = f"roofline/{c['arch']}__{c['shape']}"
        t_step = max(c["t_compute_s"], c["t_memory_s"], c["t_collective_s"])
        derived = (f"comp={c['t_compute_s']*1e3:.1f}ms "
                   f"mem={c['t_memory_s']*1e3:.1f}ms "
                   f"coll={c['t_collective_s']*1e3:.1f}ms "
                   f"bound={c['bottleneck']} "
                   f"useful={c['useful_flops_ratio']:.2f} "
                   f"mfu_ub={c['mfu_upper_bound']:.3f}"
                   + ("" if c["measured"] else " [unmeasured]"))
        rows.append((name, t_step * 1e6, derived))
    return rows
