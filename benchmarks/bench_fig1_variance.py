"""Fig. 1 + Table 3: Adam variance norm/max telemetry and its correlation
with loss-ratio spikes."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, bench_config, run_arm
from repro.core import pearson


def run(quick: bool = False) -> List[Row]:
    steps = 80 if quick else 200
    name, res, wall = run_arm(
        "fig1/baseline_aggressive",
        bench_config(slw=False, lr=0.5, steps=steps))
    ratios = np.asarray([r if np.isfinite(r) else 10.0
                         for r in res.loss_ratios])
    var_max = np.asarray(res.var_max_history)[:len(ratios)]
    var_l1 = np.asarray(res.var_l1_history)[:len(ratios)]
    r_max, p_max = pearson(ratios, var_max)
    r_l1, p_l1 = pearson(ratios, var_l1)

    name2, res2, wall2 = run_arm(
        "fig1/slw_aggressive",
        bench_config(slw=True, lr=0.5, steps=steps, duration=steps // 2))
    us = wall / max(res.steps, 1) * 1e6
    return [
        ("fig1/pearson_lossratio_vs_varmax", us,
         f"r={r_max:.3f} p={p_max:.2e} (paper: 0.26, p~0)"),
        ("fig1/pearson_lossratio_vs_varnorm", us,
         f"r={r_l1:.3f} p={p_l1:.2e} (paper: 0.23, p~0)"),
        ("fig1/varmax_peak_baseline", us,
         f"peak={np.nanmax(var_max):.3e}"),
        ("fig1/varmax_peak_slw", wall2 / max(res2.steps, 1) * 1e6,
         f"peak={np.nanmax(res2.var_max_history):.3e} "
         f"spikes={res2.tracker_summary['spikes']} vs baseline "
         f"{res.tracker_summary['spikes']}"),
    ]
