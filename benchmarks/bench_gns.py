"""Gradient-noise-scale benchmark: estimator accuracy, in-step measurement
overhead, and the pre-spike forecast lead time, self-gated for CI.

Rows:
  gns/estimator        unbiased B_noise estimate on a synthetic problem with
                       known gradient mean/covariance (analytic B_noise =
                       tr(Sigma)/|G|^2); gates the relative error
  gns/step_overhead    jitted train-step time with the in-step GNS
                       measurement on vs off (interleaved medians); gates
                       the estimator overhead < 5% (`overhead_ok=True`)
  gns/forecast_lead    injected slow-burn divergence: a sub-threshold
                       perturbation (invisible to the loss/var gates)
                       followed by an overt spike.  The direction-sketch
                       precursor must fire from measurement alone in the
                       window between them, giving a positive lead over the
                       DivergenceDetector (`lead_ok=True`)
  gns/clean_arm        same config, no faults: the precursor must stay
                       silent (false-positive gate)
  gns/critical_batch   B_noise-measured batch warmup on the bench corpus;
                       derived shows the measured B_noise trajectory pulled
                       back out of the --metrics-jsonl stream via
                       telemetry.read_metrics_jsonl

The fault matrix note: at this bench scale the landscape recovers from any
single perturbation instead of self-amplifying, so the overt spike that
the detector catches is injected at a known lag after the sub-threshold
episode.  The *measured* quantity is still honest — the precursor has no
access to the fault schedule and must fire from the realized gradient
directions, and the clean arm gates it against firing on nothing.
"""
from __future__ import annotations

import dataclasses
import os
import re
import tempfile
import time
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import BATCH, BENCH_MODEL, Row, SEQ, bench_config
from repro.configs.base import GNSConfig, RegulatorSpec, TrainConfig
from repro.core.recovery import RecoveryConfig
from repro.core.telemetry import read_metrics_jsonl
from repro.data import DataPipeline, SyntheticCorpus
from repro.distributed.fault_injection import FaultInjector
from repro.distributed.fault_tolerance import RetryPolicy
from repro.gns import GNSEstimator, gns_estimates
from repro.launch import steps as steps_lib
from repro.launch.train import MetricsJsonlHook, train

MAX_OVERHEAD = 0.05   # estimator step-time overhead gate vs baseline
MIN_LEAD = 2          # precursor must precede the detector by >= this

_EVENT_STEP = re.compile(r"@(\d+)\(")
DETECTOR_KINDS = ("nan_loss", "nan_grad", "loss_spike", "var_excursion")


def _gate(name: str, ok: bool, detail: str) -> None:
    if not ok:
        raise AssertionError(f"gns gate failed [{name}]: {detail}")


def _event_step(ev: str) -> Optional[int]:
    m = _EVENT_STEP.search(ev)
    return int(m.group(1)) if m else None


def _first_step(events, kinds) -> Optional[int]:
    for ev in events:
        if any(ev.startswith(k + "@") for k in kinds):
            return _event_step(ev)
    return None


# ---------------------------------------------------------------------------
# estimator accuracy on a known-variance synthetic problem
# ---------------------------------------------------------------------------

def _estimator_row(quick: bool) -> Row:
    """Per-sample gradients g = mu + sigma*eps with known mu, sigma: the
    analytic noise scale is B_noise = tr(Sigma)/|G|^2 = n*sigma^2/|mu|^2.
    The estimator only sees the (small, big) squared-norm pair per step —
    exactly what the jitted step emits."""
    rng = np.random.RandomState(0)
    n, sigma, big, k = 256, 0.5, 64, 8
    mu = rng.randn(n)
    mu /= np.linalg.norm(mu)                      # |G|^2 = 1
    true_b_noise = n * sigma ** 2                 # tr(Sigma)/|G|^2
    est = GNSEstimator(ema_window=64, warmup_obs=8)
    obs = 100 if quick else 300
    t0 = time.time()
    for _ in range(obs):
        samples = mu + sigma * rng.randn(big, n)
        shard_means = samples.reshape(k, big // k, n).mean(axis=1)
        small_sq = float(np.mean(np.sum(shard_means ** 2, axis=1)))
        big_sq = float(np.sum(samples.mean(axis=0) ** 2))
        est.update(small_sq, big_sq, big // k, big)
    us = (time.time() - t0) / obs * 1e6
    rel_err = abs(est.b_noise - true_b_noise) / true_b_noise
    # sanity: the raw unbiased formulas agree with the analytic expectations
    g_sq, tr_sigma = gns_estimates(small_sq, big_sq, big // k, big)
    _gate("estimator", rel_err < 0.2,
          f"B_noise={est.b_noise:.1f} vs true {true_b_noise:.1f} "
          f"(rel_err={rel_err:.3f})")
    return ("gns/estimator", us,
            f"b_noise={est.b_noise:.1f} true={true_b_noise:.1f} "
            f"rel_err={rel_err:.3f} crit_batch={est.critical_batch()} "
            f"accuracy_ok=True")


# ---------------------------------------------------------------------------
# in-step measurement overhead
# ---------------------------------------------------------------------------

def _overhead_row(quick: bool) -> Row:
    """Median jitted step time, GNS estimator on vs off, interleaved so
    machine drift hits both arms equally.  The sketch arm is reported but
    not gated (the CI contract is the *estimator* overhead)."""
    tc = bench_config(slw=False, steps=10)
    model_cfg = BENCH_MODEL
    from repro.models import model_zoo
    model = model_zoo.build_model(model_cfg, dtype=jnp.float32, remat="none")
    corpus = SyntheticCorpus(vocab_size=model_cfg.vocab_size, seq_len=SEQ,
                             seed=1234)
    batch = DataPipeline(corpus, BATCH, model_cfg=model_cfg).batch_at(0)

    arms = [
        ("base", None),
        ("est", GNSConfig(enabled=True, shards=4, precursor_window=0)),
        ("sketch", GNSConfig(enabled=True, shards=4, precursor_window=12)),
    ]
    fns, states, samples = {}, {}, {}
    for name, gns in arms:
        fns[name] = jax.jit(
            steps_lib.make_train_step(model, tc.optimizer, gns=gns),
            donate_argnums=(0,))
        states[name] = steps_lib.init_train_state(
            jax.random.PRNGKey(0), model_cfg, tc.optimizer)
        # warmup compile
        states[name], m = fns[name](states[name], batch, np.float32(1e-3),
                                    np.float32(1.0))
        jax.block_until_ready(m["loss"])
        samples[name] = []
    reps = 15 if quick else 40
    for _ in range(reps):
        for name, _gns in arms:
            t0 = time.perf_counter()
            states[name], m = fns[name](states[name], batch,
                                        np.float32(1e-3), np.float32(1.0))
            jax.block_until_ready(m["loss"])
            samples[name].append(time.perf_counter() - t0)
    med = {name: float(np.median(v)) for name, v in samples.items()}
    overhead = med["est"] / med["base"] - 1.0
    sketch_overhead = med["sketch"] / med["base"] - 1.0
    _gate("step_overhead", overhead < MAX_OVERHEAD,
          f"estimator overhead {overhead * 100:.1f}% >= "
          f"{MAX_OVERHEAD * 100:.0f}% (base={med['base'] * 1e3:.1f}ms "
          f"est={med['est'] * 1e3:.1f}ms)")
    return ("gns/step_overhead", med["est"] * 1e6,
            f"base={med['base'] * 1e3:.1f}ms est={overhead * 100:+.1f}% "
            f"sketch={sketch_overhead * 100:+.1f}% "
            f"gate<{MAX_OVERHEAD * 100:.0f}% overhead_ok=True")


# ---------------------------------------------------------------------------
# forecast lead on the injected fault matrix
# ---------------------------------------------------------------------------

def _lead_config(steps: int) -> TrainConfig:
    return dataclasses.replace(
        bench_config(slw=False, steps=steps, lr=1e-3),
        gns=GNSConfig(enabled=True, shards=4))


def _lead_rows(quick: bool) -> List[Row]:
    steps = 32
    sub, overt = 12, 22   # sub-threshold episode, then the overt spike
    fault = f"spike@{sub}:2.0,spike@{overt}:32.0"
    rec = RecoveryConfig(policy=RetryPolicy(max_retries=3))

    t0 = time.time()
    res = train(_lead_config(steps), quiet=True, recovery=rec,
                fault_injector=FaultInjector.from_cli(fault, seed=0))
    wall = time.time() - t0
    pre_step = _first_step(res.precursor_events, ("precursor",))
    det_step = _first_step(res.recovery_events, DETECTOR_KINDS)
    _gate("forecast_lead", res.steps == steps,
          f"completed {res.steps}/{steps}")
    _gate("forecast_lead", det_step is not None,
          f"detector never fired (events={res.recovery_events})")
    _gate("forecast_lead", pre_step is not None,
          f"precursor never fired (events={res.precursor_events})")
    lead = det_step - pre_step
    _gate("forecast_lead", lead >= MIN_LEAD,
          f"lead {lead} < {MIN_LEAD} (precursor@{pre_step} "
          f"detector@{det_step})")
    lead_row = ("gns/forecast_lead", wall / steps * 1e6,
                f"precursor@{pre_step} detector@{det_step} lead={lead} "
                f"rollbacks={res.rollbacks} gate>={MIN_LEAD} lead_ok=True")

    t0 = time.time()
    clean = train(_lead_config(steps), quiet=True, recovery=rec)
    wall = time.time() - t0
    _gate("clean_arm", not clean.precursor_events,
          f"false positive: {clean.precursor_events}")
    _gate("clean_arm", clean.rollbacks == 0,
          f"clean run rolled back: {clean.recovery_events}")
    clean_row = ("gns/clean_arm", wall / steps * 1e6,
                 f"precursor_events=0 rollbacks=0 over {steps} steps "
                 f"quiet_ok=True")
    return [lead_row, clean_row]


# ---------------------------------------------------------------------------
# B_noise-measured batch warmup
# ---------------------------------------------------------------------------

def _critical_batch_row(quick: bool) -> Row:
    steps = 20 if quick else 30
    tc = dataclasses.replace(
        bench_config(slw=False, steps=steps, lr=1e-3),
        gns=GNSConfig(enabled=True, shards=4, precursor_window=0,
                      warmup_obs=4),
        regulators=(RegulatorSpec(kind="critical_batch"),))
    with tempfile.TemporaryDirectory(prefix="bench_gns_") as d:
        path = os.path.join(d, "metrics.jsonl")
        t0 = time.time()
        res = train(tc, quiet=True, hooks=[MetricsJsonlHook(path)])
        wall = time.time() - t0
        _, rows = read_metrics_jsonl(path)
    # recompute the measured B_noise trajectory from the streamed scalars
    # (the same parse-back path the tests round-trip)
    est = GNSEstimator(ema_window=tc.gns.ema_window,
                       warmup_obs=tc.gns.warmup_obs)
    for r in rows:
        if "gns_small_sq" in r:
            est.update(r["gns_small_sq"], r["gns_big_sq"],
                       r["gns_b_small"], r["gns_b_big"])
    b0, b1 = res.batch_history[0], res.batch_history[-1]
    _gate("critical_batch", res.steps == steps,
          f"completed {res.steps}/{steps}")
    _gate("critical_batch", b1 >= b0,
          f"batch shrank {b0} -> {b1}")
    b_noise = est.b_noise
    note = ("inf" if b_noise == float("inf") else f"{b_noise:.1f}")
    return ("gns/critical_batch", wall / steps * 1e6,
            f"batch {b0}->{b1} of {tc.global_batch} "
            f"b_noise={note} jsonl_rows={len(rows)} "
            f"final_loss={res.loss_history[-1]:.3f}")


def run(quick: bool = False) -> List[Row]:
    rows: List[Row] = [_estimator_row(quick), _overhead_row(quick)]
    rows += _lead_rows(quick)
    rows.append(_critical_batch_row(quick))
    return rows
