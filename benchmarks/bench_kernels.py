"""Kernel microbenchmarks: Pallas (interpret mode on CPU) vs pure-jnp refs.

On this CPU container interpret mode measures *correctness* plumbing, not
TPU speed; the derived column reports the max |err| vs the oracle and the
analytic FLOPs the kernel would execute on the TPU target.  Every
differentiable kernel (flash attention, ssd, wkv6) gets a fwd row and a
fwd+bwd row (jax.grad through the custom_vjp, grad max-err vs the oracle
gradients).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels import flash_attention, flash_decode, ssd, wkv6
from repro.kernels.flash_attention.ref import attention_reference_gqa
from repro.kernels.flash_decode.ref import decode_attention_reference
from repro.kernels.rwkv6.ref import wkv6_sequential
from repro.kernels.ssd.ref import ssd_fwd_reference


def _timeit(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run(quick: bool = False) -> List[Row]:
    rows: List[Row] = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # flash attention
    b, s, h, kv, d = 1, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    fa = lambda: flash_attention(q, k, v, causal=True, block_q=64,
                                 block_k=64, interpret=True)
    us = _timeit(lambda *_: fa())
    ref = attention_reference_gqa(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(fa() - ref)))
    tpu_flops = 2 * 2 * b * h * s * s / 2 * d
    rows.append(("kernels/flash_attention_interp", us,
                 f"max_err={err:.2e} causal_tpu_flops={tpu_flops:.2e}"))

    # flash attention fwd+bwd (custom_vjp through the Pallas bwd kernels)
    w = jax.random.normal(ks[3], (b, s, h, d))

    def _loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, block_q=64,
                                       block_k=64, interpret=True) * w)

    def _loss_ref(q, k, v):
        return jnp.sum(attention_reference_gqa(q, k, v, causal=True) * w)

    grad_flash = jax.jit(jax.grad(_loss_flash, (0, 1, 2)))
    us = _timeit(grad_flash, q, k, v)
    gs = grad_flash(q, k, v)
    gr = jax.grad(_loss_ref, (0, 1, 2))(q, k, v)
    gerr = max(float(jnp.max(jnp.abs(a - b_))) for a, b_ in zip(gs, gr))
    # analytic bwd cost: dq/dk/dv each re-do the two fwd matmuls' work plus
    # the dp recompute — canonical flash-attention bwd ≈ 2.5x the fwd flops
    rows.append(("kernels/flash_attention_bwd_interp", us,
                 f"grad_max_err={gerr:.2e} "
                 f"causal_tpu_flops={2.5 * tpu_flops:.2e}"))

    # flash decode (inference-only: one query row per slot, ragged lengths)
    bd, sd, hd, kvd, dd = 4, 256, 4, 2, 32
    qd = jax.random.normal(ks[4], (bd, hd, dd))
    kc = jax.random.normal(ks[5], (bd, sd, kvd, dd))
    vc = jax.random.normal(ks[6], (bd, sd, kvd, dd))
    lengths = jnp.asarray([1, 97, 200, 256], jnp.int32)
    f_fd = lambda: flash_decode(qd, kc, vc, lengths, block_k=64,
                                interpret=True)
    us = _timeit(lambda *_: f_fd())
    ref_fd = decode_attention_reference(qd, kc, vc, lengths)
    err = float(jnp.max(jnp.abs(f_fd() - ref_fd)))
    # one (G, D) x (S, D)^T score matmul + the p @ V accumulate per kv head
    fd_flops = 2 * 2 * bd * hd * sd * dd
    rows.append(("kernels/flash_decode_interp", us,
                 f"max_err={err:.2e} tpu_flops={fd_flops:.2e}"))

    # ssd
    b2, h2, s2, p2, n2, ck = 1, 2, 256, 32, 16, 64
    x = jax.random.normal(ks[3], (b2, h2, s2, p2))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (b2, h2, s2)))
    a = -jnp.exp(jax.random.normal(ks[5], (h2,)) * 0.5)
    bi = jax.random.normal(ks[6], (b2, s2, n2))
    ci = jax.random.normal(ks[7], (b2, s2, n2))
    f_ssd = lambda: ssd(x, dt, a, bi, ci, chunk=ck, interpret=True)
    us = _timeit(lambda *_: f_ssd())
    y, st = f_ssd()
    yr, sr = ssd_fwd_reference(x, dt, a, bi, ci, chunk=ck)
    err = float(jnp.max(jnp.abs(y - yr)))
    # per chunk: scores/intra (2 Q^2 (N+P) MACs) + inter/state (4 Q N P)
    ssd_flops = 2 * b2 * h2 * s2 * (ck * (n2 + p2) + 2 * n2 * p2)
    rows.append(("kernels/ssd_interp", us,
                 f"max_err={err:.2e} tpu_flops={ssd_flops:.2e}"))

    # ssd fwd+bwd (custom_vjp through the Pallas reverse-scan kernel)
    wy = jax.random.normal(ks[0], (b2, h2, s2, p2))

    def _loss_ssd(fn):
        return lambda *t: jnp.sum(fn(*t)[0] * wy)

    grad_ssd = jax.jit(jax.grad(_loss_ssd(lambda *t: ssd(
        *t, chunk=ck, interpret=True)), (0, 1, 2, 3, 4)))
    us = _timeit(grad_ssd, x, dt, a, bi, ci)
    gs = grad_ssd(x, dt, a, bi, ci)
    gr = jax.grad(_loss_ssd(lambda *t: ssd_fwd_reference(*t, chunk=ck)),
                  (0, 1, 2, 3, 4))(x, dt, a, bi, ci)
    gerr = max(float(jnp.max(jnp.abs(g - r_))) for g, r_ in zip(gs, gr))
    # bwd recomputes the fwd tile and runs ~2x the fwd matmul work for the
    # five cotangents — analytic ≈ 3x fwd flops
    rows.append(("kernels/ssd_bwd_interp", us,
                 f"grad_max_err={gerr:.2e} tpu_flops={3 * ssd_flops:.2e}"))

    # wkv6
    bw, hw, sw, dw, ckw = 1, 2, 128, 16, 32
    r = jax.random.normal(ks[0], (bw, hw, sw, dw))
    kk = jax.random.normal(ks[1], (bw, hw, sw, dw))
    vv = jax.random.normal(ks[2], (bw, hw, sw, dw))
    lw = -jnp.exp(jax.random.normal(ks[3], (bw, hw, sw, dw)) * 0.5)
    u = jax.random.normal(ks[4], (hw, dw)) * 0.5
    f_wkv = lambda: wkv6(r, kk, vv, lw, u, chunk=ckw, interpret=True)
    us = _timeit(lambda *_: f_wkv())
    y, st = f_wkv()
    yr, sr = wkv6_sequential(r, kk, vv, lw, u)
    err = float(jnp.max(jnp.abs(y - yr)))
    # per chunk: (Q,Q,D) pairwise tensor (2 Q^2 D) + att@v (Q^2 D) + state
    # in/out (4 Q D^2)
    wkv_flops = 2 * bw * hw * sw * (3 * ckw * dw // 2 + 2 * dw * dw)
    rows.append(("kernels/wkv6_interp", us,
                 f"max_err={err:.2e} tpu_flops={wkv_flops:.2e}"))

    # wkv6 fwd+bwd (custom_vjp through the Pallas reverse-scan kernel)
    wyk = jax.random.normal(ks[5], (bw, hw, sw, dw))

    def _loss_wkv(fn):
        return lambda *t: jnp.sum(fn(*t)[0] * wyk)

    grad_wkv = jax.jit(jax.grad(_loss_wkv(lambda *t: wkv6(
        *t, chunk=ckw, interpret=True)), (0, 1, 2, 3, 4)))
    us = _timeit(grad_wkv, r, kk, vv, lw, u)
    gs = grad_wkv(r, kk, vv, lw, u)
    gr = jax.grad(_loss_wkv(wkv6_sequential), (0, 1, 2, 3, 4))(r, kk, vv,
                                                               lw, u)
    gerr = max(float(jnp.max(jnp.abs(g - r_))) for g, r_ in zip(gs, gr))
    rows.append(("kernels/wkv6_bwd_interp", us,
                 f"grad_max_err={gerr:.2e} tpu_flops={3 * wkv_flops:.2e}"))

    # XLA-path blockwise attention (the production fallback) for scale
    from repro.models.attention import blockwise_attention
    f_blk = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, True, 64))
    us = _timeit(f_blk, q, k, v)
    rows.append(("kernels/blockwise_attention_xla", us,
                 "jnp online-softmax fallback (same oracle)"))
    return rows
